"""Figs. 16/17 analog: rendering quality — stereo bit-accuracy and Δcut
compression PSNR/SSIM vs the raw-attribute baseline."""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np

from benchmarks.common import city_scene, emit, vr_rig
from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core.pipeline import render_stereo, render_stereo_reference


def psnr(a, b):
    mse = float(np.mean((np.asarray(a) - np.asarray(b)) ** 2))
    return 10 * np.log10(1.0 / max(mse, 1e-12))


def ssim(a, b, c1=0.01 ** 2, c2=0.03 ** 2):
    a = np.asarray(a).mean(-1)
    b = np.asarray(b).mean(-1)
    mu_a, mu_b = a.mean(), b.mean()
    va, vb = a.var(), b.var()
    cov = ((a - mu_a) * (b - mu_b)).mean()
    return ((2 * mu_a * mu_b + c1) * (2 * cov + c2)
            / ((mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2)))


def run():
    _cfg, leaves, tree = city_scene("medium")
    rig = vr_rig()
    cut, _ = ls.full_search(tree, np.asarray(rig.left.pos),
                            jnp.float32(rig.left.focal), jnp.float32(48.0))
    gids, _cnt, _ = ls.cut_gids(cut, tree, budget=16384)
    q = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    q = dc.replace(q, opacity=jnp.where(gids >= 0, q.opacity, 0.0))

    # stereo bit-accuracy (Fig. 16: ours vs Base — exact)
    il, ir, _ = render_stereo(q, rig, tile=16, list_len=256, max_pairs=1 << 17)
    rl, rr = render_stereo_reference(q, rig)
    exact = bool((np.asarray(il) == np.asarray(rl)).all()
                 and (np.asarray(ir) == np.asarray(rr)).all())
    emit("quality/stereo_bit_accurate", 0.0,
         f"exact={exact} (WARP/Cicero-style warping is lossy by design)")

    # compression quality (Fig. 17): codec-only loss
    for k_codes in (256, 1024, 4096):
        codec = comp.fit_codec(tree.gaussians, k_codes=k_codes, iters=8)
        dq = comp.roundtrip(codec, q)
        cl, cr, _ = render_stereo(dq, rig, tile=16, list_len=256,
                                  max_pairs=1 << 17)
        p = psnr(cl, rl)
        s = ssim(cl, rl)
        bpg = comp.wire_bytes_per_gaussian(codec)
        emit(f"quality/codec_k{k_codes}", 0.0,
             f"psnr={p:.1f}dB ssim={s:.4f} bytes/gaussian={bpg}")


if __name__ == "__main__":
    run()
