"""Paged union stream: budget pressure × bandwidth tier × fleet size.

Sweeps the paged encode-once Δcut stream (repro.serve.delta_path) along the
three axes that shape it:

  * B ∈ {2, 8} concurrent headsets on a half-overlapping walk (the
    bench_fleet_sync fleet geometry);
  * budget pressure: `delta_budget` as a fraction of the fleet's COLD union
    (measured by an un-budgeted probe) — 1.0 is the ample baseline, smaller
    fractions force the stream to page and carry debt across syncs;
  * bandwidth tier: uncontrolled vs the `BANDWIDTH_TIERS` presets, driving
    the closed-loop per-client rate controller.

Reported per (B, pressure, tier):
  * per-client wire bytes (mean / p95 across clients × syncs) — under
    pressure these are bytes of pages actually SHIPPED, never of deferred
    rows;
  * pages per sync (fleet stream) and pages pulled per client;
  * deferred-row backlog while moving, and syncs-to-drain once the fleet
    goes static — the convergence claim (`pending` empties; a finite number
    proves no Gaussian is silently lost);
  * fleet sync latency (host wall-clock).

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene, fewer
syncs, B=2 only → every (pressure, tier) row still lands in
BENCH_delta_stream.json).
"""

import os
import time

import numpy as np

from benchmarks.bench_fleet_sync import _fleet_walk
from benchmarks.common import city_scene, emit
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc

FOCAL, TAU = 260.0, 48.0
OVERLAP = 0.5
PRESSURES = (1.0, 0.25, 0.0625)
# uncontrolled / a 4KB-per-sync trickle that binds at ANY scene scale (the
# controller must pace + eventually escalate τ) / the named phone preset
TIERS = (None, 4.0e3, "phone")
MAX_DRAIN = 64


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def run():
    scale = "small" if _smoke() else "medium"
    syncs = 4 if _smoke() else 10
    batches = (2,) if _smoke() else (2, 8)
    page = 64 if _smoke() else 256
    _cfg, _leaves, tree = city_scene(scale)
    hi = np.asarray(tree.gaussians.mu).max(axis=0)
    extent = (float(hi[0]), float(hi[1]))
    cfg = SessionConfig(tau=TAU, cut_budget=16384)
    emit("delta_stream/scene", 0.0,
         f"scale={scale} nodes={tree.meta.n_real} page={page} syncs={syncs}")

    for b in batches:
        walks = _fleet_walk(b, syncs, OVERLAP, extent)
        # un-budgeted probe: the cold union the pressure axis is relative to
        probe = svc.LodService(tree, cfg, b, focal=FOCAL, mode="pooled",
                               dedup=True)
        u0 = int(np.asarray(probe.sync(walks[0]).unique_delta).sum())
        del probe
        emit(f"delta_stream/b{b}/cold_union", 0.0, f"rows={u0}")

        for press in PRESSURES:
            # pow2 budgets keep the stream-width retrace set bounded
            budget = max(2 * page, _pow2_ceil(int(u0 * press)))
            for tier in TIERS:
                service = svc.LodService(
                    tree, cfg, b, focal=FOCAL, mode="pooled", dedup=True,
                    delta_budget=budget, page_size=page, bandwidth=tier)
                t0 = time.perf_counter()
                first = service.sync(walks[0])
                np.asarray(first.sync_bytes)  # force the first (compile) sync
                t_first = time.perf_counter() - t0

                times, rows = [], [first]
                for f in range(1, syncs):
                    t0 = time.perf_counter()
                    stats = service.sync(walks[f])
                    np.asarray(stats.sync_bytes)
                    times.append(time.perf_counter() - t0)
                    rows.append(stats)

                # fleet stops moving: the carried debt must drain to zero
                drain = 0
                while (np.asarray(service.state.pending).any()
                       and drain < MAX_DRAIN):
                    service.sync(walks[-1])
                    drain += 1
                leftover = int(np.asarray(service.state.pending).sum())

                by = np.stack([np.asarray(s.sync_bytes) for s in rows])
                pages = np.stack([np.asarray(s.pages) for s in rows])
                stream_pages = np.stack(
                    [np.asarray(s.delta_shipped).max() for s in rows])
                backlog = np.stack(
                    [np.asarray(s.delta_deferred).sum() for s in rows])
                tname = ("uncapped" if tier is None else
                         tier if isinstance(tier, str) else
                         f"{int(tier)}B")
                key = (f"delta_stream/b{b}/p{int(press * 1000):04d}/{tname}")
                emit(f"{key}/sync_us", float(np.median(times) * 1e6)
                     if times else 0.0,
                     f"budget={budget} t_first={t_first * 1e3:.0f}ms")
                emit(f"{key}/bytes_per_client", float(by.mean()),
                     f"mean={by.mean() / 1024:.2f}KiB "
                     f"p95={np.percentile(by, 95) / 1024:.2f}KiB")
                emit(f"{key}/pages_per_sync", float(pages.mean()),
                     f"client_mean={pages.mean():.2f} "
                     f"shipped_rows_max={int(stream_pages.max())}")
                emit(f"{key}/deferred_backlog", float(backlog.mean()),
                     f"peak={int(backlog.max())} drain_syncs={drain} "
                     f"leftover={leftover}")
    emit("delta_stream/summary", 0.0,
         "paged stream: tight budgets bound per-sync bytes, carried debt "
         "drains once the fleet goes static — no Gaussian silently lost")


if __name__ == "__main__":
    run()
