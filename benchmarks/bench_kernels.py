"""Pallas kernel interpret-mode sweeps vs oracles (correctness timing is NOT
TPU perf — the structural numbers for the roofline come from the dry-run)."""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.binning import BinConfig, bin_left
from repro.core.camera import StereoRig, make_camera
from repro.core.gaussians import random_gaussians
from repro.core.projection import depth_ranks, project
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    g = random_gaussians(rng, 2000, sh_degree=1, extent=6.0)
    cam = make_camera([0, -18, 2], [0, 0, 0], focal_px=220.0, width=160,
                      height=96, near=0.25)
    rig = StereoRig(left=cam, baseline=0.06)
    wide = dc.replace(cam, width=256)
    splats = project(g, rig, wide)
    ranks = depth_ranks(splats)
    cfg = BinConfig(tile=16, max_pairs=1 << 16, list_len=192)
    lists = bin_left(splats, wide.width, cam.height, cfg, ranks)

    t_p = timeit(lambda: ops.rasterize(lists, splats, width=cam.width,
                                       height=cam.height, tile=16, eye="left",
                                       use_pallas=True), repeats=2)
    t_r = timeit(lambda: ops.rasterize(lists, splats, width=cam.width,
                                       height=cam.height, tile=16, eye="left",
                                       use_pallas=False), repeats=2)
    emit("kernel/rasterize_pallas_interp", t_p, "")
    emit("kernel/rasterize_oracle", t_r, "")

    x = jnp.asarray(rng.normal(size=(4096, 24)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(1024, 24)).astype(np.float32))
    emit("kernel/vq_pallas_interp",
         timeit(lambda: ops.vq_assign(x, cb, use_pallas=True), repeats=2), "")
    emit("kernel/vq_oracle",
         timeit(lambda: ops.vq_assign(x, cb, use_pallas=False), repeats=2), "")

    q = jnp.asarray(rng.normal(size=(2, 8, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 64)).astype(np.float32))
    emit("kernel/flash_attn_pallas_interp",
         timeit(lambda: ops.flash_attention(q, k, k, use_pallas=True), repeats=2), "")
    emit("kernel/flash_attn_oracle",
         timeit(lambda: ops.flash_attention(q, k, k, use_pallas=False), repeats=2), "")


if __name__ == "__main__":
    run()
