"""Encode-once fleet sync: bytes and codec work vs fleet size × overlap.

Sweeps B ∈ {1, 4, 16, 64} concurrent headsets × spatial overlap ∈
{0, 0.5, 0.9} (clients ride one shared walk, fanned out on a ring whose
radius shrinks with the overlap factor — ov=0.9 is a co-located "tour
group", ov=0 a spread fleet). Every sync runs the production path: pooled
on-device scheduling + the encode-once Δcut stream (repro.serve.delta_path).

Reported per (B, overlap):
  * bytes/client on the shared-payload wire vs the legacy per-client
    unicast accounting (recovered exactly as sync_bytes + dedup_bytes_saved
    — no second run needed). NOTE: B=1 / fully disjoint rows legitimately
    show small NEGATIVE savings — the shared stream carries explicit union
    ids (2 B/row) the unicast format leaves implicit; sharing by ≥2 clients
    always wins;
  * unique vs total Δ Gaussians per sync (the dedup ratio itself);
  * fleet sync latency (host wall-clock; the only per-sync host await is
    the pooled scheduler's bucket-size scalar).

The headline: for overlapping viewers, downlink bytes and encode work grow
with the fleet's UNIQUE Gaussians — sub-linear in B — while the legacy
accounting grows linearly.

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene, fewer
syncs, same (B, overlap) grid → every row is still present in
BENCH_fleet_sync.json).
"""

import os
import time

import numpy as np

from benchmarks.common import city_scene, emit, rigs_along_walk
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc

FOCAL, TAU = 260.0, 48.0
BATCHES = (1, 4, 16, 64)
OVERLAPS = (0.0, 0.5, 0.9)


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


def _walk(syncs: int, seed: int, extent) -> np.ndarray:
    rigs = rigs_along_walk(syncs, extent=extent, focal=FOCAL, seed=seed)
    return np.stack([np.asarray(r.left.pos, np.float32) for r in rigs])


def _fleet_walk(n_clients: int, syncs: int, overlap: float,
                extent) -> np.ndarray:
    """(syncs, B, 3) — everyone follows ONE (slow, headset-realistic) walk;
    client b's copy is displaced toward its own anchor sampled INSIDE the
    city interior, scaled by (1 - overlap): ov=1 is fully co-located, ov=0 a
    fleet spread across the whole scene (per-anchor cuts diverge strongly —
    the disjoint baseline). Anchors must stay inside the scene: a camera
    outside it degenerates to the same coarse global cut and the overlap
    axis stops discriminating."""
    shared = _walk(syncs, seed=0, extent=extent)
    rng = np.random.default_rng(17)
    lo = np.asarray([0.15 * extent[0], 0.15 * extent[1], 0.0], np.float32)
    hi = np.asarray([0.85 * extent[0], 0.85 * extent[1], 0.0], np.float32)
    anchors = rng.uniform(lo, hi, (n_clients, 3)).astype(np.float32)
    offs = (anchors - shared[0]) * (1.0 - overlap)
    offs[:, 2] = 0.0
    return (shared[:, None, :] + offs[None, :, :]).astype(np.float32)


def run():
    scale = "small" if _smoke() else "medium"
    syncs = 5 if _smoke() else 12
    _cfg, _leaves, tree = city_scene(scale)
    m = tree.meta
    hi = np.asarray(tree.gaussians.mu).max(axis=0)
    extent = (float(hi[0]), float(hi[1]))
    cfg = SessionConfig(tau=TAU, cut_budget=16384)
    emit("fleet_sync/scene", 0.0,
         f"scale={scale} nodes={m.n_real} subtrees={m.Ns} slab={m.S} "
         f"extent={extent[0]:.0f}x{extent[1]:.0f}m syncs={syncs}")

    for b in BATCHES:
        for ov in OVERLAPS:
            walks = _fleet_walk(b, syncs, ov, extent)
            service = svc.LodService(tree, cfg, b, focal=FOCAL,
                                     mode="pooled", dedup=True)
            t0 = time.perf_counter()
            first = service.sync(walks[0])
            np.asarray(first.sync_bytes)  # force the first (compile) sync
            t_first = time.perf_counter() - t0

            times, rows = [], []
            for f in range(1, syncs):
                t0 = time.perf_counter()
                stats = service.sync(walks[f])
                np.asarray(stats.sync_bytes)  # wall-clock incl. device work
                times.append(time.perf_counter() - t0)
                rows.append(stats)

            key = f"fleet_sync/b{b}/ov{int(ov * 100):02d}"
            dedup_b = np.stack([np.asarray(s.sync_bytes) for s in rows])
            saved_b = np.stack([np.asarray(s.dedup_bytes_saved) for s in rows])
            unicast_b = dedup_b + saved_b
            tot = sum(int(np.asarray(s.delta_size).sum()) for s in rows) \
                + int(np.asarray(first.delta_size).sum())
            uniq = sum(int(np.asarray(s.unique_delta).sum()) for s in rows) \
                + int(np.asarray(first.unique_delta).sum())
            emit(f"{key}/sync_us", float(np.median(times) * 1e6),
                 f"per_client={np.median(times)*1e6/b:.0f}us "
                 f"t_first={t_first*1e3:.0f}ms")
            emit(f"{key}/bytes_per_client", float(dedup_b.mean()),
                 f"steady_dedup={dedup_b.mean()/1024:.2f}KiB "
                 f"unicast={unicast_b.mean()/1024:.2f}KiB "
                 f"first_dedup={np.asarray(first.sync_bytes).mean()/1024:.1f}KiB")
            emit(f"{key}/unique_vs_total_delta", 0.0,
                 f"unique={uniq} total={tot} "
                 f"ratio={uniq / max(tot, 1):.3f}")
            emit(f"{key}/fleet_bytes_saved", 0.0,
                 f"session_total={float(saved_b.sum() + np.asarray(first.dedup_bytes_saved).sum())/1024:.1f}KiB")
    emit("fleet_sync/summary", 0.0,
         "encode-once delta path: fleet downlink and codec work follow "
         "UNIQUE Gaussians per sync, not client count")


if __name__ == "__main__":
    run()
