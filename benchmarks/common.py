"""Shared benchmark scaffolding: scene/session setup + CSV emission."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Tuple

import numpy as np

from repro.core.camera import StereoRig, TrajectoryConfig, make_camera, walk_trajectory
from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def timeit(fn: Callable, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall time (µs); blocks on jax outputs."""
    import jax
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


_SCENES = {}


def city_scene(scale: str = "medium"):
    """(leaves, tree) — cached per scale. 'paper' scales are documented in
    EXPERIMENTS.md; CPU benches default to medium."""
    if scale not in _SCENES:
        cfgs = {
            "small": CityConfig(blocks_x=2, blocks_y=2, leaf_density=0.10, seed=2),
            "medium": CityConfig(blocks_x=4, blocks_y=4, leaf_density=0.25, seed=2),
            "large": CityConfig(blocks_x=8, blocks_y=8, leaf_density=0.5, seed=2),
        }
        cfg = cfgs[scale]
        leaves = generate_city(cfg)
        tree = build_lod_tree(leaves, target_subtrees=64 if scale != "small" else 16,
                              seed=0)
        _SCENES[scale] = (cfg, leaves, tree)
    return _SCENES[scale]


def vr_rig(width=160, height=96, focal=260.0) -> StereoRig:
    cam = make_camera([40, 40, 1.7], [90, 90, 1.5], focal_px=focal,
                      width=width, height=height, near=0.25)
    return StereoRig(left=cam, baseline=0.06)


def rigs_along_walk(n: int, extent=(200.0, 200.0), width=160, height=96,
                    focal=260.0, seed=0):
    import dataclasses as dc
    out = []
    for cam in walk_trajectory(TrajectoryConfig(seed=seed), n, extent,
                               focal_px=focal, width=width, height=height):
        out.append(StereoRig(left=dc.replace(cam, near=0.25), baseline=0.06))
    return out
