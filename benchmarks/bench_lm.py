"""Framework-side: tiny-LM train throughput + serving throughput on CPU
(the TPU numbers are the §Roofline dry-run terms)."""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import ARCHS
from repro.models.config import reduced
from repro.models.model_zoo import get_model
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def run():
    cfg = reduced(ARCHS["qwen2.5-3b"], n_layers=4, d_model=256, d_ff=512,
                  vocab=2048, n_heads=8, n_kv_heads=4, head_dim=32)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ocfg = opt.OptimizerConfig()
    ostate = opt.init(params)
    step = jax.jit(make_train_step(model, ocfg))
    rng = np.random.default_rng(0)
    b, s = 8, 256
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    t = timeit(lambda: step(params, ostate, None, batch)[3]["loss"], repeats=3)
    toks = b * s
    emit("lm/train_step_us", t, f"{toks/t*1e6:.0f} tok/s (CPU, tiny cfg)")

    cache = model.make_cache(b, 128)
    dstep = jax.jit(lambda p, c, bb: model.decode_step(p, c, bb))
    tok = jnp.zeros((b,), jnp.int32)
    t = timeit(lambda: dstep(params, cache, {"token": tok})[0], repeats=3)
    emit("lm/decode_step_us", t, f"{b/t*1e6:.0f} tok/s decode")


if __name__ == "__main__":
    run()
