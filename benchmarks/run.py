"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV rows.

    python -m benchmarks.run [--only bench_fleet_sync ...] [--json PATH]

``--only`` restricts the run to the named modules (short names accepted);
``--json PATH`` additionally writes every emitted row to a machine-readable
trajectory file (the ``BENCH_<name>.json`` convention — CI emits
``BENCH_fleet_sync.json`` each run so the perf trajectory accumulates).
"""

import argparse
import json
import platform
import sys
import traceback

from benchmarks import common

MODULES = [
    "benchmarks.bench_memory",       # Figs. 2/6
    "benchmarks.bench_lod_search",   # Figs. 7/20
    "benchmarks.bench_multiclient",  # multi-user cloud serving (ROADMAP)
    "benchmarks.bench_fleet_sync",   # encode-once fleet sync (dedup × B)
    "benchmarks.bench_fleet_churn",  # ragged fleet lifecycle (admit/evict)
    "benchmarks.bench_fleet_recovery",  # snapshot/restore + journal replay
    "benchmarks.bench_fleet_shard",  # mesh-sharded fleet (clients × slabs)
    "benchmarks.bench_delta_stream",  # paged Δ stream (pressure × tier)
    "benchmarks.bench_mtp",          # deadline scheduler vs lockstep MTP
    "benchmarks.bench_bandwidth",    # Figs. 5/17(bw)/24
    "benchmarks.bench_stereo",       # Figs. 8/21
    "benchmarks.bench_stereo_batched",  # fleet-batched client rendering
    "benchmarks.bench_quality",      # Figs. 16/17(quality)
    "benchmarks.bench_e2e",          # Figs. 18/19/22
    "benchmarks.bench_tile_size",    # Figs. 23/25
    "benchmarks.bench_kernels",      # per-kernel sweeps
    "benchmarks.bench_lm",           # framework LM throughput
]


def _select(only):
    if not only:
        return list(MODULES)
    picked = []
    for name in only:
        matches = [m for m in MODULES
                   if m == name or m.split(".")[-1] == name]
        if not matches:
            raise SystemExit(f"unknown benchmark module: {name!r} "
                             f"(choose from {[m.split('.')[-1] for m in MODULES]})")
        picked.extend(matches)
    return picked


def write_json(path: str, modules, failed) -> None:
    """Write the collected rows as one trajectory point."""
    doc = {
        "schema": "nebula-bench-rows/1",
        "platform": platform.platform(),
        "python": platform.python_version(),
        "modules": modules,
        "failed": failed,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for (n, us, d) in common.ROWS],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {len(common.ROWS)} rows -> {path}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", metavar="MODULE",
                    help="run only this module (repeatable; short name ok)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write emitted rows to PATH as JSON")
    args = ap.parse_args(argv)
    modules = _select(args.only)

    print("name,us_per_call,derived")
    failed = []
    for mod_name in modules:
        print(f"# --- {mod_name} ---", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if args.json:
        write_json(args.json, modules, failed)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
