"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV rows."""

import sys
import traceback

MODULES = [
    "benchmarks.bench_memory",       # Figs. 2/6
    "benchmarks.bench_lod_search",   # Figs. 7/20
    "benchmarks.bench_multiclient",  # multi-user cloud serving (ROADMAP)
    "benchmarks.bench_bandwidth",    # Figs. 5/17(bw)/24
    "benchmarks.bench_stereo",       # Figs. 8/21
    "benchmarks.bench_stereo_batched",  # fleet-batched client rendering
    "benchmarks.bench_quality",      # Figs. 16/17(quality)
    "benchmarks.bench_e2e",          # Figs. 18/19/22
    "benchmarks.bench_tile_size",    # Figs. 23/25
    "benchmarks.bench_kernels",      # per-kernel sweeps
    "benchmarks.bench_lm",           # framework LM throughput
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        print(f"# --- {mod_name} ---", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
