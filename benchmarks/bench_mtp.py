"""Motion-to-photon latency: deadline scheduler vs lockstep baseline.

The paper's headline serving claim is a 2.7× motion-to-photon speedup from
not making every client wait on the whole fleet. This bench prices that on
a STRAGGLER-LADEN fleet: most clients are tight-deadline headsets with
bursty head motion; a few are stragglers that teleport across the city
every few frames, forcing near-full slab resweeps. Under lockstep `sync()`
every frame that contains a straggler teleport is slow for EVERYONE; the
deadline scheduler (`repro.serve.scheduler`) gives stragglers loose
deadlines, so their expensive resweeps run in their own ticks while the
tight-deadline majority keeps syncing in small fast ticks.

Swept axes (ISSUE: arrival rate × motion burstiness × bandwidth tier):

  * motion arrival rate — per-frame Poisson intensity of head-pose
    deliveries per normal client (sparser arrivals → idle clients the
    scheduler can skip, lockstep cannot);
  * motion burstiness — probability of a saccade (large jump) per
    delivered pose (`scheduler.bursty_motion_path`);
  * bandwidth tier — uncontrolled vs a `BANDWIDTH_TIERS` preset driving
    the closed-loop rate controller under the scheduler.

Per row, BOTH modes replay the IDENTICAL motion schedule (same rng seed)
and report p50/p99 motion-to-photon latency (motion delivery → completion
of the sync that served it, wall clock) and the deadline-miss rate.
Deadlines are calibrated from a measured warm lockstep tick so the rows
are machine-independent: tight = 3×, straggler = 60× the warm tick.

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene, fewer
frames, one rate×burst×tier row — the lockstep-vs-deadline p99 comparison
still lands in BENCH_mtp.json).
"""

import os
import time

import numpy as np

from benchmarks.common import city_scene, emit
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc
from repro.serve.scheduler import (DeadlineScheduler, bursty_motion_path,
                                   straggler_path)

FOCAL, TAU = 260.0, 48.0


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


def _motion_schedule(rng, n_normal, n_straggler, frames, rate, burst,
                     extent):
    """frames × clients motion deliveries (None = no pose this frame).
    Normals: Poisson(rate)-thinned bursty walks; stragglers: teleporting
    paths delivered every frame (they are head-tracked too — just mostly
    still between teleports)."""
    n = n_normal + n_straggler
    paths = []
    for i in range(n_normal):
        paths.append(bursty_motion_path(
            rng, frames, speed=0.8, burst_prob=burst, burst_scale=12.0,
            start=rng.uniform(-extent / 4, extent / 4, 3)))
    for i in range(n_straggler):
        paths.append(straggler_path(rng, frames, teleport_every=4,
                                    extent=extent))
    deliver = np.ones((frames, n), bool)
    deliver[:, :n_normal] = rng.poisson(rate, (frames, n_normal)) > 0
    return paths, deliver


def _build(tree, cfg, n, tier):
    return svc.LodService(tree, cfg, n, focal=FOCAL, mode="pooled",
                          dedup=True, bandwidth=tier)


def _run_lockstep(tree, cfg, n, tier, paths, deliver):
    """Lockstep baseline with the scheduler's MTP bookkeeping: every frame
    syncs EVERY live client; a client's sample is its oldest undelivered
    pose → sync completion."""
    service = _build(tree, cfg, n, tier)
    ids = service.active_ids
    oldest = {c: None for c in ids}
    cams = {c: np.asarray(paths[i][0], np.float32)
            for i, c in enumerate(ids)}
    samples = []
    service.sync(cams)  # warm/compile sync outside the measured window
    for f in range(deliver.shape[0]):
        now = time.monotonic()
        moved = False
        for i, c in enumerate(ids):
            if deliver[f, i]:
                cams[c] = np.asarray(paths[i][f], np.float32)
                if oldest[c] is None:
                    oldest[c] = now
                moved = True
        if not moved:
            continue
        stats = service.sync(cams)
        np.asarray(stats.sync_bytes)  # block: completion = photon time
        done = time.monotonic()
        for c in ids:
            if oldest[c] is not None:
                samples.append((done - oldest[c]) * 1e3)
                oldest[c] = None
    return np.asarray(samples)


def _run_deadline(tree, cfg, n_normal, n_straggler, tier, paths, deliver,
                  tight_ms, loose_ms, budget_ms):
    service = _build(tree, cfg, n_normal + n_straggler, tier)
    ids = service.active_ids
    sched = DeadlineScheduler(service, default_deadline_ms=tight_ms,
                              tick_budget_ms=budget_ms)
    for i, c in enumerate(ids):
        sched.set_deadline(c, loose_ms if i >= n_normal else tight_ms)
        sched.observe_motion(c, paths[i][0])
    sched.tick()  # warm/compile tick outside the measured window
    sched._mtp_samples.clear()
    for f in range(deliver.shape[0]):
        for i, c in enumerate(ids):
            if deliver[f, i]:
                sched.observe_motion(c, paths[i][f])
        sched.tick()
    # drain: motion the budget deferred still gets served (and counted)
    for _ in range(16):
        if sched.tick() is None:
            break
    mtp = np.asarray([s[0] for s in sched._mtp_samples])
    miss = np.asarray([s[1] for s in sched._mtp_samples], bool)
    return mtp, miss, sched


def run():
    scale = "small" if _smoke() else "medium"
    frames = 40 if _smoke() else 80
    n_normal, n_straggler = (5, 2) if _smoke() else (9, 3)
    rates = (1.0,) if _smoke() else (0.4, 1.0)
    bursts = (0.2,) if _smoke() else (0.0, 0.3)
    tiers = (None,) if _smoke() else (None, "headset")
    _cfg, _leaves, tree = city_scene(scale)
    hi = np.asarray(tree.gaussians.mu).max(axis=0)
    extent = float(max(hi[0], hi[1]))
    cfg = SessionConfig(tau=TAU, cut_budget=4096)
    n = n_normal + n_straggler
    emit("mtp/scene", 0.0,
         f"scale={scale} B={n} stragglers={n_straggler} frames={frames}")

    # calibrate deadlines off a measured warm lockstep tick: machine-
    # independent rows, and the scheduler is never handed a deadline the
    # hardware could not hold even for an empty fleet
    calib = _build(tree, cfg, n, None)
    walk = np.asarray(bursty_motion_path(np.random.default_rng(9), 4))
    calib.sync(np.tile(walk[0], (n, 1)))
    ts = []
    for i in range(1, 4):
        t0 = time.monotonic()
        np.asarray(calib.sync(np.tile(walk[i], (n, 1))).sync_bytes)
        ts.append(time.monotonic() - t0)
    warm_ms = float(np.median(ts) * 1e3)
    tight_ms, loose_ms = 3.0 * warm_ms, 60.0 * warm_ms
    budget_ms = 2.0 * warm_ms
    del calib
    emit("mtp/calibration", warm_ms * 1e3,
         f"warm_tick={warm_ms:.2f}ms tight={tight_ms:.1f}ms "
         f"loose={loose_ms:.1f}ms")

    for rate in rates:
        for burst in bursts:
            for tier in tiers:
                rng = np.random.default_rng(11)
                paths, deliver = _motion_schedule(
                    rng, n_normal, n_straggler, frames, rate, burst, extent)
                lock = _run_lockstep(tree, cfg, n, tier, paths, deliver)
                mtp, miss, sched = _run_deadline(
                    tree, cfg, n_normal, n_straggler, tier, paths, deliver,
                    tight_ms, loose_ms, budget_ms)
                tname = tier if isinstance(tier, str) else "uncapped"
                key = f"mtp/r{int(rate * 100):03d}/bst{int(burst * 100):03d}/{tname}"
                lp50, lp99 = (float(np.percentile(lock, 50)),
                              float(np.percentile(lock, 99)))
                dp50, dp99 = (float(np.percentile(mtp, 50)),
                              float(np.percentile(mtp, 99)))
                emit(f"{key}/lockstep", lp99 * 1e3,
                     f"p50={lp50:.2f}ms p99={lp99:.2f}ms n={lock.size}")
                emit(f"{key}/deadline", dp99 * 1e3,
                     f"p50={dp50:.2f}ms p99={dp99:.2f}ms "
                     f"miss={float(miss.mean()) * 100:.1f}% n={mtp.size}")
                emit(f"{key}/p99_speedup", 0.0,
                     f"lockstep_p99/deadline_p99={lp99 / max(dp99, 1e-9):.2f}x "
                     f"cost_model=a{sched.cost.alpha:.2f}+b{sched.cost.beta:.4f}")
    emit("mtp/summary", 0.0,
         "deadline scheduler: straggler resweeps leave the tight-deadline "
         "majority's ticks, p99 MTP drops below the lockstep baseline")


if __name__ == "__main__":
    run()
