"""Multi-client LoD serving: per-client cost as the fleet grows.

Sweeps B ∈ {1, 4, 16, 64} concurrent headsets on staggered copies of one
city walk (a "tour group": heavy temporal+spatial correlation, the regime
the cloud actually serves) and reports, per client: downlink sync bytes,
LoD-search nodes touched, and the pooled scheduler's sweep pool occupancy.
The headline: with cross-client pooling, cloud work scales with TOTAL fleet
staleness (stale slab pairs), not with B — the multi-user analog of the
paper's temporal-reuse figures."""

import time

import numpy as np

from benchmarks.common import city_scene, emit, rigs_along_walk
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc

FOCAL, TAU = 260.0, 48.0
SYNCS = 24
BATCHES = (1, 4, 16, 64)


def _fleet_walk(n_clients: int, syncs: int) -> np.ndarray:
    """(syncs, B, 3) — client b follows the shared walk b steps behind."""
    rigs = rigs_along_walk(syncs + n_clients, extent=(200.0, 200.0),
                           focal=FOCAL)
    poses = np.stack([np.asarray(r.left.pos, np.float32) for r in rigs])
    return np.stack([poses[f + np.arange(n_clients)] for f in range(syncs)])


def run():
    _cfg, _leaves, tree = city_scene("medium")
    m = tree.meta
    cfg = SessionConfig(tau=TAU, cut_budget=16384)
    emit("multiclient/scene", 0.0,
         f"nodes={m.n_real} subtrees={m.Ns} slab={m.S}")

    for b in BATCHES:
        walks = _fleet_walk(b, SYNCS)
        # dedup=False keeps this module's rows comparable to its PR-1
        # baseline (unicast accounting, no per-sync codec dispatch); the
        # encode-once path has its own sweep in bench_fleet_sync.py
        service = svc.LodService(tree, cfg, b, focal=FOCAL, mode="pooled",
                                 dedup=False)
        # warm-up sync (full sweep for every client) + jit compilation
        t0 = time.perf_counter()
        first = service.sync(walks[0])
        t_first = time.perf_counter() - t0

        times, per_bytes, per_nodes, per_resweeps = [], [], [], []
        for f in range(1, SYNCS):
            t0 = time.perf_counter()
            stats = service.sync(walks[f])
            times.append(time.perf_counter() - t0)
            per_bytes.append(np.asarray(stats.sync_bytes))
            per_nodes.append(np.asarray(stats.nodes_touched))
            per_resweeps.append(np.asarray(stats.resweeps))

        per_bytes = np.stack(per_bytes)       # (syncs-1, B)
        per_nodes = np.stack(per_nodes)
        pool = np.stack(per_resweeps).sum(axis=1)  # stale pairs per sync
        steady = per_bytes[2:]
        emit(f"multiclient/b{b}/sync_us_per_client",
             float(np.median(times) * 1e6 / b),
             f"fleet_sync={np.median(times)*1e6:.0f}us "
             f"t_first={t_first*1e3:.0f}ms")
        emit(f"multiclient/b{b}/sync_bytes_per_client", 0.0,
             f"first={np.asarray(first.sync_bytes).mean()/1024:.1f}KiB "
             f"steady={steady.mean()/1024:.2f}KiB")
        emit(f"multiclient/b{b}/nodes_touched_per_client", 0.0,
             f"mean={per_nodes.mean():.0f} of {m.T + m.Ns * m.S} "
             f"({per_nodes.mean()/(m.T + m.Ns*m.S)*100:.1f}%)")
        emit(f"multiclient/b{b}/pool", 0.0,
             f"stale_pairs/sync={pool.mean():.1f} of {b * m.Ns} "
             f"({pool.mean()/(b*m.Ns)*100:.1f}%)")
    emit("multiclient/summary", 0.0,
         "pooled scheduler: sweep work follows total fleet staleness, "
         "not client count")


if __name__ == "__main__":
    run()
