"""Figs. 23/25 analog: tile-size sensitivity + RU scaling model."""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np

from benchmarks.common import city_scene, emit, timeit, vr_rig
from repro.core import lod_search as ls
from repro.core.pipeline import render_stereo


def run():
    _cfg, leaves, tree = city_scene("medium")
    rig = vr_rig()
    cut, _ = ls.full_search(tree, np.asarray(rig.left.pos),
                            jnp.float32(rig.left.focal), jnp.float32(48.0))
    gids, _c, _ = ls.cut_gids(cut, tree, budget=16384)
    q = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    q = dc.replace(q, opacity=jnp.where(gids >= 0, q.opacity, 0.0))

    # tile-size sensitivity (Fig. 25)
    for tile in (8, 16, 32):
        t = timeit(lambda tl=tile: render_stereo(q, rig, tile=tl, list_len=384,
                                                 max_pairs=1 << 17)[:2],
                   repeats=2)
        emit(f"tile/stereo_tile{tile}", t, "")

    # RU scaling model (Fig. 23): work per tile / RUs, 1 GHz RTL-class model
    il, ir, (splats, ll, rl, st) = render_stereo(q, rig, tile=16, list_len=384,
                                                 max_pairs=1 << 17)
    blends = st.left_blends + st.right_candidates
    px_per_tile = 16 * 16
    # scale measured blend counts to VR per-eye resolution (2064×2208)
    scale = (2064 * 2208) / (rig.left.width * rig.left.height)
    for rus in (64, 128, 256, 512):
        # each RU handles one pixel-blend per cycle @1GHz (GSCore-class)
        cycles = blends * scale * px_per_tile / rus
        fps = 1e9 / max(cycles, 1)
        emit(f"ru/fps_at_{rus}RU", 0.0,
             f"{fps:.0f}fps modeled at VR res "
             f"({'meets' if fps >= 90 else 'below'} 90fps; paper Fig. 23)")


if __name__ == "__main__":
    run()
