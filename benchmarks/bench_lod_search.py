"""Fig. 20 analog: LoD search — full traversal vs fully-streaming vs
temporal-aware. Reports wall time AND nodes-touched (the architecture-neutral
work metric; the paper's 52.7× is a GPU wall-clock number)."""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import city_scene, emit, rigs_along_walk, timeit
from repro.core import lod_search as ls

FOCAL, TAU = 1400.0, 48.0


def run():
    _cfg, leaves, tree = city_scene("large")
    m = tree.meta
    rigs = rigs_along_walk(96, extent=(200.0, 200.0))
    poses = [np.asarray(r.left.pos) for r in rigs]

    # baseline: brute-force reference (numpy level iteration = OctreeGS-style
    # full traversal; counts all real nodes)
    t_ref = timeit(lambda: ls.reference_search_np(tree, poses[0], FOCAL, TAU),
                   repeats=3)
    emit("lod/full_traversal_np", t_ref, f"nodes={m.n_real}")

    # fully-streaming initial frame (ours)
    f = jnp.float32(FOCAL)
    tau = jnp.float32(TAU)
    t_full = timeit(lambda: ls.full_search(tree, poses[0], f, tau))
    emit("lod/streaming_full", t_full, f"nodes={m.T + m.Ns * m.S}")

    # temporal-aware across the walk (hybrid: real skipping)
    cut, state = ls.full_search(tree, poses[0], f, tau)
    touched, times = [], []
    for p in poses[1:]:
        import time
        t0 = time.perf_counter()
        cut, state = ls.temporal_search_hybrid(tree, state, p, FOCAL, TAU)
        times.append(time.perf_counter() - t0)
        touched.append(int(cut.nodes_touched))
    emit("lod/temporal_aware", float(np.median(times) * 1e6),
         f"nodes_touched={np.mean(touched):.0f}")
    emit("lod/speedup_nodes", 0.0,
         f"{(m.T + m.Ns * m.S) / max(np.mean(touched), 1):.1f}x fewer nodes")
    emit("lod/speedup_walltime", 0.0,
         f"{t_full / max(np.median(times) * 1e6, 1e-9):.1f}x vs streaming-full "
         f"(CPU dispatch floor ~= sweep cost at this scale; the nodes-touched "
         f"ratio is the transferable metric — paper's 52.7x is memory-bound GPU)")

    # temporal similarity (Fig. 7 analog): consecutive-cut overlap
    cut, state = ls.full_search(tree, poses[0], f, tau)
    prev = np.asarray(cut.mask(tree))
    overlaps = []
    for p in poses[1:33]:
        cut, state = ls.temporal_search(tree, state, p, f, tau)
        cur = np.asarray(cut.mask(tree))
        inter = (prev & cur).sum()
        union = max(prev.sum(), 1)
        overlaps.append(inter / union)
        prev = cur
    emit("lod/temporal_similarity", 0.0,
         f"mean_overlap={np.mean(overlaps)*100:.2f}%")


if __name__ == "__main__":
    run()
