"""Figs. 5/17/19/24 analog: bandwidth — Nebula Δcut streaming vs H.265 video.

Sweeps resolution (Fig. 5), frame interval w (Fig. 24), and reports the
steady-state bandwidth ratio (the paper's headline 19-25%-of-video /
'1925%' claim)."""

import numpy as np

from benchmarks.common import city_scene, emit, rigs_along_walk
from repro.core.pipeline import CollaborativeSession, SessionConfig
from repro.core.video_model import (H265_BPP, StreamConfig, nebula_bandwidth_bps,
                                    video_bandwidth_bps)

VR_RES = (2064, 2208)
FPS = 90.0


def _steady_state_sync_bytes(w: int, n_frames: int = 120):
    _cfg, _leaves, tree = city_scene("medium")
    rigs = rigs_along_walk(n_frames, extent=(200.0, 200.0))
    sess = CollaborativeSession(tree, SessionConfig(tau=48.0, w=w, w_star=32,
                                                    cut_budget=16384), rigs[0])
    per_sync, churn = [], []
    for i, rig in enumerate(rigs):
        stats, _ = sess.step(rig, render=False)
        if stats.synced and i > n_frames // 3:   # steady state only
            per_sync.append(stats.sync_bytes)
            churn.append(stats.delta_size / max(stats.cut_size, 1))
    return float(np.mean(per_sync)), float(np.mean(churn))


def run():
    # resolution sweep (Fig. 5): Nebula traffic is resolution-independent
    sync_bytes, churn = _steady_state_sync_bytes(w=4)
    for w_px, h_px, tag in [(960, 1080, "1080p-eye"), VR_RES + ("quest3-eye",),
                            (2880, 2880, "4k-eye")]:
        for preset in ("lossy-L", "lossy-H", "lossless"):
            v = video_bandwidth_bps(StreamConfig(w_px, h_px, FPS, preset))
            emit(f"bw/video_{tag}_{preset}", 0.0, f"{v/1e6:.0f}Mbps")
    nb = nebula_bandwidth_bps(sync_bytes, w=4, fps=FPS)
    emit("bw/nebula", 0.0, f"{nb/1e6:.1f}Mbps (resolution-independent)")
    ref = video_bandwidth_bps(StreamConfig(*VR_RES, FPS, "lossy-H"))
    emit("bw/nebula_vs_lossyH", 0.0,
         f"{nb/ref*100:.1f}% of video (small test scene; see paperscale row)")
    # paper-scale projection: HierGS-class cut (~2M gaussians) with OUR
    # measured per-sync churn fraction and codec bytes/gaussian
    cut_paper = 2e6
    bytes_per_sync = cut_paper * churn * 30.0
    nb_p = nebula_bandwidth_bps(bytes_per_sync, w=4, fps=FPS)
    emit("bw/nebula_paperscale", 0.0,
         f"{nb_p/1e6:.0f}Mbps = {nb_p/ref*100:.0f}% of video at 2M-gaussian "
         f"cut, churn={churn*100:.2f}%/sync (paper: 19-25%)")

    # frame-interval sensitivity (Fig. 24)
    for w in (1, 2, 4, 8, 16):
        sb, _ = _steady_state_sync_bytes(w=w, n_frames=96)
        nbw = nebula_bandwidth_bps(sb, w=w, fps=FPS)
        emit(f"bw/nebula_w{w}", 0.0, f"{nbw/1e6:.2f}Mbps")


if __name__ == "__main__":
    run()
