"""Figs. 2/6 analog: memory demand by scene scale and by pipeline stage
(Gaussian counts as the proxy, as in the paper)."""

import numpy as np

from benchmarks.common import city_scene, emit, vr_rig
from repro.core import lod_search as ls
from repro.core.gaussians import bytes_per_gaussian
import jax.numpy as jnp


def run():
    rig = vr_rig()
    for scale in ("small", "medium", "large"):
        _cfg, leaves, tree = city_scene(scale)
        bpg = bytes_per_gaussian(leaves.sh_degree)
        emit(f"mem/scene_{scale}", 0.0,
             f"{tree.meta.n_real} nodes = {tree.meta.n_real*bpg/1e6:.1f}MB raw")

    _cfg, leaves, tree = city_scene("medium")
    bpg = bytes_per_gaussian(leaves.sh_degree)
    cut, _ = ls.full_search(tree, np.asarray(rig.left.pos),
                            jnp.float32(rig.left.focal), jnp.float32(48.0))
    n_cut = int(cut.count())
    # stage demand (Fig. 6): LoD search touches the tree; later stages only
    # the cut — this gap is what makes the cloud/client split possible
    emit("mem/stage_lod_search", 0.0,
         f"{tree.meta.n_real} gaussians ({tree.meta.n_real*bpg/1e6:.1f}MB)")
    for stage in ("preprocess", "sort", "raster"):
        emit(f"mem/stage_{stage}", 0.0, f"{n_cut} gaussians ({n_cut*bpg/1e6:.2f}MB)")
    emit("mem/stage_ratio", 0.0,
         f"LoD/{'raster'}={tree.meta.n_real/max(n_cut,1):.1f}x")


if __name__ == "__main__":
    run()
