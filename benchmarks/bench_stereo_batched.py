"""Fleet-batched stereo rendering: per-client frame cost vs a sequential
per-client loop (ROADMAP "client-side Pallas stereo batching").

Sweeps B ∈ {1, 4, 16} cloud-rendered fallback clients sharing one scene cut,
each with its own rig along a city walk. The batched path is
`repro.render.batched_render_stereo` (the whole project→bin→merge→rasterize
chain on a leading client axis, bit-identical per client to the sequential
loop — proven in tests/test_render_batched.py); the baseline calls the
single-client pipeline B times. Headline: per-client stereo frame cost DROPS
monotonically from B=1 to B=16 — per-op dispatch overhead and the many small
tile-scan ops amortize across the fleet. The `jit` rows additionally fuse the
whole fleet into one XLA program (fastest, allclose rather than bitwise)."""

import dataclasses as dc

import numpy as np

import jax.numpy as jnp

from benchmarks.common import city_scene, emit, rigs_along_walk, timeit
from repro import render as rnd
from repro.core import lod_search as ls

FOCAL, TAU = 260.0, 96.0
BATCHES = (1, 4, 16)
WIDTH, HEIGHT = 64, 48
LIST_LEN = 64
MAX_PAIRS = 1 << 14


def _fleet():
    _cfg, _leaves, tree = city_scene("small")
    rigs = rigs_along_walk(max(BATCHES), extent=(100.0, 100.0), width=WIDTH,
                           height=HEIGHT, focal=FOCAL)
    # one shared cut (the fleet serves one neighborhood); per-client rigs
    cut, _ = ls.full_search(tree, np.asarray(rigs[0].left.pos),
                            jnp.float32(FOCAL), jnp.float32(TAU))
    gids, cnt, _ = ls.cut_gids(cut, tree, budget=1024)
    q = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    q = dc.replace(q, opacity=jnp.where(gids >= 0, q.opacity, 0.0))
    return q, rigs, int(cnt)


def run():
    queue, rigs_all, n = _fleet()
    emit("stereo_batched/queue_size", 0.0,
         f"{n} gaussians {WIDTH}x{HEIGHT}")

    for b in BATCHES:
        rigs = rigs_all[:b]
        cfg = rnd.RenderConfig.for_fleet(rigs, tile=16, list_len=LIST_LEN,
                                         max_pairs=MAX_PAIRS)
        queues = rnd.stack_pytrees([queue] * b)
        stacked = rnd.stack_rigs(rigs)

        t_batched = timeit(lambda: rnd.batched_render_stereo(
            queues, stacked, cfg, path="vmap")[:2], repeats=5)
        t_jit = timeit(lambda: rnd.batched_render_stereo(
            queues, stacked, cfg, path="vmap", jit=True)[:2], repeats=5)

        def sequential():
            outs = []
            for i in range(b):
                plan = rnd.build_plan(queues[i], rigs[i], cfg)
                outs.append(rnd.render_stereo(plan, cfg)[:2])
            return outs

        t_seq = timeit(sequential, repeats=5)
        emit(f"stereo_batched/b{b}/frame_us_per_client", t_batched / b,
             f"fleet={t_batched:.0f}us sequential_per_client={t_seq / b:.0f}us "
             f"speedup={t_seq / t_batched:.2f}x")
        emit(f"stereo_batched/b{b}/frame_us_per_client_jit", t_jit / b,
             f"whole-fleet jit (allclose, not bitwise) "
             f"speedup_vs_seq={t_seq / t_jit:.2f}x")


if __name__ == "__main__":
    run()
