"""Ragged fleet lifecycle: per-sync cost under churn tracks ACTIVE clients,
not slot capacity.

Two experiments on the pooled production scheduler (dedup on):

  1. **Churn steady state** — a fixed pow2 capacity (64; smoke: 8) holding
     n_active ∈ {1, 4, 16, 64} live clients; every sync first recycles ~20%
     of the fleet (evict + admit — each admitted client syncs cold next
     round) before all live clients move. Reported per n_active:
       * steady per-sync wall time at the BIG capacity vs the same fleet in
         a right-sized capacity-n_active service (the "capacity tax");
       * the churn-op overhead itself (admit+evict wall time per sync —
         jitted slot scatters, no retraces inside the bucket).
  2. **Growth trajectory** — one service admits its way 1 → capacity through
     every pow2 bucket; per bucket we report the first-sync (retrace) cost
     vs the steady in-bucket sync cost — the "exactly one recompile per
     growth" contract priced in wall-clock.

The headline: in-bucket admits/evicts are recompile-free and cost
microseconds, the pooled sweep tracks the ACTIVE fleet's staleness (an
almost-empty big-capacity service syncs almost as fast as a small one), and
capacity growth is a bounded, per-bucket one-off.

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene, capacity 8,
fewer syncs → every row still present in BENCH_fleet_churn.json).
"""

import os
import time

import numpy as np

from benchmarks.common import city_scene, emit
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc

FOCAL, TAU = 260.0, 48.0
CHURN = 0.2  # fraction of the live fleet recycled per sync


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


def _force(stats) -> None:
    np.asarray(stats.sync_bytes)


class _FleetWalk:
    """Headset-realistic camera state: every live client random-walks from a
    persistent position (teleporting the whole fleet per sync would re-cold
    every cut and benchmark the codec compile cache instead)."""

    def __init__(self, rng, extent, step=3.0):
        self.rng = rng
        self.lo = np.asarray([0.15 * extent[0], 0.15 * extent[1], 1.5],
                             np.float32)
        self.hi = np.asarray([0.85 * extent[0], 0.85 * extent[1], 8.0],
                             np.float32)
        self.step = step
        self.pos = {}

    def spawn(self):
        return self.rng.uniform(self.lo, self.hi).astype(np.float32)

    def cams(self, service):
        """Advance every live client one step; returns the {cid: pos} dict
        `sync` takes."""
        live = service.active_ids
        for cid in list(self.pos):
            if cid not in live:
                del self.pos[cid]
        out = {}
        for cid in live:
            p = self.pos.get(cid)
            p = self.spawn() if p is None else p + self.rng.normal(
                0, self.step, 3).astype(np.float32)
            self.pos[cid] = np.clip(p, self.lo, self.hi)
            out[cid] = self.pos[cid]
        return out


def _churn_sync(service, walk, churn=CHURN):
    """One churn step: recycle ~churn of the fleet, move everyone, sync.
    Returns (churn_seconds, sync_seconds)."""
    n = service.n_clients
    k = max(1, int(round(churn * n))) if n > 1 else 0
    t0 = time.perf_counter()
    for cid in list(walk.rng.choice(service.active_ids, size=k,
                                    replace=False)):
        service.evict(int(cid))
        p = walk.spawn()
        walk.pos[service.admit(p)] = p
    t_churn = time.perf_counter() - t0
    cams = walk.cams(service)
    t0 = time.perf_counter()
    stats = service.sync(cams)
    _force(stats)
    return t_churn, time.perf_counter() - t0


def _steady(service, walk, syncs, churn=CHURN, warmup=2):
    """Median (churn_us, sync_us) over `syncs` churn steps, after `warmup`
    untimed steps that populate the data-dependent pow2 bucket traces."""
    for _ in range(warmup):
        _churn_sync(service, walk, churn)
    t_c, t_s = [], []
    for _ in range(syncs):
        c, s = _churn_sync(service, walk, churn)
        t_c.append(c)
        t_s.append(s)
    return float(np.median(t_c) * 1e6), float(np.median(t_s) * 1e6)


def run():
    scale = "small" if _smoke() else "medium"
    syncs = 4 if _smoke() else 8
    cap = 8 if _smoke() else 64
    actives = (1, 4, 8) if _smoke() else (1, 4, 16, 64)
    _cfg, _leaves, tree = city_scene(scale)
    hi = np.asarray(tree.gaussians.mu).max(axis=0)
    extent = (float(hi[0]), float(hi[1]))
    cfg = SessionConfig(tau=TAU, cut_budget=16384)
    emit("fleet_churn/scene", 0.0,
         f"scale={scale} nodes={tree.meta.n_real} cap={cap} "
         f"churn={CHURN:.0%}/sync syncs={syncs}")

    # -- (1) churn steady state: big capacity vs right-sized capacity --------
    for n in actives:
        walk = _FleetWalk(np.random.default_rng(5), extent)
        big = svc.LodService(tree, cfg, n, focal=FOCAL, mode="pooled",
                             dedup=True, capacity=cap)
        t0 = time.perf_counter()
        _force(big.sync(walk.cams(big)))
        t_first = time.perf_counter() - t0
        churn_us, big_us = _steady(big, walk, syncs)

        walk = _FleetWalk(np.random.default_rng(5), extent)
        snug = svc.LodService(tree, cfg, n, focal=FOCAL, mode="pooled",
                              dedup=True, capacity=n)
        _force(snug.sync(walk.cams(snug)))
        _, snug_us = _steady(snug, walk, syncs)

        key = f"fleet_churn/cap{cap}/active{n}"
        emit(f"{key}/sync_us", big_us,
             f"per_client={big_us / n:.0f}us t_first={t_first * 1e3:.0f}ms")
        emit(f"{key}/capacity_tax", 0.0,
             f"cap{cap}={big_us:.0f}us cap{n}={snug_us:.0f}us "
             f"ratio={big_us / max(snug_us, 1e-9):.2f}")
        pairs = max(1, int(round(CHURN * n))) if n > 1 else 0
        emit(f"{key}/churn_ops_us", churn_us,
             f"{pairs} evict+admit pairs/sync"
             + (" (sole client is never recycled)" if pairs == 0
                else ", zero retraces in-bucket"))

    # -- (2) growth trajectory: 1 -> cap through every pow2 bucket -----------
    walk = _FleetWalk(np.random.default_rng(9), extent)
    service = svc.LodService(tree, cfg, 1, focal=FOCAL, mode="pooled",
                             dedup=True, capacity=1)
    _force(service.sync(walk.cams(service)))
    while service.capacity < cap:
        target = min(cap, service.capacity * 2)
        t0 = time.perf_counter()
        while service.n_clients < target:
            service.admit(walk.spawn())
        t_admit = time.perf_counter() - t0
        assert service.capacity == target
        t0 = time.perf_counter()
        _force(service.sync(walk.cams(service)))
        t_grow_sync = time.perf_counter() - t0   # includes the one retrace
        _, steady_us = _steady(service, walk, max(2, syncs // 2), warmup=1)
        emit(f"fleet_churn/grow/cap{target}/first_sync_us",
             float(t_grow_sync * 1e6),
             f"admits={t_admit * 1e3:.1f}ms steady={steady_us:.0f}us "
             f"retrace_tax={t_grow_sync * 1e6 / max(steady_us, 1e-9):.1f}x")
    emit("fleet_churn/summary", 0.0,
         "in-bucket churn is recompile-free; sync cost tracks active "
         "clients + their staleness, capacity growth is a bounded pow2 "
         "one-off")


if __name__ == "__main__":
    run()
