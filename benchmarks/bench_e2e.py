"""Figs. 18/19/22 analog: end-to-end collaborative session — motion-to-photon
model, energy model, and the CMP/TA/SR ablation stack."""

import dataclasses as dc
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import city_scene, emit, rigs_along_walk
from repro.core import energy, lod_search as ls
from repro.core.manager import POSE_UPLINK_BYTES
from repro.core.pipeline import (CollaborativeSession, SessionConfig,
                                 render_stereo, render_stereo_reference)
from repro.core.video_model import (LINK_RATE_BPS, StreamConfig,
                                    nebula_sync_latency_s,
                                    video_bytes_per_frame,
                                    video_frame_latency_s)


def run():
    _cfg, leaves, tree = city_scene("medium")
    rigs = rigs_along_walk(48, extent=(200.0, 200.0))

    # ---- ablation (Fig. 22): BASE / +CMP / +CMP+TA / +ALL -------------------
    variants = {
        "base": SessionConfig(tau=48.0, w=4, use_compression=False),
        "cmp": SessionConfig(tau=48.0, w=4, use_compression=True),
    }
    byte_rows = {}
    for name, cfg in variants.items():
        sess = CollaborativeSession(tree, cfg, rigs[0])
        tot, n = 0.0, 0
        for rig in rigs:
            stats, _ = sess.step(rig, render=False)
            tot += stats.sync_bytes
            n += 1
        byte_rows[name] = tot / n
        emit(f"e2e/bytes_per_frame_{name}", 0.0, f"{tot/n:.0f}B")
    emit("e2e/cmp_reduction", 0.0,
         f"{byte_rows['base']/max(byte_rows['cmp'],1):.2f}x fewer bytes")

    # TA ablation: nodes touched with/without temporal reuse
    poses = [np.asarray(r.left.pos) for r in rigs]
    f, tau = jnp.float32(rigs[0].left.focal), jnp.float32(48.0)
    cut, state = ls.full_search(tree, poses[0], f, tau)
    full_nodes = int(cut.nodes_touched)
    touched = []
    for p in poses[1:]:
        cut, state = ls.temporal_search(tree, state, p, f, tau)
        touched.append(int(cut.nodes_touched))
    emit("e2e/ta_node_reduction", 0.0,
         f"{full_nodes/max(np.mean(touched),1):.1f}x fewer nodes/frame")

    # SR ablation: stereo sharing vs two-pass wall time
    cut2, _ = ls.full_search(tree, poses[0], f, tau)
    gids, _c, _ = ls.cut_gids(cut2, tree, budget=16384)
    q = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    q = dc.replace(q, opacity=jnp.where(gids >= 0, q.opacity, 0.0))
    from benchmarks.common import timeit
    from benchmarks.bench_stereo import _two_pass_tiled
    t_sr = timeit(lambda: render_stereo(q, rigs[0], tile=16, list_len=256,
                                        max_pairs=1 << 17)[:2], repeats=2)
    t_2p = timeit(lambda: _two_pass_tiled(q, rigs[0]), repeats=2)
    emit("e2e/sr_speedup", 0.0,
         f"{t_2p/t_sr:.2f}x vs independent tiled eyes (CPU; paper: 1.4-1.9x)")

    # ---- motion-to-photon model (Fig. 18, VR resolution) --------------------
    video_lat = video_frame_latency_s(StreamConfig())
    sess = CollaborativeSession(tree, SessionConfig(tau=48.0, w=4), rigs[0])
    sync_bytes = []
    for rig in rigs:
        st, _ = sess.step(rig, render=False)
        if st.synced:
            sync_bytes.append(st.sync_bytes)
    steady = float(np.mean(sync_bytes[len(sync_bytes) // 3:]))
    # client-side only on the critical path (Fig. 10); cloud+net amortized
    nebula_lat = nebula_sync_latency_s(steady) / 4 + POSE_UPLINK_BYTES * 8 / LINK_RATE_BPS
    emit("e2e/mtp_video_streaming", video_lat * 1e6, "per frame (encode+tx+decode)")
    emit("e2e/mtp_nebula_net", nebula_lat * 1e6,
         f"NETWORK path only (paper's 2.7x also includes client render); "
         f"net-path speedup={video_lat/nebula_lat:.0f}x")

    # ---- energy model (Fig. 19) ---------------------------------------------
    vb = video_bytes_per_frame(StreamConfig())
    e_video = energy.client_frame_energy(dram_bytes=vb * 2, sram_bytes=0,
                                         macs=5e6, comm_bytes=vb)
    n_cut = int(cut2.count())
    from repro.core.gaussians import bytes_per_gaussian
    g_bytes = n_cut * bytes_per_gaussian(1)
    e_neb = energy.client_frame_energy(dram_bytes=g_bytes * 3,
                                       sram_bytes=g_bytes * 8,
                                       macs=n_cut * 2000.0,
                                       comm_bytes=steady / 4 + POSE_UPLINK_BYTES)
    emit("e2e/energy_video_mj", e_video.total_j * 1e3, "per frame (modeled)")
    emit("e2e/energy_nebula_mj", e_neb.total_j * 1e3,
         f"comm={e_neb.comm_j*1e3:.2f}mJ compute={e_neb.compute_j*1e3:.2f}mJ")


if __name__ == "__main__":
    run()
