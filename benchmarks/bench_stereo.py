"""Figs. 8/18/21 analog: stereo rasterization — work sharing vs two-pass.

Wall time on CPU + architecture-neutral work counts: preprocess ops saved,
sort passes saved, right-eye α-check skips (what the paper's RTL turns into
its 1.4-1.9× client speedup)."""

import numpy as np

from benchmarks.common import city_scene, emit, timeit, vr_rig
from repro.core import lod_search as ls
from repro.core.pipeline import render_stereo, render_stereo_reference
import jax.numpy as jnp


def _queue():
    _cfg, leaves, tree = city_scene("medium")
    rig = vr_rig()
    cut, _ = ls.full_search(tree, np.asarray(rig.left.pos),
                            jnp.float32(rig.left.focal), jnp.float32(48.0))
    gids, cnt, _ = ls.cut_gids(cut, tree, budget=16384)
    q = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    import dataclasses as dc
    q = dc.replace(q, opacity=jnp.where(gids >= 0, q.opacity, 0.0))
    return q, rig, int(cnt)


def _two_pass_tiled(q, rig):
    """Fair baseline: the SAME tile pipeline run independently per eye
    (2× project + 2× sort + 2× bin + 2× raster) — what the paper's BASE is."""
    import dataclasses as dc
    import jax.numpy as jnp
    from repro.core.binning import BinConfig, bin_left, bin_right
    from repro.core.projection import depth_ranks, project
    from repro.core.raster import render_tiles
    cam = rig.left
    tile = 16
    cfg = BinConfig(tile=tile, max_pairs=1 << 17, list_len=256)
    outs = []
    for eye in ("left", "right"):
        wide = dc.replace(cam, width=-(-cam.width // tile) * tile)
        s = project(q, rig, wide)          # independent projection per eye
        ranks = depth_ranks(s)             # independent sort per eye
        if eye == "left":
            lists = bin_left(s, wide.width, cam.height, cfg, ranks)
        else:
            lists = bin_right(s, cam.width, cam.height, cfg, ranks)
        img, _ = render_tiles(lists, s, width=cam.width, height=cam.height,
                              tile=tile, eye=eye)
        outs.append(img)
    return outs


def run():
    q, rig, n = _queue()
    emit("stereo/queue_size", 0.0, f"{n} gaussians")

    t_stereo = timeit(lambda: render_stereo(q, rig, tile=16, list_len=256,
                                            max_pairs=1 << 17)[:2])
    t_tiled2 = timeit(lambda: _two_pass_tiled(q, rig))
    t_two_pass = timeit(lambda: render_stereo_reference(q, rig))
    emit("stereo/shared_pipeline", t_stereo, "")
    emit("stereo/two_pass_tiled", t_tiled2,
         f"{t_tiled2 / t_stereo:.2f}x slower (fair BASE: paper reports 1.4-1.9x)")
    emit("stereo/two_pass_untiled_oracle", t_two_pass,
         f"{t_two_pass / t_stereo:.2f}x slower (untiled oracle, not a fair baseline)")

    il, ir, (splats, ll, rl, st) = render_stereo(q, rig, tile=16, list_len=256,
                                                 max_pairs=1 << 17)
    # work accounting (architecture-neutral: what the RTL would save)
    emit("stereo/preprocess_shared", 0.0,
         f"{st.shared_preprocess} splats projected once (2x saved)")
    emit("stereo/sort_shared", 0.0, "1 depth sort for 2 eyes")
    skip = st.right_alpha_skipped / max(st.right_candidates, 1)
    emit("stereo/right_alpha_skip", 0.0,
         f"{skip*100:.1f}% of right-eye candidates prunable by left α-check")
    emit("stereo/right_vs_left_blends", 0.0,
         f"right={st.right_candidates} left={st.left_blends}")

    # stereo similarity (Fig. 8): pixel overlap between eyes
    d = np.abs(np.asarray(il) - np.asarray(ir)).max(-1)
    emit("stereo/pixel_similarity", 0.0,
         f"{(d < 0.04).mean()*100:.1f}% pixels within 4% between eyes")


if __name__ == "__main__":
    run()
