"""Elastic fault-tolerant serving: what a crash actually costs.

Two experiments on the pooled production scheduler (dedup on):

  1. **Snapshot round-trip vs fleet size** — a B-client fleet (B ∈ {4, 16,
     64}; smoke {2, 4, 8}) synced warm, then `snapshot` → kill → `restore`:
     reported per B are the snapshot's on-disk bytes, the atomic save wall
     time, the restore wall time (manifest + leaf load + device_put + the
     host-mirror cross-check), and the cold journal `recover` time when the
     crash happens mid-interval (restore + a fixed 4-sync journal-tail
     replay).
  2. **Journal-replay cost vs snapshot cadence K** — one fleet journaled
     through a fixed schedule with snapshot-every-K for K ∈ {1, 4, 16},
     crashed at the end: `recover` restores the newest snapshot and
     replays at most K syncs, so K is the dial trading steady-state
     snapshot I/O against worst-case recovery wall time. Reported per K:
     records replayed and total recover wall time.

The headline: snapshot bytes and save/restore time scale with the slot
array (capacity x per-slot state), not with the city tree (the shared tree
is fingerprinted, never serialized), and recovery wall time is
restore + K syncs — the same dial the ROADMAP's elastic-serving row
promises.

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene, small
fleets, fewer syncs → every row still present in
BENCH_fleet_recovery.json).
"""

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import city_scene, emit
from repro.core.pipeline import SessionConfig
from repro.serve import lod_service as svc
from repro.serve import recovery as rec

FOCAL, TAU = 260.0, 48.0
TAIL = 4  # journal records between the last snapshot and the "crash"


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


class _Walk:
    def __init__(self, rng, extent, step=3.0):
        self.rng, self.step = rng, step
        self.lo = np.asarray([0.15 * extent[0], 0.15 * extent[1], 1.5],
                             np.float32)
        self.hi = np.asarray([0.85 * extent[0], 0.85 * extent[1], 8.0],
                             np.float32)
        self.pos = {}

    def cams(self, service):
        out = {}
        for cid in service.active_ids:
            p = self.pos.get(cid)
            p = (self.rng.uniform(self.lo, self.hi).astype(np.float32)
                 if p is None
                 else p + self.rng.normal(0, self.step, 3).astype(np.float32))
            self.pos[cid] = np.clip(p, self.lo, self.hi)
            out[cid] = self.pos[cid]
        return out


def run():
    scale = "small" if _smoke() else "medium"
    fleets = (2, 4, 8) if _smoke() else (4, 16, 64)
    warm = 2 if _smoke() else 3
    _cfg, _leaves, tree = city_scene(scale)
    hi = np.asarray(tree.gaussians.mu).max(axis=0)
    extent = (float(hi[0]), float(hi[1]))
    cfg = SessionConfig(tau=TAU, cut_budget=16384)
    emit("fleet_recovery/scene", 0.0,
         f"scale={scale} nodes={tree.meta.n_real} fleets={list(fleets)} "
         f"tail={TAIL}")

    # -- (1) snapshot round-trip vs fleet size -------------------------------
    for b in fleets:
        walk = _Walk(np.random.default_rng(5), extent)
        service = svc.LodService(tree, cfg, b, focal=FOCAL, mode="pooled",
                                 dedup=True)
        for _ in range(warm):
            np.asarray(service.sync(walk.cams(service)).sync_bytes)

        snap = tempfile.mkdtemp(prefix="nebula_snap_")
        try:
            t0 = time.perf_counter()
            final = service.snapshot(snap)
            t_save = time.perf_counter() - t0
            nbytes = _dir_bytes(final)
            t0 = time.perf_counter()
            restored = svc.LodService.restore(tree, snap)
            t_restore = time.perf_counter() - t0
            assert restored.active_ids == service.active_ids
        finally:
            shutil.rmtree(snap, ignore_errors=True)

        # crash mid-interval: restore + TAIL-sync journal replay
        work = tempfile.mkdtemp(prefix="nebula_rec_")
        try:
            mgr = rec.RecoveryManager(service, work, every=10**6, keep=2)
            for _ in range(TAIL):
                np.asarray(mgr.sync(walk.cams(service)).sync_bytes)
            del mgr, service
            t0 = time.perf_counter()
            _mgr2, replayed = rec.recover(tree, work)
            t_recover = time.perf_counter() - t0
            assert replayed == TAIL
        finally:
            shutil.rmtree(work, ignore_errors=True)

        key = f"fleet_recovery/B{b}"
        emit(f"{key}/snapshot_bytes", float(nbytes),
             f"capacity={restored.capacity} "
             f"per_slot={nbytes / restored.capacity / 1e3:.0f}kB")
        emit(f"{key}/save_us", t_save * 1e6,
             f"{nbytes / max(t_save, 1e-9) / 1e6:.0f} MB/s atomic")
        emit(f"{key}/restore_us", t_restore * 1e6,
             "load + device_put + mirror cross-check")
        emit(f"{key}/recover_us", t_recover * 1e6,
             f"restore + {replayed}-sync journal tail")

    # -- (2) journal-replay cost vs snapshot cadence K -----------------------
    b = fleets[1]
    # deliberately NOT a multiple of any K, so every cadence leaves a
    # nonzero journal tail to replay
    n_syncs = 7 if _smoke() else 18
    for k in (1, 4, 16):
        walk = _Walk(np.random.default_rng(7), extent)
        service = svc.LodService(tree, cfg, b, focal=FOCAL, mode="pooled",
                                 dedup=True)
        np.asarray(service.sync(walk.cams(service)).sync_bytes)
        work = tempfile.mkdtemp(prefix="nebula_reck_")
        try:
            t0 = time.perf_counter()
            mgr = rec.RecoveryManager(service, work, every=k, keep=3)
            for _ in range(n_syncs):
                np.asarray(mgr.sync(walk.cams(service)).sync_bytes)
            t_run = time.perf_counter() - t0
            del mgr, service
            t0 = time.perf_counter()
            _mgr2, replayed = rec.recover(tree, work)
            t_recover = time.perf_counter() - t0
            assert replayed <= k
        finally:
            shutil.rmtree(work, ignore_errors=True)
        emit(f"fleet_recovery/K{k}/recover_us", t_recover * 1e6,
             f"replayed={replayed} of {n_syncs} journaled syncs "
             f"(bound: {k}); journaled run={t_run * 1e3:.0f}ms")
    emit("fleet_recovery/summary", 0.0,
         "snapshot cost tracks the slot array, never the shared tree; "
         "recovery = restore + at most K re-executed syncs")


if __name__ == "__main__":
    run()
