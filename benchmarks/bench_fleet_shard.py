"""Mesh-sharded fleet service: per-client sync cost and per-shard state
residency as the serving mesh widens (ROADMAP "shard ServiceState + tree on
the cloud mesh").

Sweep: fleet size B ∈ {4, 16, 64} × mesh {1, 2, 4, 8} virtual CPU devices
(the `clients` axis of `launch.make_fleet_mesh`; mesh 1 is the unsharded
baseline service). Every cell runs in its OWN subprocess with
`--xla_force_host_platform_device_count=8` — XLA's device count is fixed at
first import, so the parent bench process (which must keep seeing the single
real device) cannot host the meshes itself.

Reported per cell:
  * `us_per_call` — steady-state pooled sync wall time / B (per-client cost;
    on host-platform virtual devices this measures partitioning OVERHEAD,
    not speedup — the 8 "devices" share one CPU. The number that must not
    regress is mesh-1);
  * `derived` — fleet sync µs, max per-shard resident bytes of the
    slot-axis service state under its client-axis placement
    (`sharding.fleet.shard_resident_bytes` — the HBM-per-host figure the
    sharding exists to bound) and the same figure unsharded.

Set NEBULA_BENCH_SMOKE=1 for the CI trajectory run (small scene,
B ∈ {4, 16}, mesh ∈ {1, 2}, fewer syncs → every row still present in
BENCH_fleet_shard.json).
"""

import json
import os
import subprocess
import sys

from benchmarks.common import emit

FOCAL, TAU = 260.0, 48.0


def _smoke() -> bool:
    return os.environ.get("NEBULA_BENCH_SMOKE", "") not in ("", "0")


_SUBPROC = r"""
import os, json, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import numpy as np, jax
cfg_in = json.loads(sys.argv[1])
B, shards, smoke = cfg_in["B"], cfg_in["shards"], cfg_in["smoke"]

from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree
from repro.launch.mesh import make_fleet_mesh
from repro.serve import lod_service as svc
from repro.sharding import fleet as shf

city = CityConfig(blocks_x=2 if smoke else 4, blocks_y=2 if smoke else 4,
                  leaf_density=0.10 if smoke else 0.25, seed=2)
leaves = generate_city(city)
tree = build_lod_tree(leaves, target_subtrees=16 if smoke else 64, seed=0)
cfg = svc.SessionConfig(tau=%(tau)r, cut_budget=8192)
mesh = None if shards == 1 else make_fleet_mesh(clients=shards, slabs=1)
service = svc.LodService(tree, cfg, B, focal=%(focal)r, mode="pooled",
                         dedup=True, mesh=mesh)

rng = np.random.default_rng(0)
lo = np.asarray([0.15 * city.blocks_x * 50, 0.15 * city.blocks_y * 50, 1.5])
hi = np.asarray([0.85 * city.blocks_x * 50, 0.85 * city.blocks_y * 50, 8.0])
pos = rng.uniform(lo, hi, (B, 3)).astype(np.float32)

def one_sync():
    global pos
    pos = np.clip(pos + rng.normal(0, 3.0, (B, 3)), lo, hi).astype(np.float32)
    stats = service.sync(pos)
    np.asarray(stats.sync_bytes)   # force

for _ in range(2):
    one_sync()                     # warmup/compile
ts = []
for _ in range(3 if smoke else 6):
    t0 = time.perf_counter()
    one_sync()
    ts.append(time.perf_counter() - t0)

shard_bytes = shf.shard_resident_bytes(mesh, service.state)
flat_bytes = shf.shard_resident_bytes(None, service.state)
print(json.dumps({
    "fleet_us": float(np.median(ts) * 1e6),
    "shard_bytes": int(shard_bytes),
    "flat_bytes": int(flat_bytes),
    "devices": len(jax.devices()),
}))
""" % {"tau": TAU, "focal": FOCAL}


def run():
    smoke = _smoke()
    fleets = (4, 16) if smoke else (4, 16, 64)
    meshes = (1, 2) if smoke else (1, 2, 4, 8)
    for b in fleets:
        for d in meshes:
            if b % d:
                # clients axis must divide the slot capacity (== B here) or
                # every constraint replicates — the row would silently
                # re-measure the unsharded program under a mesh8 label
                print(f"# skip fleet_shard_B{b}_mesh{d}: {d} does not "
                      f"divide B={b} (replicate fallback)", flush=True)
                continue
            payload = json.dumps({"B": b, "shards": d, "smoke": smoke})
            out = subprocess.run([sys.executable, "-c", _SUBPROC, payload],
                                 capture_output=True, text=True, timeout=1800)
            if out.returncode != 0:
                raise RuntimeError(
                    f"bench_fleet_shard B={b} mesh={d} failed:\n"
                    f"{out.stderr[-2000:]}")
            row = json.loads(out.stdout.strip().splitlines()[-1])
            emit(f"fleet_shard_B{b}_mesh{d}", row["fleet_us"] / b,
                 f"fleet_us={row['fleet_us']:.0f} "
                 f"shard_state_bytes={row['shard_bytes']} "
                 f"flat_state_bytes={row['flat_bytes']}")


if __name__ == "__main__":
    run()
