"""Quickstart: build a procedural city, run the LoD search, render a stereo
frame with the bit-accurate shared pipeline, and verify it against two
independent per-eye renders.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np

from repro.core import lod_search as ls
from repro.core.camera import StereoRig, make_camera
from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree
from repro.core.pipeline import render_stereo, render_stereo_reference


def main():
    print("== building city scene ==")
    leaves = generate_city(CityConfig(blocks_x=3, blocks_y=3, leaf_density=0.2))
    tree = build_lod_tree(leaves, target_subtrees=32)
    print(f"   {leaves.n} leaf gaussians → LoD tree: {tree.meta.n_real} nodes, "
          f"{tree.meta.Ns} subtrees of {tree.meta.S} slots, depth {tree.meta.depth}")

    cam = make_camera([30, 30, 1.7], [80, 80, 1.5], focal_px=300.0,
                      width=192, height=108, near=0.25)
    rig = StereoRig(left=cam, baseline=0.06)

    print("== LoD search (fully-streaming) ==")
    cut, state = ls.full_search(tree, np.asarray(cam.pos),
                                jnp.float32(cam.focal), jnp.float32(48.0))
    n_cut = int(cut.count())
    print(f"   cut = {n_cut} gaussians "
          f"({n_cut / tree.meta.n_real * 100:.1f}% of the scene)")

    gids, _cnt, _ovf = ls.cut_gids(cut, tree, budget=16384)
    queue = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    queue = dc.replace(queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))

    print("== stereo rendering (shared preprocessing + triangulation) ==")
    left, right, (_s, _ll, _rl, stats) = render_stereo(
        queue, rig, tile=16, list_len=256, max_pairs=1 << 17)
    ref_l, ref_r = render_stereo_reference(queue, rig)
    exact = bool((np.asarray(left) == np.asarray(ref_l)).all()
                 and (np.asarray(right) == np.asarray(ref_r)).all())
    print(f"   bit-accurate vs independent per-eye renders: {exact}")
    print(f"   work sharing: {stats.shared_preprocess} splats preprocessed once, "
          f"{stats.right_alpha_skipped}/{stats.right_candidates} right-eye "
          f"candidates prunable via left α-checks")

    out = np.concatenate([np.asarray(left), np.asarray(right)], axis=1)
    path = "/tmp/nebula_quickstart_stereo.npy"
    np.save(path, out)
    print(f"   stereo pair saved to {path} (shape {out.shape})")


if __name__ == "__main__":
    main()
