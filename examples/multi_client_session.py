"""Batched multi-client cloud session (the paper's Fig. 9 cloud, B headsets).

One shared city tree + codec serves a fleet of head-tracked clients: the
per-sync temporal LoD search is vmapped across clients (each with its own
foveated τ) and the stale-subtree sweeps of all clients are pooled into one
bucketed dispatch (repro.serve.lod_service). After the session, the cloud
renders a batched stereo frame for the fallback tier — headsets too weak to
rasterize locally — via the repro.render subsystem. Prints a per-client
accounting table and the fleet-level bandwidth vs per-user H.265 video
streaming.

    PYTHONPATH=src python examples/multi_client_session.py [--clients 8]
"""

import argparse
import dataclasses as dc

import numpy as np

from repro.core.camera import StereoRig, TrajectoryConfig, walk_trajectory
from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree
from repro.core.pipeline import SessionConfig
from repro.core.video_model import (StreamConfig, nebula_bandwidth_bps,
                                    video_bandwidth_bps)
from repro.serve.lod_service import LodService

FOCAL = 260.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--syncs", type=int, default=24)
    args = ap.parse_args()
    b = args.clients

    leaves = generate_city(CityConfig(blocks_x=4, blocks_y=4, leaf_density=0.25))
    tree = build_lod_tree(leaves, target_subtrees=64)
    print(f"scene: {tree.meta.n_real} nodes, {tree.meta.Ns} subtrees; "
          f"{b} clients")

    # every client walks the same city on its own seed
    walks = []
    last_cams = []
    for c in range(b):
        cams = list(walk_trajectory(TrajectoryConfig(seed=c), args.syncs,
                                    (200.0, 200.0), focal_px=FOCAL,
                                    width=160, height=96))
        walks.append(np.stack([np.asarray(cam.pos, np.float32)
                               for cam in cams]))
        last_cams.append(cams[-1])
    walks = np.stack(walks, axis=1)  # (syncs, B, 3)

    cfg = SessionConfig(tau=48.0, w=4, w_star=32, cut_budget=16384)
    # foveated fleet: half the clients run a looser (coarser) LoD threshold
    taus = np.where(np.arange(b) % 2 == 0, cfg.tau, 1.75 * cfg.tau
                    ).astype(np.float32)
    service = LodService(tree, cfg, b, focal=FOCAL, mode="pooled", taus=taus)

    total_bytes = np.zeros(b)
    total_delta = total_unique = total_saved = 0.0
    for f in range(args.syncs):
        stats = service.sync(walks[f])
        total_bytes += np.asarray(stats.sync_bytes)
        total_delta += float(np.asarray(stats.delta_size).sum())
        total_unique += float(np.asarray(stats.unique_delta).sum())
        total_saved += float(np.asarray(stats.dedup_bytes_saved).sum())
        if f < 4 or f % 8 == 0:
            sb = np.asarray(stats.sync_bytes)
            print(f"sync {f:3d}: pool={int(np.asarray(stats.resweeps).sum()):4d}"
                  f"/{b * tree.meta.Ns} slabs  "
                  f"bytes/client med={np.median(sb)/1024:7.1f}KiB "
                  f"max={sb.max()/1024:7.1f}KiB  "
                  f"cut med={int(np.median(np.asarray(stats.cut_size)))}")

    print("\nper-client totals over the session:")
    for c in range(b):
        print(f"  client {c}: {total_bytes[c]/1024:8.1f} KiB "
              f"({total_bytes[c]/args.syncs/1024:6.2f} KiB/sync)")

    print(f"\nencode-once delta path: {int(total_unique)} unique of "
          f"{int(total_delta)} requested Δ Gaussians "
          f"({total_unique / max(total_delta, 1) * 100:.1f}%); "
          f"{total_saved / 1024:.1f} KiB fleet downlink saved vs per-client "
          f"unicast")

    per_sync = total_bytes.mean() / args.syncs
    nb = nebula_bandwidth_bps(per_sync, cfg.w, 90.0)
    video = video_bandwidth_bps(StreamConfig())
    print(f"\nfleet mean bandwidth/client: nebula {nb/1e6:.1f} Mbps vs "
          f"H.265@VR {video/1e6:.0f} Mbps → {nb/video*100:.1f}% "
          f"(×{b} clients served from one tree)")

    # fallback tier: the cloud renders every client's queue in ONE batched
    # stereo dispatch (repro.render.batched_render_stereo)
    rigs = [StereoRig(left=dc.replace(cam, width=96, height=64, cx=48.0,
                                      cy=32.0), baseline=0.06)
            for cam in last_cams]
    il, ir, fstats = service.render_fallback(rigs, list_len=192)
    print(f"\nfallback render: {il.shape[0]} stereo frames "
          f"{il.shape[2]}x{il.shape[1]} in one batched dispatch; "
          f"per-client splats shared across eyes: "
          f"{np.asarray(fstats.shared_preprocess).tolist()}")


if __name__ == "__main__":
    main()
