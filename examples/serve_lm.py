"""Batched serving example: load a small model, submit a batch of requests,
run prefill + lockstep batched greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import ARCHS
from repro.models.config import reduced
from repro.models.model_zoo import get_model
from repro.serve.engine import Request, ServingEngine


def main():
    cfg = reduced(ARCHS["gemma3-4b"], n_layers=6, d_model=256, d_ff=512,
                  vocab=4096, n_heads=8, n_kv_heads=4, head_dim=32,
                  sliding_window=64)
    model = get_model(cfg)
    engine = ServingEngine(model, slots=4, max_len=256)
    engine.load(seed=0)

    rng = np.random.default_rng(0)
    for rid in range(8):
        prompt = rng.integers(1, cfg.vocab, rng.integers(8, 48)).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt, max_new=12))

    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"request {r.rid}: prompt[{len(r.prompt)}] → {r.out}")
    print(f"\nserved {len(done)} requests in lockstep batches of "
          f"{engine.slots} ({cfg.name} reduced)")


if __name__ == "__main__":
    main()
