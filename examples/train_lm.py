"""End-to-end training driver: train a ~100M-class LM for a few hundred steps
on the synthetic Markov-Zipf stream, with checkpointing + fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--arch qwen2.5-3b]

(The arch's reduced ~100M variant is used so the run fits this CPU box; the
full configs are exercised by the 512-device dry-run.)
"""

import argparse
import dataclasses as dc

import numpy as np

from repro.configs import ARCHS
from repro.data.tokens import DataConfig
from repro.models.config import reduced
from repro.models.model_zoo import get_model
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    # ~100M-class variant of the chosen family (use --batch/--seq to trade
    # speed; the CI-validated quick setting is --steps 120 --batch 4 --seq 128)
    cfg = reduced(ARCHS[args.arch], n_layers=12, d_model=768, d_ff=2048,
                  vocab=32768, n_heads=12, n_kv_heads=4, head_dim=64)
    model = get_model(cfg)
    n_params = cfg.param_count
    print(f"arch={cfg.name} family={cfg.family} params≈{n_params/1e6:.0f}M")

    trainer = Trainer(
        model,
        opt.OptimizerConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                      checkpoint_dir=args.ckpt_dir, log_every=20,
                      compress_grads=args.compress_grads),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
    )
    out = trainer.run(resume=True)
    hist = out["history"]
    print(f"\nsteps run: {len(hist)}  restarts: {out['restarts']}")
    print(f"loss: first5={np.mean([h['loss'] for h in hist[:5]]):.3f} "
          f"last5={np.mean([h['loss'] for h in hist[-5:]]):.3f}")
    print(f"median step: {np.median([h['time'] for h in hist[3:]])*1e3:.0f} ms")


if __name__ == "__main__":
    main()
