"""End-to-end collaborative VR session (the paper's Fig. 9/10 workflow).

Simulates a 90 FPS head-tracked walk through the city: the cloud runs
temporal-aware LoD search every w frames and streams compressed Δcuts; the
client maintains its mirrored store and renders bit-accurate stereo frames.
Reports bandwidth vs H.265 video streaming.

    PYTHONPATH=src python examples/vr_session.py [--frames 96]
"""

import argparse
import dataclasses as dc

import numpy as np

from repro.core.camera import StereoRig, TrajectoryConfig, walk_trajectory
from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree
from repro.core.pipeline import CollaborativeSession, SessionConfig
from repro.core.video_model import (StreamConfig, nebula_bandwidth_bps,
                                    video_bandwidth_bps)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=96)
    ap.add_argument("--render-every", type=int, default=24)
    args = ap.parse_args()

    leaves = generate_city(CityConfig(blocks_x=4, blocks_y=4, leaf_density=0.25))
    tree = build_lod_tree(leaves, target_subtrees=64)
    print(f"scene: {tree.meta.n_real} nodes")

    rigs = []
    for cam in walk_trajectory(TrajectoryConfig(), args.frames, (200.0, 200.0),
                               focal_px=260.0, width=160, height=96):
        rigs.append(StereoRig(left=dc.replace(cam, near=0.25), baseline=0.06))

    cfg = SessionConfig(tau=48.0, w=4, w_star=32, cut_budget=16384)
    sess = CollaborativeSession(tree, cfg, rigs[0])

    total_bytes, resweeps, cut_sizes = 0.0, [], []
    for i, rig in enumerate(rigs):
        stats, out = sess.step(rig, render=(i % args.render_every == 0))
        total_bytes += stats.sync_bytes
        cut_sizes.append(stats.cut_size)
        if stats.synced:
            resweeps.append(stats.resweeps)
            if i < 20 or i % 24 == 0:
                print(f"frame {i:3d}: sync Δ={stats.delta_size:5d} gaussians "
                      f"{stats.sync_bytes/1024:7.1f}KiB resweeps={stats.resweeps}"
                      f" resident={stats.client_resident}")

    per_frame = total_bytes / args.frames
    nb = nebula_bandwidth_bps(per_frame * cfg.w, cfg.w, 90.0)
    video = video_bandwidth_bps(StreamConfig())  # VR res H.265 lossy-H
    print(f"\nmean cut size: {np.mean(cut_sizes):.0f}")
    print(f"mean subtree resweeps/sync: {np.mean(resweeps):.1f} "
          f"of {tree.meta.Ns} (temporal reuse)")
    print(f"bandwidth: nebula {nb/1e6:.1f} Mbps vs H.265@VR {video/1e6:.0f} Mbps "
          f"→ {nb/video*100:.1f}% (paper: 19-25%)")


if __name__ == "__main__":
    main()
