"""Pallas TPU kernel: flash attention (online softmax) for the LM framework.

Grid (batch, q_heads, q_blocks); GQA is handled zero-copy by the K/V
BlockSpec index maps (head h reads kv head h // group). The kv loop streams
(block_k, head_dim) chunks through VMEM with the usual running
(max, denom, acc) carry. Supports causal and sliding-window (local) masking —
the two patterns the assigned architectures need. The dry-run path uses the
pure-JAX chunked implementation in repro.models.attention (this kernel is the
TPU hot-spot realization, validated in interpret mode)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  seq_k: int, causal: bool, window: int, scale: float):
    qb = pl.program_id(2)
    q = q_ref[0, 0] * scale                       # (Bq, D)
    row = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    nk = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        m_i, l_i, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], kb * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], kb * block_k, block_k, 0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Bq, Bk)
        col = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = col < seq_k
        if causal:
            mask = mask & (col <= row)
        if window > 0:
            mask = mask & (col > row - window)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jnp.dot(p.astype(v.dtype), v,
                                    preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    init = (jnp.full((block_q, 1), _NEG_INF, jnp.float32),
            jnp.zeros((block_q, 1), jnp.float32),
            jnp.zeros((block_q, q.shape[-1]), jnp.float32))
    m_i, l_i, acc = jax.lax.fori_loop(0, nk, body, init)
    o_ref[0, 0] = (acc / jnp.maximum(l_i, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Lq, D); k, v: (B, Hkv, Lk, D) with H % Hkv == 0."""
    b, h, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = h // hkv
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                               seq_k=lk, causal=causal, window=window,
                               scale=scale)
    grid = (b, h, pl.cdiv(lq, block_q))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qq: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda bb, hh, qq: (bb, hh // group, 0, 0)),
            pl.BlockSpec((1, 1, lk, d), lambda bb, hh, qq: (bb, hh // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qq: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
