"""Pallas TPU kernel: shared stereo EWA preprocessing (paper Fig. 13 left).

One pass per Gaussian block: world→cam transform, perspective Jacobian,
2D covariance + conic, conservative α-extent, per-eye SH color, disparity.
Pure VPU vector math over (B,) lanes; blocks stream HBM→VMEM. Camera is a
packed (P,) parameter vector (pos, rot, focal, principal point, near/far,
baseline, eye positions)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gaussians import SH_C0, SH_C1
from repro.core.projection import ALPHA_MIN, COV_BLUR

# packed camera params layout
_P_POS = 0          # 3
_P_ROT = 3          # 9 (row-major world→cam)
_P_FOCAL = 12
_P_CX = 13
_P_CY = 14
_P_NEAR = 15
_P_FAR = 16
_P_BASE = 17
_P_LPOS = 18        # 3 left eye pos
_P_RPOS = 21        # 3 right eye pos
_P_W = 24           # widened width
_P_H = 25
P_LEN = 26


def pack_camera(rig, wide) -> jax.Array:
    w2c = wide.rot.T
    return jnp.concatenate([
        wide.pos.reshape(3), w2c.reshape(9),
        jnp.asarray([wide.focal, wide.cx, wide.cy, wide.near, wide.far,
                     rig.baseline], jnp.float32),
        rig.left.pos.reshape(3), rig.right.pos.reshape(3),
        jnp.asarray([wide.width, wide.height], jnp.float32),
    ]).astype(jnp.float32)


def _sh_color(sh, dirs, k: int):
    c = SH_C0 * sh[:, 0, :]
    if k >= 4:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        c = c - SH_C1 * y * sh[:, 1, :] + SH_C1 * z * sh[:, 2, :] - SH_C1 * x * sh[:, 3, :]
    if k >= 9:
        x, y, z = dirs[:, 0:1], dirs[:, 1:2], dirs[:, 2:3]
        xx, yy, zz, xy, yz, xz = x * x, y * y, z * z, x * y, y * z, x * z
        c = (c + 1.0925484305920792 * xy * sh[:, 4, :]
             - 1.0925484305920792 * yz * sh[:, 5, :]
             + 0.31539156525252005 * (2.0 * zz - xx - yy) * sh[:, 6, :]
             - 1.0925484305920792 * xz * sh[:, 7, :]
             + 0.5462742152960396 * (xx - yy) * sh[:, 8, :])
    return jnp.maximum(c + 0.5, 0.0)


def _preprocess_kernel(cam_ref, mu_ref, ls_ref, quat_ref, opa_ref, sh_ref,
                       out_ref, *, sh_k: int):
    prm = cam_ref[...]
    pos = prm[_P_POS:_P_POS + 3]
    w2c = prm[_P_ROT:_P_ROT + 9].reshape(3, 3)
    f = prm[_P_FOCAL]
    cx, cy = prm[_P_CX], prm[_P_CY]
    near, far = prm[_P_NEAR], prm[_P_FAR]
    baseline = prm[_P_BASE]
    lpos = prm[_P_LPOS:_P_LPOS + 3]
    rpos = prm[_P_RPOS:_P_RPOS + 3]
    width, height = prm[_P_W], prm[_P_H]

    mu = mu_ref[...]
    t = (mu - pos[None, :]) @ w2c.T                      # world→cam
    z = t[:, 2]
    inv_z = 1.0 / jnp.maximum(z, 1e-6)
    mx = f * t[:, 0] * inv_z + cx
    my = f * t[:, 1] * inv_z + cy

    # R S S R^T from quaternion
    q = quat_ref[...]
    q = q / (jnp.sqrt(jnp.sum(q * q, -1, keepdims=True)) + 1e-12)
    w_, x_, y_, z_ = q[:, 0], q[:, 1], q[:, 2], q[:, 3]
    r00 = 1 - 2 * (y_ * y_ + z_ * z_); r01 = 2 * (x_ * y_ - w_ * z_); r02 = 2 * (x_ * z_ + w_ * y_)
    r10 = 2 * (x_ * y_ + w_ * z_); r11 = 1 - 2 * (x_ * x_ + z_ * z_); r12 = 2 * (y_ * z_ - w_ * x_)
    r20 = 2 * (x_ * z_ - w_ * y_); r21 = 2 * (y_ * z_ + w_ * x_); r22 = 1 - 2 * (x_ * x_ + y_ * y_)
    rot = jnp.stack([jnp.stack([r00, r01, r02], -1),
                     jnp.stack([r10, r11, r12], -1),
                     jnp.stack([r20, r21, r22], -1)], -2)  # (B,3,3)
    s = jnp.exp(ls_ref[...])
    rs = rot * s[:, None, :]
    cov3 = rs @ jnp.swapaxes(rs, -1, -2)

    zero = jnp.zeros_like(z)
    j = jnp.stack([
        jnp.stack([f * inv_z, zero, -f * t[:, 0] * inv_z * inv_z], -1),
        jnp.stack([zero, f * inv_z, -f * t[:, 1] * inv_z * inv_z], -1),
    ], -2)                                               # (B,2,3)
    jw = j @ jnp.broadcast_to(w2c, (j.shape[0], 3, 3))
    cov2 = jw @ cov3 @ jnp.swapaxes(jw, -1, -2)
    a = cov2[:, 0, 0] + COV_BLUR
    b = cov2[:, 0, 1]
    c = cov2[:, 1, 1] + COV_BLUR
    det = jnp.maximum(a * c - b * b, 1e-12)

    opa = opa_ref[...]
    tau2 = 2.0 * jnp.log(jnp.maximum(opa, ALPHA_MIN) / ALPHA_MIN)
    ext_x = jnp.sqrt(jnp.maximum(tau2, 0.0) * a)
    ext_y = jnp.sqrt(jnp.maximum(tau2, 0.0) * c)

    sh = sh_ref[...].reshape(mu.shape[0], sh_k, 3)
    dl = mu - lpos[None, :]
    dr = mu - rpos[None, :]
    dl = dl / (jnp.sqrt(jnp.sum(dl * dl, -1, keepdims=True)) + 1e-12)
    dr = dr / (jnp.sqrt(jnp.sum(dr * dr, -1, keepdims=True)) + 1e-12)
    col_l = _sh_color(sh, dl, sh_k)
    col_r = _sh_color(sh, dr, sh_k)

    disparity = baseline * f * inv_z
    visible = ((z > near) & (z < far) & (opa > ALPHA_MIN)
               & (mx + ext_x >= 0.0) & (mx - ext_x <= width)
               & (my + ext_y >= 0.0) & (my - ext_y <= height))

    out = jnp.stack([
        mx, my, z, c / det, -b / det, a / det, ext_x, ext_y,
        col_l[:, 0], col_l[:, 1], col_l[:, 2],
        col_r[:, 0], col_r[:, 1], col_r[:, 2],
        opa, disparity, visible.astype(jnp.float32),
    ], axis=-1)
    out_ref[...] = out


OUT_COLS = 17


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def preprocess_pallas(mu, log_scale, quat, opacity, sh, cam_params, *,
                      block: int = 256, interpret: bool = True) -> jax.Array:
    """Returns (M, 17): [mean2d(2), depth, conic(3), ext(2), color_l(3),
    color_r(3), opacity, disparity, visible]."""
    m = mu.shape[0]
    sh_k = sh.shape[1]
    block = min(block, m)
    grid = (pl.cdiv(m, block),)
    kernel = functools.partial(_preprocess_kernel, sh_k=sh_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((P_LEN,), lambda i: (0,)),
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            pl.BlockSpec((block, 3), lambda i: (i, 0)),
            pl.BlockSpec((block, 4), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, sh_k * 3), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, OUT_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, OUT_COLS), jnp.float32),
        interpret=interpret,
    )(cam_params, mu, log_scale, quat, opacity, sh.reshape(m, sh_k * 3))
