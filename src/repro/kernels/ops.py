"""Jit'd public wrappers around the Pallas kernels.

Every op takes `use_pallas` (+ `interpret`); the fallback is the pure-jnp
oracle path, so callers can flip between the accelerator kernel and XLA. On
this CPU container the kernels run with interpret=True; on TPU the same call
sites compile the real kernels (the dry-run deliberately uses the jnp paths —
see DESIGN.md §7)."""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.binning import TileLists
from repro.core.projection import Splats
from repro.render.common import eye_views
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lod_cut import lod_slab_sweep_pallas
from repro.kernels.preprocess import OUT_COLS, pack_camera, preprocess_pallas
from repro.kernels.rasterize import rasterize_tiles_pallas
from repro.kernels.stereo_shift import stereo_merge_pallas
from repro.kernels.vq_assign import vq_assign_pallas

_INF32 = jnp.int32(2**30)


# -- rasterize ---------------------------------------------------------------


def gather_entries(lists: TileLists, s: Splats, eye: str
                   ) -> Tuple[jax.Array, jax.Array]:
    """Pre-gather per-tile entry slabs (the Fig. 14 attribute broadcast)."""
    means, colors = eye_views(s, eye)
    idx = lists.lists
    g = jnp.clip(idx, 0, s.m - 1)
    valid = idx >= 0
    ent = jnp.concatenate([
        means[g], s.conic[g], colors[g],
        jnp.where(valid, s.opacity[g], 0.0)[..., None],
    ], axis=-1)
    return ent.astype(jnp.float32), lists.counts


def rasterize(lists: TileLists, s: Splats, *, width: int, height: int,
              tile: int, eye: str, eps_t: float = 0.0, use_pallas: bool = True,
              interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Tile raster → (image (H, W, 3), α-hit flags (n_tiles, L))."""
    entries, counts = gather_entries(lists, s, eye)
    if use_pallas:
        tiles_img, hits = rasterize_tiles_pallas(
            entries, counts, tile=tile, tiles_x=lists.tiles_x, eps_t=eps_t,
            interpret=interpret)
    else:
        tiles_img, hits = kref.ref_rasterize(entries, counts, tile=tile,
                                             tiles_x=lists.tiles_x, eps_t=eps_t)
    ty, tx = lists.tiles_y, lists.tiles_x
    img = tiles_img.reshape(ty, tx, tile, tile, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(ty * tile, tx * tile, 3)
    return img[:height, :width], hits


# -- vq ----------------------------------------------------------------------


def vq_assign(x: jax.Array, codebook: jax.Array, *, use_pallas: bool = True,
              interpret: bool = True) -> jax.Array:
    if use_pallas:
        return vq_assign_pallas(x, codebook, interpret=interpret)
    return kref.ref_vq_assign(x, codebook)


# -- preprocessing ------------------------------------------------------------


def preprocess(g, rig, wide, *, use_pallas: bool = True,
               interpret: bool = True) -> Splats:
    """Kernelized repro.core.projection.project (same Splats output)."""
    if not use_pallas:
        from repro.core.projection import project
        return project(g, rig, wide)
    cam = pack_camera(rig, wide)
    out = preprocess_pallas(g.mu, g.log_scale, g.quat, g.opacity, g.sh, cam,
                            interpret=interpret)
    return Splats(
        mean2d=out[:, 0:2], depth=out[:, 2], conic=out[:, 3:6], ext=out[:, 6:8],
        color_l=out[:, 8:11], color_r=out[:, 11:14], opacity=out[:, 14],
        disparity=out[:, 15], visible=out[:, 16] > 0.5)


# -- LoD sweep ----------------------------------------------------------------


def lod_slab_sweep(tree, cam_pos, focal, tau, root_parent_expand, *,
                   use_pallas: bool = True, interpret: bool = True):
    args = (tree.slab_mu(), tree.slab_size(), tree.slab_parent, tree.slab_level,
            tree.slab_is_leaf, tree.slab_valid, root_parent_expand)
    if use_pallas:
        return lod_slab_sweep_pallas(*args, cam_pos, focal, tau,
                                     max_depth=tree.meta.slab_max_depth,
                                     interpret=interpret)
    return kref.ref_lod_slab_sweep(*args, cam_pos, focal, tau,
                                   max_depth=tree.meta.slab_max_depth)


# -- stereo merge --------------------------------------------------------------


def build_merge_sources(left: TileLists, s: Splats, ranks: jax.Array, *,
                        tile: int, width: int, n_cat: int):
    """SRU front-end: per right tile, the n_cat include-filtered, compacted,
    depth-sorted source rows (what the line buffer holds)."""
    tiles_x_r = -(-width // tile)
    tiles_y = left.tiles_y
    tiles_x_w = left.tiles_x
    l_len = left.lists.shape[1]
    m = s.m
    wide = left.lists.reshape(tiles_y, tiles_x_w, l_len)

    def per_cx(cx):
        cols = jnp.clip(cx + jnp.arange(n_cat), 0, tiles_x_w - 1)
        src = wide[:, cols, :]
        ok = (cx + jnp.arange(n_cat)) < tiles_x_w
        return jnp.where(ok[None, :, None], src, -1)

    src = jax.vmap(per_cx, out_axes=1)(jnp.arange(tiles_x_r))
    src = src.reshape(tiles_y * tiles_x_r, n_cat, l_len)

    from repro.core.binning import corner_r2
    g = jnp.clip(src, 0, m - 1)
    valid = src >= 0
    x_r = s.mean2d[g, 0] - s.disparity[g]
    ext_x = s.ext[g, 0]
    cx_of = (jnp.arange(tiles_y * tiles_x_r) % tiles_x_r)
    cy_of = (jnp.arange(tiles_y * tiles_x_r) // tiles_x_r)
    lo = (cx_of * tile).astype(jnp.float32)[:, None, None]
    include = valid & (x_r + ext_x >= lo) & (x_r - ext_x <= lo + tile)
    r2 = corner_r2(s.conic, s.opacity)[g]
    y_r = s.mean2d[g, 1]
    ylo = (cy_of * tile).astype(jnp.float32)[:, None, None]
    dx = jnp.maximum(jnp.maximum(lo - x_r, x_r - (lo + tile)), 0.0)
    dy = jnp.maximum(jnp.maximum(ylo - y_r, y_r - (ylo + tile)), 0.0)
    include = include & (dx * dx + dy * dy <= r2)

    ranks_src = jnp.where(include, ranks[g], _INF32)
    ids_src = jnp.where(include, g, -1)
    # compact each row (entries are sorted; excluded → INF sink to the end)
    order = jnp.argsort(ranks_src, axis=-1, stable=True)
    return (jnp.take_along_axis(ranks_src, order, axis=-1),
            jnp.take_along_axis(ids_src, order, axis=-1))


def stereo_merge(left: TileLists, s: Splats, ranks: jax.Array, *, tile: int,
                 width: int, n_cat: int, use_pallas: bool = True,
                 interpret: bool = True) -> TileLists:
    """Kernelized stereo.stereo_lists (same TileLists output)."""
    src_ranks, src_ids = build_merge_sources(left, s, ranks, tile=tile,
                                             width=width, n_cat=n_cat)
    l_len = left.lists.shape[1]
    if use_pallas:
        out, counts, ovf = stereo_merge_pallas(src_ranks, src_ids,
                                               interpret=interpret)
        merge_overflow = ovf.any()
    else:
        out, counts = kref.ref_stereo_merge(src_ranks, src_ids)
        merge_overflow = (counts > l_len).any()
    tiles_x_r = -(-width // tile)
    return TileLists(lists=out, counts=jnp.minimum(counts, l_len),
                     overflow=left.overflow | merge_overflow,
                     tiles_x=tiles_x_r, tiles_y=left.tiles_y)


# -- attention -----------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=interpret)
    return kref.ref_attention(q, k, v, causal=causal, window=window)
