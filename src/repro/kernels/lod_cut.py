"""Pallas TPU kernel: fully-streaming LoD slab sweep (paper §4.2).

One grid cell = one subtree slab, resident in VMEM for its entire sweep —
the TPU analogue of the paper's "blocks small enough to fully reside in GPU
shared memory". The level loop propagates the expand bit down the slab; the
only irregular access is the slab-local parent gather, which stays inside
VMEM (on real TPU this lowers to a dynamic-gather over an (S,) vector; an
equivalent one-hot-matmul formulation is available for MXU-heavy variants —
see DESIGN.md §2). Also emits the per-subtree temporal reuse radius ρ."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS_DIST = 1e-6
_BIG = 3.4e38  # plain literal — jnp constants would be captured as consts


def _sweep_body(cam, focal, tau, rpe_ref, mu_ref, size_ref, parent_ref,
                level_ref, leaf_ref, valid_ref, cut_ref, rexp_ref, rho_ref,
                max_depth: int):
    """The ONE slab-sweep body: both kernels below (shared-camera slab grid
    and per-pair pooled grid) delegate here, so the parity-critical math —
    level loop, distance clamp, ρ margin — can never diverge between them."""
    mu = mu_ref[0]            # (S, 3)
    size = size_ref[0]        # (S,)
    parent = parent_ref[0]    # (S,)
    level = level_ref[0]
    leaf = leaf_ref[0] != 0
    valid = valid_ref[0] != 0
    rpe = rpe_ref[0] != 0

    d = mu - cam[None, :]
    dist = jnp.sqrt(jnp.sum(d * d, axis=-1))
    proj = size * focal / jnp.maximum(dist, _EPS_DIST)
    gt = proj > tau

    s = mu.shape[0]
    expand = jnp.zeros((s,), jnp.bool_)
    pexp = jnp.zeros((s,), jnp.bool_)
    for l in range(max_depth + 1):
        at = level == l
        pe_l = jnp.where(parent < 0, rpe, expand[jnp.clip(parent, 0, s - 1)])
        pexp = jnp.where(at, pe_l, pexp)
        expand = jnp.where(at, pe_l & gt, expand)
    expand = expand & valid
    in_cut = pexp & (~gt | leaf) & valid

    rstar = size * focal / tau
    margin = jnp.where(valid, jnp.abs(dist - rstar), _BIG)

    cut_ref[0] = in_cut
    rexp_ref[0] = expand[0]
    rho_ref[0] = jnp.min(margin)


def _lod_kernel(params_ref, rpe_ref, mu_ref, size_ref, parent_ref, level_ref,
                leaf_ref, valid_ref, cut_ref, rexp_ref, rho_ref, *, max_depth: int):
    _sweep_body(params_ref[0:3], params_ref[3], params_ref[4], rpe_ref,
                mu_ref, size_ref, parent_ref, level_ref, leaf_ref, valid_ref,
                cut_ref, rexp_ref, rho_ref, max_depth)


def _pair_kernel(focal_ref, cam_ref, tau_ref, rpe_ref, mu_ref, size_ref,
                 parent_ref, level_ref, leaf_ref, valid_ref,
                 cut_ref, rexp_ref, rho_ref, *, max_depth: int):
    """One grid cell = one pooled (client, slab) pair: same sweep body as
    `_lod_kernel`, but camera and τ come from per-pair inputs instead of
    the shared params vector — the kernel form of
    repro.core.lod_search.sweep_slab_camera_pairs."""
    _sweep_body(cam_ref[0], focal_ref[0], tau_ref[0], rpe_ref,
                mu_ref, size_ref, parent_ref, level_ref, leaf_ref, valid_ref,
                cut_ref, rexp_ref, rho_ref, max_depth)


@functools.partial(jax.jit, static_argnames=("max_depth", "interpret"))
def lod_pair_sweep_pallas(pair_mu, pair_size, pair_parent, pair_level,
                          pair_is_leaf, pair_valid, root_parent_expand,
                          cam_pos, focal, tau, *, max_depth: int,
                          interpret: bool = True):
    """Sweep K pooled (client, slab) pairs — each with its OWN camera and τ —
    in one kernel dispatch. Inputs are the gathered pair tables
    ((K, S, ...) slab attributes, (K,) root-parent-expand bits, (K, 3)
    cameras, (K,) taus); returns (in_cut (K,S) bool, root_expand (K,),
    rho (K,)). Bit-parity with `lod_search.sweep_slab_camera_pairs` — the
    service-sweep kernel behind `LodService(sweep_impl="pallas")`."""
    k, s = pair_size.shape
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (k,))
    focal_arr = jnp.asarray(focal, jnp.float32).reshape(1)
    kernel = functools.partial(_pair_kernel, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, s), jnp.bool_),
            jax.ShapeDtypeStruct((k,), jnp.bool_),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(focal_arr, jnp.asarray(cam_pos, jnp.float32), taus,
      root_parent_expand, pair_mu, pair_size, pair_parent, pair_level,
      pair_is_leaf.astype(jnp.int32), pair_valid.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("max_depth", "interpret"))
def lod_slab_sweep_pallas(slab_mu, slab_size, slab_parent, slab_level,
                          slab_is_leaf, slab_valid, root_parent_expand,
                          cam_pos, focal, tau, *, max_depth: int,
                          interpret: bool = True):
    """Sweep all (Ns, S) slabs. Returns (in_cut (Ns,S) bool, root_expand (Ns,),
    rho (Ns,)). Matches repro.core.lod_search._slab_sweep_one bit-for-bit."""
    ns, s = slab_size.shape
    params = jnp.concatenate([
        jnp.asarray(cam_pos, jnp.float32).reshape(3),
        jnp.asarray(focal, jnp.float32).reshape(1),
        jnp.asarray(tau, jnp.float32).reshape(1),
    ])
    kernel = functools.partial(_lod_kernel, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((5,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ns, s), jnp.bool_),
            jax.ShapeDtypeStruct((ns,), jnp.bool_),
            jax.ShapeDtypeStruct((ns,), jnp.float32),
        ],
        interpret=interpret,
    )(params, root_parent_expand, slab_mu, slab_size,
      slab_parent, slab_level, slab_is_leaf.astype(jnp.int32),
      slab_valid.astype(jnp.int32))
