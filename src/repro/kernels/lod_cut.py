"""Pallas TPU kernel: fully-streaming LoD slab sweep (paper §4.2).

One grid cell = one subtree slab, resident in VMEM for its entire sweep —
the TPU analogue of the paper's "blocks small enough to fully reside in GPU
shared memory". The level loop propagates the expand bit down the slab; the
only irregular access is the slab-local parent gather, which stays inside
VMEM (on real TPU this lowers to a dynamic-gather over an (S,) vector; an
equivalent one-hot-matmul formulation is available for MXU-heavy variants —
see DESIGN.md §2). Also emits the per-subtree temporal reuse radius ρ."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS_DIST = 1e-6
_BIG = 3.4e38  # plain literal — jnp constants would be captured as consts


def _lod_kernel(params_ref, rpe_ref, mu_ref, size_ref, parent_ref, level_ref,
                leaf_ref, valid_ref, cut_ref, rexp_ref, rho_ref, *, max_depth: int):
    cam = params_ref[0:3]
    focal = params_ref[3]
    tau = params_ref[4]

    mu = mu_ref[0]            # (S, 3)
    size = size_ref[0]        # (S,)
    parent = parent_ref[0]    # (S,)
    level = level_ref[0]
    leaf = leaf_ref[0] != 0
    valid = valid_ref[0] != 0
    rpe = rpe_ref[0] != 0

    d = mu - cam[None, :]
    dist = jnp.sqrt(jnp.sum(d * d, axis=-1))
    proj = size * focal / jnp.maximum(dist, _EPS_DIST)
    gt = proj > tau

    s = mu.shape[0]
    expand = jnp.zeros((s,), jnp.bool_)
    pexp = jnp.zeros((s,), jnp.bool_)
    for l in range(max_depth + 1):
        at = level == l
        pe_l = jnp.where(parent < 0, rpe, expand[jnp.clip(parent, 0, s - 1)])
        pexp = jnp.where(at, pe_l, pexp)
        expand = jnp.where(at, pe_l & gt, expand)
    expand = expand & valid
    in_cut = pexp & (~gt | leaf) & valid

    rstar = size * focal / tau
    margin = jnp.where(valid, jnp.abs(dist - rstar), _BIG)

    cut_ref[0] = in_cut
    rexp_ref[0] = expand[0]
    rho_ref[0] = jnp.min(margin)


@functools.partial(jax.jit, static_argnames=("max_depth", "interpret"))
def lod_slab_sweep_pallas(slab_mu, slab_size, slab_parent, slab_level,
                          slab_is_leaf, slab_valid, root_parent_expand,
                          cam_pos, focal, tau, *, max_depth: int,
                          interpret: bool = True):
    """Sweep all (Ns, S) slabs. Returns (in_cut (Ns,S) bool, root_expand (Ns,),
    rho (Ns,)). Matches repro.core.lod_search._slab_sweep_one bit-for-bit."""
    ns, s = slab_size.shape
    params = jnp.concatenate([
        jnp.asarray(cam_pos, jnp.float32).reshape(3),
        jnp.asarray(focal, jnp.float32).reshape(1),
        jnp.asarray(tau, jnp.float32).reshape(1),
    ])
    kernel = functools.partial(_lod_kernel, max_depth=max_depth)
    return pl.pallas_call(
        kernel,
        grid=(ns,),
        in_specs=[
            pl.BlockSpec((5,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ns, s), jnp.bool_),
            jax.ShapeDtypeStruct((ns,), jnp.bool_),
            jax.ShapeDtypeStruct((ns,), jnp.float32),
        ],
        interpret=interpret,
    )(params, root_parent_expand, slab_mu, slab_size,
      slab_parent, slab_level, slab_is_leaf.astype(jnp.int32),
      slab_valid.astype(jnp.int32))
