"""Pallas TPU kernel: SRU re-projection + line-buffer k-way merge (paper §5).

One grid cell = one right-eye tile. Inputs are the n_cat pre-compacted source
sequences (left columns cx..cx+n_cat−1 after the SRU's x-overlap include
test), each already depth-sorted. The kernel is a faithful merge unit: it
repeatedly selects the minimum-rank head among the n_cat circular-buffer rows
(INF when exhausted), emits it, advances that head, and drops duplicate ranks
(the same Gaussian arriving from two source columns)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = 2**30  # plain literal — jnp constants would be captured as consts


def _merge_kernel(ranks_ref, ids_ref, out_ref, cnt_ref, ovf_ref, *, n_cat: int,
                  l_len: int, out_len: int):
    ranks = ranks_ref[0]      # (n_cat, L) int32, INF-padded, each row sorted
    ids = ids_ref[0]          # (n_cat, L) int32

    def head_rank(ptrs):
        return jax.vmap(lambda row, p: jnp.where(p < l_len, row[jnp.minimum(p, l_len - 1)], _INF)
                        )(ranks, ptrs)

    def body(i, state):
        ptrs, out, count, prev = state
        hr = head_rank(ptrs)
        c = jnp.argmin(hr).astype(jnp.int32)
        r = hr[c]
        valid = r < _INF
        dup = r == prev
        emit = valid & ~dup
        write = emit & (count < out_len)   # capacity full → count only (overflow)
        gid = jax.vmap(lambda row, p: row[jnp.minimum(p, l_len - 1)])(ids, ptrs)[c]
        out = jnp.where(write, out.at[jnp.minimum(count, out_len - 1)].set(gid), out)
        count = count + emit.astype(jnp.int32)
        ptrs = ptrs.at[c].add(jnp.where(valid, 1, 0))
        prev = jnp.where(valid, r, prev)
        return ptrs, out, count, prev

    init = (jnp.zeros((n_cat,), jnp.int32),
            jnp.full((out_len,), -1, jnp.int32),
            jnp.int32(0),
            -jnp.ones((), jnp.int32))
    _, out, count, _ = jax.lax.fori_loop(0, n_cat * l_len, body, init)
    out_ref[0] = out
    cnt_ref[0] = count
    ovf_ref[0] = count > out_len


@functools.partial(jax.jit, static_argnames=("interpret",))
def stereo_merge_pallas(src_ranks: jax.Array, src_ids: jax.Array, *,
                        interpret: bool = True):
    """src_ranks/src_ids: (n_tiles, n_cat, L) — per right tile, the n_cat
    include-filtered sorted source rows (INF/-1 padded).
    Returns (merged ids (n_tiles, L), counts (n_tiles,), overflow (n_tiles,)).

    `overflow[t]` flags a merge that produced more unique entries than the
    output capacity — the write loop drops the tail, so a True flag means
    tile t's list is TRUNCATED (counts still reports the untruncated total;
    callers surface the flag on the merged TileLists instead of silently
    clamping)."""
    n_tiles, n_cat, l_len = src_ranks.shape
    kernel = functools.partial(_merge_kernel, n_cat=n_cat, l_len=l_len,
                               out_len=l_len)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, n_cat, l_len), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, n_cat, l_len), lambda t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l_len), lambda t: (t, 0)),
            pl.BlockSpec((1,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (t,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, l_len), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.bool_),
        ],
        interpret=interpret,
    )(src_ranks, src_ids)
