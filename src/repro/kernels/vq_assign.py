"""Pallas TPU kernel: vector-quantization codeword assignment (paper §4.3).

argmin_k ||x − c_k||² = argmin_k (||c_k||² − 2·x·c_kᵀ) — the dominant term is
a (Bm, D) × (D, Bk) matmul that maps straight onto the MXU. The codebook is
tiled over the minor grid axis with a running (best_val, best_idx) carried in
the output block (revisited sequentially per TPU grid semantics)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vq_kernel(x_ref, cb_ref, c2_ref, val_ref, idx_ref, *, block_k: int):
    kb = pl.program_id(1)
    x = x_ref[...]                     # (Bm, D)
    cb = cb_ref[...]                   # (Bk, D)
    c2 = c2_ref[...]                   # (Bk,)
    scores = c2[None, :] - 2.0 * jnp.dot(x, cb.T,
                                         preferred_element_type=jnp.float32)
    local_idx = jnp.argmin(scores, axis=1).astype(jnp.int32)
    local_val = jnp.min(scores, axis=1)
    global_idx = local_idx + kb * block_k

    @pl.when(kb == 0)
    def _init():
        val_ref[...] = local_val
        idx_ref[...] = global_idx

    @pl.when(kb > 0)
    def _accum():
        better = local_val < val_ref[...]
        val_ref[...] = jnp.where(better, local_val, val_ref[...])
        idx_ref[...] = jnp.where(better, global_idx, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def vq_assign_pallas(x: jax.Array, codebook: jax.Array, *, block_m: int = 256,
                     block_k: int = 128, interpret: bool = True) -> jax.Array:
    """(M, D) × (Kc, D) → (M,) nearest codeword indices."""
    m, d = x.shape
    kc = codebook.shape[0]
    block_m = min(block_m, m)
    block_k = min(block_k, kc)
    grid = (pl.cdiv(m, block_m), pl.cdiv(kc, block_k))
    c2 = jnp.sum(codebook * codebook, axis=-1)
    kernel = functools.partial(_vq_kernel, block_k=block_k)
    val, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, k: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, k: (k, 0)),
            pl.BlockSpec((block_k,), lambda i, k: (k,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i, k: (i,)),
            pl.BlockSpec((block_m,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(x, codebook, c2)
    del val
    return idx
