"""Pallas TPU kernel: tile rasterization (the paper's VRC, §5).

Dataflow mirrors GSCore's volume rendering core: per grid cell = one image
tile; the tile's depth-ordered Gaussian entries are streamed through VMEM and
broadcast to all T×T "rendering units" (vector lanes); each lane α-checks and
front-to-back blends. Early termination stops the entry loop once every
lane's transmittance is exhausted (eps_t) — set eps_t=0.0 for the bitwise
mode used by the stereo bit-accuracy proofs.

Entry layout (pre-gathered by ops.rasterize — the attribute broadcast of
Fig. 14): entries[t, i] = [mean_x, mean_y, conic_a, conic_b, conic_c,
r, g, b, opacity]; invalid slots carry opacity = 0.

BlockSpec: one (1, L, 9) entry slab + one (1,) count per tile in VMEM;
output is the (1, T, T, 3) tile image + (1, L) α-hit flags (the SRU feed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.projection import ALPHA_MAX, ALPHA_MIN


def _raster_kernel(count_ref, entries_ref, img_ref, hit_ref, *, tile: int,
                   tiles_x: int, eps_t: float):
    tid = pl.program_id(0)
    ox = (tid % tiles_x) * tile
    oy = (tid // tiles_x) * tile
    px = (jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
          + ox.astype(jnp.float32) + 0.5)
    py = (jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
          + oy.astype(jnp.float32) + 0.5)

    entries = entries_ref[0]          # (L, 9) in VMEM
    count = count_ref[0]
    l_max = entries.shape[0]

    def cond(state):
        i, _color, t_acc, _hits = state
        return (i < count) & (jnp.max(t_acc) > eps_t)

    def body(state):
        i, color, t_acc, hits = state
        e = entries[i]
        dx = px - e[0]
        dy = py - e[1]
        power = 0.5 * (e[2] * dx * dx + 2.0 * e[3] * dx * dy + e[4] * dy * dy)
        a = e[8] * jnp.exp(-power)
        a = jnp.minimum(a, ALPHA_MAX)
        a = jnp.where(a >= ALPHA_MIN, a, 0.0)
        contrib = t_acc * a
        color = color + contrib[..., None] * e[5:8]
        t_acc = t_acc * (1.0 - a)
        hits = hits.at[i].set(jnp.any(a > 0.0))
        return i + 1, color, t_acc, hits

    init = (jnp.int32(0),
            jnp.zeros((tile, tile, 3), jnp.float32),
            jnp.ones((tile, tile), jnp.float32),
            jnp.zeros((l_max,), jnp.bool_))
    _, color, _t, hits = jax.lax.while_loop(cond, body, init)
    img_ref[0] = color
    hit_ref[0] = hits


@functools.partial(jax.jit, static_argnames=("tile", "tiles_x", "eps_t", "interpret"))
def rasterize_tiles_pallas(entries: jax.Array, counts: jax.Array, *, tile: int,
                           tiles_x: int, eps_t: float = 0.0,
                           interpret: bool = True):
    """entries: (n_tiles, L, 9) f32; counts: (n_tiles,) int32.
    Returns (tile_images (n_tiles, T, T, 3), hits (n_tiles, L))."""
    n_tiles, l_max, _ = entries.shape
    kernel = functools.partial(_raster_kernel, tile=tile, tiles_x=tiles_x,
                               eps_t=eps_t)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda t: (t,)),
            pl.BlockSpec((1, l_max, 9), lambda t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile, tile, 3), lambda t: (t, 0, 0, 0)),
            pl.BlockSpec((1, l_max), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles, tile, tile, 3), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles, l_max), jnp.bool_),
        ],
        interpret=interpret,
    )(counts, entries)
