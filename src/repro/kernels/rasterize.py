"""Pallas TPU kernel: tile rasterization (the paper's VRC, §5).

Dataflow mirrors GSCore's volume rendering core: per grid cell = one tile
slab; the slab's depth-ordered Gaussian entries are streamed through VMEM and
broadcast to all T×T "rendering units" (vector lanes); each lane α-checks and
front-to-back blends (the α test itself is the shared definition in
repro.render.common — one expression for every rasterization path). Early
termination stops the entry loop once every lane's transmittance is exhausted
(eps_t) — set eps_t=0.0 for the bitwise mode used by the stereo bit-accuracy
proofs.

The kernel is ORIGIN-BASED: each slab carries its own pixel-space tile corner,
so the grid needs no image-shape knowledge. That is what lets
repro.render.batched pool the occupied slabs of a whole client fleet — mixed
clients, mixed eyes, mixed grid positions — into one dispatch
(`rasterize_slabs_pallas`); the classic one-image entry point
(`rasterize_tiles_pallas`) derives origins from the tile grid and calls the
same kernel.

Entry layout (pre-gathered by ops.gather_entries from RenderPlan slabs — the
attribute broadcast of Fig. 14): entries[t, i] = [mean_x, mean_y, conic_a,
conic_b, conic_c, r, g, b, opacity]; invalid slots carry opacity = 0.

BlockSpec: one (1, L, 9) entry slab + one (1,) count + one (1, 2) origin per
grid cell in VMEM; output is the (1, T, T, 3) tile image + (1, L) α-hit flags
(the SRU feed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.render.common import entry_alpha


def _raster_kernel(origin_ref, count_ref, entries_ref, img_ref, hit_ref, *,
                   tile: int, eps_t: float):
    ox = origin_ref[0, 0]
    oy = origin_ref[0, 1]
    px = (jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
          + ox.astype(jnp.float32) + 0.5)
    py = (jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
          + oy.astype(jnp.float32) + 0.5)

    entries = entries_ref[0]          # (L, 9) in VMEM
    count = count_ref[0]
    l_max = entries.shape[0]

    def cond(state):
        i, _color, t_acc, _hits = state
        return (i < count) & (jnp.max(t_acc) > eps_t)

    def body(state):
        i, color, t_acc, hits = state
        e = entries[i]
        a = entry_alpha(px, py, e)
        contrib = t_acc * a
        color = color + contrib[..., None] * e[5:8]
        t_acc = t_acc * (1.0 - a)
        hits = hits.at[i].set(jnp.any(a > 0.0))
        return i + 1, color, t_acc, hits

    init = (jnp.int32(0),
            jnp.zeros((tile, tile, 3), jnp.float32),
            jnp.ones((tile, tile), jnp.float32),
            jnp.zeros((l_max,), jnp.bool_))
    _, color, _t, hits = jax.lax.while_loop(cond, body, init)
    img_ref[0] = color
    hit_ref[0] = hits


@functools.partial(jax.jit, static_argnames=("tile", "eps_t", "interpret"))
def rasterize_slabs_pallas(entries: jax.Array, counts: jax.Array,
                           origins: jax.Array, *, tile: int,
                           eps_t: float = 0.0, interpret: bool = True):
    """Rasterize arbitrary tile slabs — each with its own pixel origin.

    entries: (n_slabs, L, 9) f32; counts: (n_slabs,) int32;
    origins: (n_slabs, 2) int32 pixel-space tile corners (x, y).
    Returns (tile_images (n_slabs, T, T, 3), hits (n_slabs, L)).

    This is the fleet-pooled entry point: slabs may come from different
    clients, eyes, and grid positions (repro.render.batched pools occupied
    slabs into power-of-two buckets and makes ONE dispatch here)."""
    n_slabs, l_max, _ = entries.shape
    kernel = functools.partial(_raster_kernel, tile=tile, eps_t=eps_t)
    return pl.pallas_call(
        kernel,
        grid=(n_slabs,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda t: (t, 0)),
            pl.BlockSpec((1,), lambda t: (t,)),
            pl.BlockSpec((1, l_max, 9), lambda t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile, tile, 3), lambda t: (t, 0, 0, 0)),
            pl.BlockSpec((1, l_max), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_slabs, tile, tile, 3), jnp.float32),
            jax.ShapeDtypeStruct((n_slabs, l_max), jnp.bool_),
        ],
        interpret=interpret,
    )(origins, counts, entries)


@functools.partial(jax.jit, static_argnames=("tile", "tiles_x", "eps_t", "interpret"))
def rasterize_tiles_pallas(entries: jax.Array, counts: jax.Array, *, tile: int,
                           tiles_x: int, eps_t: float = 0.0,
                           interpret: bool = True):
    """One-image entry point: entries: (n_tiles, L, 9) f32 laid out on a
    row-major (tiles_y, tiles_x) grid; counts: (n_tiles,) int32.
    Returns (tile_images (n_tiles, T, T, 3), hits (n_tiles, L))."""
    n_tiles = entries.shape[0]
    idx = jnp.arange(n_tiles, dtype=jnp.int32)
    origins = jnp.stack([(idx % tiles_x) * tile, (idx // tiles_x) * tile], -1)
    return rasterize_slabs_pallas(entries, counts, origins, tile=tile,
                                  eps_t=eps_t, interpret=interpret)
