"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Where a core module already implements the math in pure jnp, the oracle
reuses it (the core path is itself tested against independent references —
e.g. raster vs the untiled per-pixel renderer, lod sweep vs the numpy
level-iteration). Attention gets an independent naive softmax here."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import lod_search as _ls
from repro.core.compression import vq_assign_ref as ref_vq_assign  # noqa: F401
from repro.render.common import entry_alpha


def ref_rasterize_slabs(entries: jax.Array, counts: jax.Array,
                        origins: jax.Array, *, tile: int, eps_t: float = 0.0):
    """Oracle for rasterize.rasterize_slabs_pallas: origin-based tile slabs
    (the fleet-pooled entry layout)."""
    n_slabs, l_max, _ = entries.shape

    yy, xx = jnp.meshgrid(jnp.arange(tile), jnp.arange(tile), indexing="ij")

    def tile_fn(origin, ent, count):
        ox = origin[0]
        oy = origin[1]
        px = xx.astype(jnp.float32) + ox + 0.5
        py = yy.astype(jnp.float32) + oy + 0.5

        def step(carry, i):
            color, t_acc, hits, alive = carry
            e = ent[i]
            a = entry_alpha(px, py, e)
            active = alive & (i < count)
            a = jnp.where(active, a, 0.0)
            contrib = t_acc * a
            color = color + contrib[..., None] * e[5:8]
            t_acc = t_acc * (1.0 - a)
            hits = hits.at[i].set(active & jnp.any(a > 0.0))
            alive = alive & (jnp.max(t_acc) > eps_t)
            return (color, t_acc, hits, alive), None

        init = (jnp.zeros((tile, tile, 3), jnp.float32),
                jnp.ones((tile, tile), jnp.float32),
                jnp.zeros((l_max,), jnp.bool_),
                jnp.bool_(True))
        (color, _t, hits, _a), _ = jax.lax.scan(step, init, jnp.arange(l_max))
        return color, hits

    return jax.vmap(tile_fn)(origins, entries, counts)


def ref_rasterize(entries: jax.Array, counts: jax.Array, *, tile: int,
                  tiles_x: int, eps_t: float = 0.0):
    """Oracle for rasterize.rasterize_tiles_pallas (same entry layout)."""
    n_tiles = entries.shape[0]
    idx = jnp.arange(n_tiles, dtype=jnp.int32)
    origins = jnp.stack([(idx % tiles_x) * tile, (idx // tiles_x) * tile], -1)
    return ref_rasterize_slabs(entries, counts, origins, tile=tile,
                               eps_t=eps_t)


def ref_lod_slab_sweep(slab_mu, slab_size, slab_parent, slab_level,
                       slab_is_leaf, slab_valid, root_parent_expand,
                       cam_pos, focal, tau, *, max_depth: int):
    fn = functools.partial(_ls._slab_sweep_one, cam_pos=jnp.asarray(cam_pos, jnp.float32),
                           focal=focal, tau=tau, max_depth=max_depth)
    return jax.vmap(fn)(slab_mu, slab_size, slab_parent, slab_level,
                        slab_is_leaf, slab_valid, root_parent_expand)


def ref_stereo_merge(src_ranks: jax.Array, src_ids: jax.Array):
    """Vectorized merge oracle: stable sort by rank, drop INF and duplicates."""
    n_tiles, n_cat, l_len = src_ranks.shape
    r = src_ranks.reshape(n_tiles, -1)
    g = src_ids.reshape(n_tiles, -1)
    order = jnp.argsort(r, axis=1, stable=True)
    sr = jnp.take_along_axis(r, order, axis=1)
    sg = jnp.take_along_axis(g, order, axis=1)
    dup = jnp.concatenate([jnp.zeros((n_tiles, 1), bool),
                           sr[:, 1:] == sr[:, :-1]], axis=1)
    keep = (sr < 2**30) & ~dup
    comp_key = jnp.where(keep, jnp.arange(sr.shape[1])[None, :], 2**30)
    comp_order = jnp.argsort(comp_key, axis=1)
    out = jnp.take_along_axis(jnp.where(keep, sg, -1), comp_order, axis=1)
    return out[:, :l_len].astype(jnp.int32), keep.sum(1).astype(jnp.int32)


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive (materialized-scores) GQA attention oracle."""
    b, h, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / (d ** 0.5)
    row = jnp.arange(lq)[:, None]
    col = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask = mask & (col <= row)
    if window > 0:
        mask = mask & (col > row - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
