"""Shared per-splat shading math — the ONE definition of eye-view selection
and the α test (paper §4.4's bit-accuracy hinges on every rasterization path
evaluating the exact same expression).

Consumers:
  * the XLA tile renderer / untiled reference (repro.render.stages, re-exported
    through repro.core.raster for legacy imports);
  * the pure-jnp kernel oracle (repro.kernels.ref);
  * the Pallas rasterization kernel body (repro.kernels.rasterize) — the helper
    is plain jnp, so it traces identically inside a kernel.

Keeping one definition here is what lets the stereo bit-accuracy proofs cover
all four paths: any change to the α math changes every path at once.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.projection import ALPHA_MAX, ALPHA_MIN, Splats


def eye_views(s: Splats, eye: str) -> Tuple[jax.Array, jax.Array]:
    """(means, colors) for the requested eye. Right = triangulation shift
    x_R = x_L − B·f/z (depth, conic, and extent are eye-invariant)."""
    if eye == "left":
        return s.mean2d, s.color_l
    shift = jnp.stack([s.disparity, jnp.zeros_like(s.disparity)], -1)
    return s.mean2d - shift, s.color_r


def splat_alpha(dx, dy, conic_a, conic_b, conic_c, opacity, *,
                alpha_min: float = ALPHA_MIN, alpha_max: float = ALPHA_MAX):
    """α of one splat at pixel offset (dx, dy) from its center.

    Op order is load-bearing: `opacity * exp(-power)` then the min/threshold —
    every rasterization path must emit this exact sequence for bitwise
    reproducibility across program structures."""
    power = 0.5 * (conic_a * dx * dx + 2.0 * conic_b * dx * dy
                   + conic_c * dy * dy)
    a = opacity * jnp.exp(-power)
    a = jnp.minimum(a, alpha_max)
    return jnp.where(a >= alpha_min, a, 0.0)


def pixel_alpha(px: jax.Array, mean: jax.Array, conic: jax.Array,
                opacity: jax.Array, *, alpha_min: float = ALPHA_MIN,
                alpha_max: float = ALPHA_MAX) -> jax.Array:
    """α at pixel centers px (..., 2) — the (mean, conic) call form used by
    the XLA renderers."""
    d = px - mean
    return splat_alpha(d[..., 0], d[..., 1], conic[0], conic[1], conic[2],
                       opacity, alpha_min=alpha_min, alpha_max=alpha_max)


def entry_alpha(px, py, entry, *, alpha_min: float = ALPHA_MIN,
                alpha_max: float = ALPHA_MAX):
    """α for one pre-gathered entry row [mx, my, ca, cb, cc, r, g, b, opa]
    (the Fig. 14 attribute-broadcast layout consumed by the kernels)."""
    return splat_alpha(px - entry[0], py - entry[1], entry[2], entry[3],
                       entry[4], entry[8], alpha_min=alpha_min,
                       alpha_max=alpha_max)
