"""Static render-geometry configuration for the client stereo pipeline.

Everything a compiled render program needs to know at trace time lives here:
tile size, per-eye resolution, list/pair budgets, the stereo line-buffer
width n_cat (derived from the rig's near-plane disparity bound), and the α
thresholds. Per-client quantities that vary at runtime (camera pose, focal,
the render queue) stay pytree leaves — that split is what makes one
`RenderConfig` serve a whole fleet: `batched_render_stereo` vmaps the plan
construction across clients under a single static config.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.core.binning import BinConfig
from repro.core.camera import Camera, StereoRig
from repro.core.projection import ALPHA_MAX, ALPHA_MIN
from repro.core.stereo import n_categories


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    """Static stereo-render geometry (hashable; safe as a jit static arg).

    width/height: per-eye output resolution in pixels
    tile:         tile side in pixels
    list_len:     per-tile depth-list capacity
    max_pairs:    (splat, tile) expansion budget for binning
    n_cat:        stereo line-buffer rows = ⌊max_disparity/tile⌋ + 2
    alpha_min/alpha_max: α test thresholds (paper defaults; XLA path honors
                  overrides, the Pallas kernels assume the defaults)
    eps_t:        early-termination transmittance (0.0 = bitwise mode)
    """

    width: int
    height: int
    tile: int = 16
    list_len: int = 256
    max_pairs: int = 1 << 16
    n_cat: int = 2
    alpha_min: float = ALPHA_MIN
    alpha_max: float = ALPHA_MAX
    eps_t: float = 0.0

    @classmethod
    def for_rig(cls, rig: StereoRig, *, tile: int = 16, list_len: int = 256,
                max_pairs: int = 1 << 16, eps_t: float = 0.0) -> "RenderConfig":
        """Config for one rig (n_cat from its near-plane disparity bound)."""
        return cls(width=rig.left.width, height=rig.left.height, tile=tile,
                   list_len=list_len, max_pairs=max_pairs,
                   n_cat=n_categories(rig.max_disparity_px(), tile),
                   eps_t=eps_t)

    @classmethod
    def for_fleet(cls, rigs: Iterable[StereoRig], *, tile: int = 16,
                  list_len: int = 256, max_pairs: int = 1 << 16,
                  eps_t: float = 0.0) -> "RenderConfig":
        """Config covering a fleet of rigs: shared resolution is required;
        n_cat is the max over rigs so the widened plane covers every client's
        disparity range."""
        rigs = list(rigs)
        if not rigs:
            raise ValueError("for_fleet needs at least one rig")
        w, h = rigs[0].left.width, rigs[0].left.height
        for r in rigs[1:]:
            if (r.left.width, r.left.height) != (w, h):
                raise ValueError("fleet rigs must share one resolution: "
                                 f"{(w, h)} vs {(r.left.width, r.left.height)}")
        n_cat = max(n_categories(r.max_disparity_px(), tile) for r in rigs)
        return cls(width=w, height=h, tile=tile, list_len=list_len,
                   max_pairs=max_pairs, n_cat=n_cat, eps_t=eps_t)

    # -- derived static geometry ----------------------------------------------

    @property
    def tiles_x(self) -> int:
        """Right-eye (output) tile columns."""
        return -(-self.width // self.tile)

    @property
    def tiles_y(self) -> int:
        return -(-self.height // self.tile)

    @property
    def tiles_x_wide(self) -> int:
        """Widened-left tile columns (covers the union of both frusta)."""
        return self.tiles_x + self.n_cat - 1

    @property
    def wide_width(self) -> int:
        return self.tiles_x_wide * self.tile

    def bin_config(self) -> BinConfig:
        return BinConfig(tile=self.tile, max_pairs=self.max_pairs,
                         list_len=self.list_len)

    def widened(self, cam: Camera) -> Camera:
        """The shared-preprocessing camera: same intrinsics/principal point,
        image plane extended to wide_width columns."""
        return dataclasses.replace(cam, width=self.wide_width)
