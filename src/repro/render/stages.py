"""Explicit client-render stages: project → bin_shared → stereo_merge →
rasterize (paper Fig. 13/§4.4), over a static `RenderConfig`.

The stages are pure functions of pytrees, so the same code serves three
callers with identical math:
  * the legacy single-client `repro.core.pipeline.render_stereo` (builds a
    plan, rasterizes, returns the historical tuple);
  * `render_stereo(plan)` here — one call from plan to pixels;
  * `repro.render.batched.batched_render_stereo` — the whole chain vmapped on
    a leading client axis (bit-identical per client, proven in tests).

`render_tiles` / `render_reference` (the XLA rasterizers, formerly in
repro.core.raster) live here so the render subsystem is self-contained;
repro.core.raster re-exports them for existing imports.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import projection as proj
from repro.core.binning import TileLists, bin_left
from repro.core.camera import StereoRig
from repro.core.gaussians import Gaussians
from repro.core.projection import ALPHA_MAX, ALPHA_MIN, Splats, depth_ranks
from repro.core.stereo import stereo_lists
from repro.render.common import eye_views, pixel_alpha
from repro.render.config import RenderConfig
from repro.render.plan import RenderPlan


# ---------------------------------------------------------------------------
# XLA rasterizers (oracle-consistent; moved from repro.core.raster)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("width", "height", "tile", "eye",
                                             "alpha_min", "alpha_max"))
def render_tiles(lists: TileLists, s: Splats, *, width: int, height: int,
                 tile: int, eye: str, alpha_min: float = ALPHA_MIN,
                 alpha_max: float = ALPHA_MAX) -> Tuple[jax.Array, jax.Array]:
    """Render from per-tile lists. Returns (image (H,W,3), alpha_hit (n_tiles, L)).

    alpha_hit[t, i] — entry i of tile t passed the α-check at ≥1 pixel; this is
    exactly what the paper's SRU forwards to the stereo buffer."""
    means, colors = eye_views(s, eye)
    tiles_x, tiles_y = lists.tiles_x, lists.tiles_y

    ty, tx = jnp.meshgrid(jnp.arange(tiles_y), jnp.arange(tiles_x), indexing="ij")
    origins = jnp.stack([tx.reshape(-1) * tile, ty.reshape(-1) * tile], -1)

    yy, xx = jnp.meshgrid(jnp.arange(tile), jnp.arange(tile), indexing="ij")
    px_local = jnp.stack([xx + 0.5, yy + 0.5], -1)   # (T, T, 2) pixel centers

    def tile_fn(list_row, origin):
        px = px_local + origin.astype(jnp.float32)

        def step(carry, idx):
            color_acc, t_acc = carry
            valid = idx >= 0
            g = jnp.clip(idx, 0, s.m - 1)
            a = pixel_alpha(px, means[g], s.conic[g], s.opacity[g],
                            alpha_min=alpha_min, alpha_max=alpha_max)
            a = jnp.where(valid, a, 0.0)
            contrib = t_acc * a
            color_acc = color_acc + contrib[..., None] * colors[g]
            t_acc = t_acc * (1.0 - a)
            return (color_acc, t_acc), (a > 0.0).any()

        init = (jnp.zeros((tile, tile, 3), jnp.float32),
                jnp.ones((tile, tile), jnp.float32))
        (color, _t), hit = jax.lax.scan(step, init, list_row)
        return color, hit

    colors_t, hits = jax.vmap(tile_fn)(lists.lists, origins)   # (n_tiles, T, T, 3)
    img = colors_t.reshape(tiles_y, tiles_x, tile, tile, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(tiles_y * tile, tiles_x * tile, 3)
    return img[:height, :width], hits


@functools.partial(jax.jit, static_argnames=("width", "height", "eye",
                                             "alpha_min", "alpha_max"))
def render_reference(s: Splats, *, width: int, height: int, eye: str,
                     alpha_min: float = ALPHA_MIN,
                     alpha_max: float = ALPHA_MAX) -> jax.Array:
    """Oracle: per-pixel blend of every splat in global depth order (no tiles)."""
    means, colors = eye_views(s, eye)
    key = jnp.where(s.visible, s.depth, jnp.inf)
    order = jnp.argsort(key, stable=True)

    yy, xx = jnp.meshgrid(jnp.arange(height), jnp.arange(width), indexing="ij")
    px = jnp.stack([xx + 0.5, yy + 0.5], -1).astype(jnp.float32)

    def step(carry, g):
        color_acc, t_acc = carry
        a = pixel_alpha(px, means[g], s.conic[g], s.opacity[g],
                        alpha_min=alpha_min, alpha_max=alpha_max)
        a = jnp.where(s.visible[g], a, 0.0)
        contrib = t_acc * a
        color_acc = color_acc + contrib[..., None] * colors[g]
        t_acc = t_acc * (1.0 - a)
        return (color_acc, t_acc), None

    init = (jnp.zeros((height, width, 3), jnp.float32),
            jnp.ones((height, width), jnp.float32))
    (img, _), _ = jax.lax.scan(step, init, order)
    return img


# ---------------------------------------------------------------------------
# pipeline stages
# ---------------------------------------------------------------------------


def project(queue: Gaussians, rig: StereoRig, cfg: RenderConfig
            ) -> Tuple[Splats, jax.Array]:
    """Shared stereo preprocessing: one EWA projection on the widened-left
    plane + one depth sort serve BOTH eyes. Returns (splats, ranks)."""
    splats = proj.project(queue, rig, cfg.widened(rig.left))
    return splats, depth_ranks(splats)


def bin_shared(splats: Splats, ranks: jax.Array, cfg: RenderConfig
               ) -> TileLists:
    """Depth-ordered tile binning on the widened grid (left eye; the right
    eye's lists derive from these via the shift-merge)."""
    return bin_left(splats, cfg.wide_width, cfg.height, cfg.bin_config(),
                    ranks)


def stereo_merge(splats: Splats, ranks: jax.Array, left: TileLists,
                 cfg: RenderConfig, *, use_pallas: bool = False,
                 interpret: bool = True) -> TileLists:
    """Right-eye lists via the SRU/line-buffer k-way shift-merge (no re-sort,
    no re-bin). `use_pallas` switches to the merge kernel (same output)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.stereo_merge(left, splats, ranks, tile=cfg.tile,
                                 width=cfg.width, n_cat=cfg.n_cat,
                                 interpret=interpret)
    return stereo_lists(left, splats, ranks, tile=cfg.tile, width=cfg.width,
                        n_cat=cfg.n_cat)


def build_plan(queue: Gaussians, rig: StereoRig, cfg: RenderConfig, *,
               use_pallas_merge: bool = False, interpret: bool = True
               ) -> RenderPlan:
    """project → bin_shared → stereo_merge, composed."""
    splats, ranks = project(queue, rig, cfg)
    left = bin_shared(splats, ranks, cfg)
    right = stereo_merge(splats, ranks, left, cfg,
                         use_pallas=use_pallas_merge, interpret=interpret)
    return RenderPlan(splats=splats, ranks=ranks, left=left, right=right)


def rasterize(plan: RenderPlan, cfg: RenderConfig, *, use_pallas: bool = False,
              interpret: bool = True
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Rasterize both eyes from a plan → (img_l, img_r, left α-hit flags).

    XLA path by default; `use_pallas` dispatches the tile kernel per eye
    (allclose vs XLA — FMA contraction differs across program structures)."""
    if use_pallas:
        if (cfg.alpha_min, cfg.alpha_max) != (ALPHA_MIN, ALPHA_MAX):
            raise NotImplementedError(
                "the Pallas rasterizer assumes the default α thresholds; "
                f"got ({cfg.alpha_min}, {cfg.alpha_max})")
        from repro.kernels import ops as kops
        img_l, hits = kops.rasterize(plan.left, plan.splats, width=cfg.width,
                                     height=cfg.height, tile=cfg.tile,
                                     eye="left", eps_t=cfg.eps_t,
                                     interpret=interpret)
        img_r, _ = kops.rasterize(plan.right, plan.splats, width=cfg.width,
                                  height=cfg.height, tile=cfg.tile,
                                  eye="right", eps_t=cfg.eps_t,
                                  interpret=interpret)
        return img_l, img_r, hits
    img_l, hits = render_tiles(plan.left, plan.splats, width=cfg.width,
                               height=cfg.height, tile=cfg.tile, eye="left",
                               alpha_min=cfg.alpha_min,
                               alpha_max=cfg.alpha_max)
    img_r, _ = render_tiles(plan.right, plan.splats, width=cfg.width,
                            height=cfg.height, tile=cfg.tile, eye="right",
                            alpha_min=cfg.alpha_min, alpha_max=cfg.alpha_max)
    return img_l, img_r, hits


def render_stereo(plan: RenderPlan, cfg: RenderConfig, *,
                  use_pallas: bool = False, interpret: bool = True
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One call from plan to pixels: (img_l, img_r, left α-hit flags)."""
    return rasterize(plan, cfg, use_pallas=use_pallas, interpret=interpret)


def render_stereo_reference(queue: Gaussians, rig: StereoRig,
                            cfg: RenderConfig = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Two fully independent untiled eye renders (the BASE baseline of
    Fig. 16) from the same projected splats."""
    if cfg is None:
        cfg = RenderConfig.for_rig(rig)
    splats, _ranks = project(queue, rig, cfg)
    img_l = render_reference(splats, width=cfg.width, height=cfg.height,
                             eye="left", alpha_min=cfg.alpha_min,
                             alpha_max=cfg.alpha_max)
    img_r = render_reference(splats, width=cfg.width, height=cfg.height,
                             eye="right", alpha_min=cfg.alpha_min,
                             alpha_max=cfg.alpha_max)
    return img_l, img_r
