"""RenderPlan — everything the rasterization stage needs, as one pytree.

A plan is the output of the explicit pipeline stages (project → bin_shared →
stereo_merge): projected splats, the shared front-to-back depth ranks, and
both eyes' tile lists. It is a plain pytree, so plans vmap/stack cleanly on a
leading client axis — `batched_render_stereo` builds one batched plan for the
whole fleet and the kernels consume its slabs directly.

`StereoFrameStats` is the array-valued (vmappable) per-frame accounting; the
host-int `repro.core.stereo.StereoStats` remains for the legacy single-client
API.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.binning import TileLists
from repro.core.projection import Splats


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RenderPlan:
    """Client render plan (pure data; all leaves arrays).

    splats: (M, ...) projected 2D Gaussians on the widened-left plane
    ranks:  (M,) shared front-to-back depth ranks (one sort, two eyes)
    left:   widened-grid tile lists (binning output)
    right:  right-eye tile lists (shift-merge output)
    """

    splats: Splats
    ranks: jax.Array
    left: TileLists
    right: TileLists

    @property
    def overflow(self) -> jax.Array:
        """() bool — any budget (pairs, list, merge) exceeded anywhere."""
        return self.left.overflow | self.right.overflow


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StereoFrameStats:
    """One stereo frame's work-sharing accounting, as arrays (vmappable).

    shared_preprocess:   () int32 — splats projected once instead of twice
    left_blends:         () int32 — (tile, entry) pairs blended, left eye
    right_candidates:    () int32 — entries merged for the right eye
    right_alpha_skipped: () int32 — right candidates prunable by left α-check
    overflow:            () bool  — any plan budget exceeded
    """

    shared_preprocess: jax.Array
    left_blends: jax.Array
    right_candidates: jax.Array
    right_alpha_skipped: jax.Array
    overflow: jax.Array


def frame_stats(plan: RenderPlan, left_hits: jax.Array) -> StereoFrameStats:
    """Array-valued analog of `repro.core.stereo.alpha_skip_stats` (the
    paper's step-② forwarding accounting), safe under jit/vmap."""
    s = plan.splats
    m = s.m
    hit_any = jnp.zeros((m + 1,), bool)
    g = jnp.where(plan.left.lists >= 0, plan.left.lists, m)
    hit_any = hit_any.at[g.reshape(-1)].max(left_hits.reshape(-1))
    rg = jnp.where(plan.right.lists >= 0, plan.right.lists, m)
    r_valid = plan.right.lists >= 0
    r_hit = hit_any[rg] & r_valid
    return StereoFrameStats(
        shared_preprocess=s.visible.sum().astype(jnp.int32),
        left_blends=(plan.left.lists >= 0).sum().astype(jnp.int32),
        right_candidates=r_valid.sum().astype(jnp.int32),
        right_alpha_skipped=(r_valid & ~r_hit).sum().astype(jnp.int32),
        overflow=plan.overflow,
    )
