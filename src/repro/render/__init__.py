"""Client render subsystem: batched fleet-wide stereo rasterization from
projection to pixels (paper §4.4/§5; ROADMAP "client-side Pallas stereo
batching").

Layering (import order matters — repro.core.raster re-exports from here):

    common  — the ONE definition of eye-view selection + the α test
    config  — RenderConfig: static tile/resolution/stereo geometry
    plan    — RenderPlan pytree + vmappable StereoFrameStats
    stages  — project / bin_shared / stereo_merge / rasterize,
              render_stereo(plan), the XLA rasterizers
    batched — batched_render_stereo: vmapped XLA path + pooled Pallas
              bucket path (fleet-wide occupied-tile pooling)
"""

from repro.render.common import entry_alpha, eye_views, pixel_alpha, splat_alpha
from repro.render.config import RenderConfig
from repro.render.plan import RenderPlan, StereoFrameStats, frame_stats
from repro.render.stages import (bin_shared, build_plan, project, rasterize,
                                 render_reference, render_stereo,
                                 render_stereo_reference, render_tiles,
                                 stereo_merge)
from repro.render.batched import (batched_build_plans, batched_render_stereo,
                                  stack_pytrees, stack_rigs)

__all__ = [
    "entry_alpha", "eye_views", "pixel_alpha", "splat_alpha",
    "RenderConfig", "RenderPlan", "StereoFrameStats", "frame_stats",
    "project", "bin_shared", "stereo_merge", "rasterize", "build_plan",
    "render_stereo", "render_stereo_reference", "render_tiles",
    "render_reference", "batched_build_plans", "batched_render_stereo",
    "stack_pytrees", "stack_rigs",
]
