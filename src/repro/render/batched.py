"""Fleet-batched stereo rendering (ROADMAP "client-side Pallas stereo
batching"): render B clients' queues in one shot.

Two paths, identical math:

  * `path="vmap"` — the whole project→bin→merge→rasterize chain vmapped on a
    leading client axis: one fused device program, bit-identical per client
    to the single-client `repro.core.pipeline.render_stereo` (proven in
    tests/test_render_batched.py).
  * `path="pooled"` — the Pallas bucket path, mirroring the stale-slab
    pooling of repro.serve.lod_service: plans are built vmapped, then the
    OCCUPIED (client, eye, tile) slabs of the whole fleet are pooled,
    rounded up to a power-of-two bucket (bounded recompilation), and
    rasterized by ONE origin-based kernel dispatch
    (repro.kernels.rasterize.rasterize_slabs_pallas). Empty tiles never
    reach the kernel, so fleet rasterization work scales with total occupied
    tiles, not clients × tiles. Bit-identical to the per-client Pallas
    rasterizer; allclose (FMA contraction) vs the XLA path.

Rigs are batched as pytrees: stack per-client rigs with `stack_rigs` (static
fields — resolution, near/far, baseline — must agree; pose and focal are
leaves and vary per client).
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lod_search as ls
from repro.core.camera import StereoRig
from repro.core.gaussians import Gaussians
from repro.render.config import RenderConfig
from repro.render.plan import RenderPlan, StereoFrameStats, frame_stats
from repro.render.stages import build_plan, render_stereo


def stack_pytrees(items: Sequence) -> object:
    """Stack a list of identically-shaped pytrees on a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *items)


def stack_rigs(rigs: Sequence[StereoRig]) -> StereoRig:
    """Stack rigs on a leading client axis. Static fields must agree — they
    define the compiled program; per-client pose/focal stay leaves."""
    rigs = list(rigs)
    r0 = rigs[0]
    key = (r0.left.width, r0.left.height, r0.left.near, r0.left.far,
           r0.left.cx, r0.left.cy, r0.baseline)
    for r in rigs[1:]:
        k = (r.left.width, r.left.height, r.left.near, r.left.far,
             r.left.cx, r.left.cy, r.baseline)
        if k != key:
            raise ValueError(f"rig static fields differ: {key} vs {k}")
    return stack_pytrees(rigs)


def batched_build_plans(queues: Gaussians, rigs: StereoRig, cfg: RenderConfig
                        ) -> RenderPlan:
    """Build every client's RenderPlan vmapped (leaves gain a leading B)."""
    return jax.vmap(lambda q, r: build_plan(q, r, cfg))(queues, rigs)


def _single_frame(queue, rig, cfg):
    plan = build_plan(queue, rig, cfg)
    img_l, img_r, hits = render_stereo(plan, cfg)
    return img_l, img_r, frame_stats(plan, hits)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _vmapped_frames_jit(queues, rigs, cfg):
    return jax.vmap(lambda q, r: _single_frame(q, r, cfg))(queues, rigs)


def batched_render_stereo(queues: Gaussians, rigs: StereoRig,
                          cfg: RenderConfig, *, path: str = "vmap",
                          jit: bool = False, interpret: bool = True,
                          active=None, mesh=None
                          ) -> Tuple[jax.Array, jax.Array, StereoFrameStats]:
    """Render B clients → (img_l (B,H,W,3), img_r (B,H,W,3), per-client
    StereoFrameStats). `queues`/`rigs` carry a leading client axis (see
    `stack_pytrees`/`stack_rigs`).

    `jit=True` wraps the vmap path in one whole-fleet jit: measurably faster,
    but whole-program fusion reassociates FMAs, so results are allclose — not
    bitwise — vs the single-client path. Leave it off where the bit-accuracy
    guarantee matters.

    `active` is an optional (B,) bool slot mask (ragged fleets,
    repro.serve.fleet). On the pooled path an inactive slot's tiles NEVER
    enter the occupied-tile bucket — fleet rasterization work tracks live
    clients, not slot capacity — and its frames come back black. The fixed
    -shape vmap path ignores the mask (an inactive slot's queue is empty, so
    it renders black anyway at unavoidable vmap cost).

    `mesh` (a fleet mesh, repro.sharding.fleet) shards the returned frames
    and per-client stats on the `clients` axis — on both paths each client
    shard's fallback pixels live with its slots (plan building and the XLA
    rasterization are slot-parallel; the pooled path's single Pallas bucket
    dispatch itself stays replicated — its tile pooling is still global)."""
    if path == "vmap":
        if jit:
            out = _vmapped_frames_jit(queues, rigs, cfg)
        else:
            out = jax.vmap(lambda q, r: _single_frame(q, r, cfg))(queues,
                                                                  rigs)
        return _constrain_frames(out, mesh)
    if path == "pooled":
        return _constrain_frames(
            _pooled_render(queues, rigs, cfg, interpret=interpret,
                           active=active, mesh=mesh), mesh)
    raise ValueError(f"unknown batched render path: {path!r}")


def _constrain_frames(out, mesh):
    """Pin (img_l, img_r, stats) on the `clients` axis (no-op meshless)."""
    if mesh is None:
        return out
    from repro.sharding.fleet import shard_service_state
    return shard_service_state(mesh, out)


# ---------------------------------------------------------------------------
# Pallas bucket path: pool occupied tiles fleet-wide, one kernel dispatch
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _gather_fleet_slabs(plans: RenderPlan, cfg: RenderConfig):
    """(entries, counts, origins) for every (client, eye, tile) slab, flat.

    Left slabs live on the widened grid (they must all be rasterized — even
    columns later cropped out feed the α-hit forwarding); right slabs on the
    output grid. Origins are pixel-space tile corners, so the kernel needs no
    grid shape."""
    from repro.kernels.ops import gather_entries

    def per_client(plan):
        ent_l, cnt_l = gather_entries(plan.left, plan.splats, "left")
        ent_r, cnt_r = gather_entries(plan.right, plan.splats, "right")
        return ent_l, cnt_l, ent_r, cnt_r

    ent_l, cnt_l, ent_r, cnt_r = jax.vmap(per_client)(plans)
    b = cnt_l.shape[0]
    t = cfg.tile

    def grid_origins(tiles_x, n_tiles):
        idx = jnp.arange(n_tiles, dtype=jnp.int32)
        return jnp.stack([(idx % tiles_x) * t, (idx // tiles_x) * t], -1)

    org_l = jnp.broadcast_to(grid_origins(cfg.tiles_x_wide, cnt_l.shape[1]),
                             (b,) + (cnt_l.shape[1], 2))
    org_r = jnp.broadcast_to(grid_origins(cfg.tiles_x, cnt_r.shape[1]),
                             (b,) + (cnt_r.shape[1], 2))
    entries = jnp.concatenate([ent_l.reshape(-1, *ent_l.shape[2:]),
                               ent_r.reshape(-1, *ent_r.shape[2:])])
    counts = jnp.concatenate([cnt_l.reshape(-1), cnt_r.reshape(-1)])
    origins = jnp.concatenate([org_l.reshape(-1, 2), org_r.reshape(-1, 2)])
    return entries, counts, origins


@functools.partial(jax.jit, static_argnames=("n_slabs", "tile", "l_len"))
def _scatter_slabs(sel, tiles_img, hits, *, n_slabs: int, tile: int,
                   l_len: int):
    """Scatter pooled kernel outputs back to the dense fleet slab array.
    Repeat-padded slabs write identical values — harmless."""
    imgs = jnp.zeros((n_slabs, tile, tile, 3), jnp.float32)
    flags = jnp.zeros((n_slabs, l_len), jnp.bool_)
    return imgs.at[sel].set(tiles_img), flags.at[sel].set(hits)


def _assemble(tiles_img, tiles_y, tiles_x, tile, height, width):
    img = tiles_img.reshape(-1, tiles_y, tiles_x, tile, tile, 3)
    img = img.transpose(0, 1, 3, 2, 4, 5).reshape(
        -1, tiles_y * tile, tiles_x * tile, 3)
    return img[:, :height, :width]


def _pooled_render(queues, rigs, cfg: RenderConfig, *, interpret: bool = True,
                   active=None, mesh=None):
    from repro.kernels.rasterize import rasterize_slabs_pallas

    plans = batched_build_plans(queues, rigs, cfg)
    if mesh is not None:
        # the pooling tail (slab gather → ONE Pallas bucket dispatch →
        # scatter/assemble) is cross-client by design and its kernel is
        # opaque to the SPMD partitioner — running it on client-sharded
        # plans computes shard-local garbage. Replicate the built plans
        # (one all-gather; plan BUILDING above stays sharded over clients)
        # so the tail is exactly the single-device program, then
        # `_constrain_frames` re-shards the assembled frames over clients.
        from repro.sharding.fleet import replicate_fleet
        plans = replicate_fleet(mesh, plans)
    entries, counts, origins = _gather_fleet_slabs(plans, cfg)
    b = plans.ranks.shape[0]
    n_l = b * cfg.tiles_x_wide * cfg.tiles_y      # left slabs, then right
    n_slabs = int(counts.shape[0])

    occ_mask = np.asarray(counts) > 0
    if active is not None:
        # ragged fleet: an inactive slot's slabs never reach the kernel
        act = np.asarray(active, bool)
        occ_mask &= np.concatenate([
            np.repeat(act, cfg.tiles_x_wide * cfg.tiles_y),
            np.repeat(act, cfg.tiles_x * cfg.tiles_y)])
    occupied = np.nonzero(occ_mask)[0]
    if occupied.size:
        bucket = ls.pow2_bucket(occupied.size, n_slabs)
        sel = jnp.asarray(np.resize(occupied, bucket))
        tiles_img, hits = rasterize_slabs_pallas(
            entries[sel], counts[sel], origins[sel], tile=cfg.tile,
            eps_t=cfg.eps_t, interpret=interpret)
        all_img, all_hits = _scatter_slabs(
            sel, tiles_img, hits, n_slabs=n_slabs, tile=cfg.tile,
            l_len=cfg.list_len)
    else:
        all_img = jnp.zeros((n_slabs, cfg.tile, cfg.tile, 3), jnp.float32)
        all_hits = jnp.zeros((n_slabs, cfg.list_len), jnp.bool_)

    img_l = _assemble(all_img[:n_l], cfg.tiles_y, cfg.tiles_x_wide, cfg.tile,
                      cfg.height, cfg.width)
    img_r = _assemble(all_img[n_l:], cfg.tiles_y, cfg.tiles_x, cfg.tile,
                      cfg.height, cfg.width)
    left_hits = all_hits[:n_l].reshape(b, -1, cfg.list_len)
    stats = jax.vmap(frame_stats)(plans, left_hits)
    return img_l, img_r, stats
