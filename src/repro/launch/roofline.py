"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), TPU v5e constants:
  compute    = HLO_FLOPs / peak_FLOPs            (per-device program)
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / link_bw

cost_analysis() is per-device under SPMD. collective_bytes is NOT in
cost_analysis — we parse the compiled HLO: build a symbol table of every
instruction's result-type byte size, then sum the operand sizes of each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from typing import Dict

# TPU v5e per-chip constants (assignment-provided)
HW = dict(
    peak_flops=197e12,      # bf16 FLOP/s
    hbm_bw=819e9,           # B/s
    link_bw=50e9,           # B/s per ICI link
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[^=\s]+)\s+([\w\-]+)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, e.g. 'bf16[8,128]{1,0}' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum operand byte sizes per collective op kind.

    Strategy: each collective line declares its own result type; for these
    ops the operand bytes equal (all-reduce, all-to-all, collective-permute)
    or are directly derivable from the result type (all-gather output =
    input × group, reduce-scatter output = input / group). We use the
    RESULT size as the on-wire proxy for gather/scatter (the larger side —
    conservative) and result size for the others (= operand size)."""
    sizes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            # fused forms like all-reduce-start
            for c in _COLLECTIVES:
                if op.startswith(c):
                    kind = c
                    break
        if kind is None:
            continue
        nbytes = _type_bytes(m.group(2))
        sizes[kind] += nbytes
        counts[kind] += 1
    total = sum(sizes.values())
    out = {f"{k}_bytes": v for k, v in sizes.items()}
    out.update({f"{k}_count": counts[k] for k in _COLLECTIVES})
    out["total_bytes"] = total
    return out


def roofline_terms(rec: dict) -> dict:
    """Compute the three roofline terms (seconds) + bottleneck for a cell
    record produced by launch/dryrun.py."""
    flops = rec.get("flops", 0.0)
    byts = rec.get("bytes_accessed", 0.0)
    coll = rec.get("collectives", {}).get("collective_bytes", 0.0)
    t_compute = flops / HW["peak_flops"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = coll / HW["link_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D train (N = active params, D = tokens); 2·N·D fwd-only
    n_active = rec.get("model_params_active", 0)
    tokens = rec.get("tokens", 0)
    mode = rec.get("mode", "train")
    factor = 6 if mode == "train" else 2
    model_flops = factor * n_active * tokens
    n_chips = max(rec.get("n_chips", 1), 1)
    hlo_flops_global = flops * n_chips  # cost_analysis is per-device (SPMD)
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    bound = max(t_compute, t_memory, t_coll)
    ideal = model_flops / n_chips / HW["peak_flops"]
    return dict(
        terms, dominant=dominant.replace("_s", ""),
        model_flops=model_flops,
        useful_flops_ratio=useful,
        roofline_fraction=(ideal / bound) if bound > 0 else 0.0,
    )
