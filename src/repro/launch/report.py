"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the cell JSONs.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

HBM_PER_CHIP = 16e9  # v5e


def load(dir_: str) -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.2f}"


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def dryrun_table(rows: List[dict]) -> str:
    out = ["| arch | shape | mesh | status | mem/dev GB | compile s | collective schedule (counts) |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — | {r['error'][:60]} |")
            continue
        mem = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]
               + r["memory"]["output_bytes"]) / 1e9
        c = r["collectives"]
        sched = " ".join(f"{k.split('_')[0]}×{int(c[k])}"
                         for k in sorted(c) if k.endswith("_count") and c[k])
        fit = "" if mem <= 16 else " ⚠>16GB"
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok{fit} | "
                   f"{mem:.2f} | {r['compile_s']:.0f} | {sched or '—'} |")
    return "\n".join(out)


def roofline_table(rows: List[dict], mesh: str = "single") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful | roofline-frac | what moves the bottleneck |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        hint = bottleneck_hint(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.2e} | "
            f"{rf['useful_flops_ratio']:.2f} | {rf['roofline_fraction']:.3f} | {hint} |")
    return "\n".join(out)


def bottleneck_hint(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    fam_moe = r.get("model_params_active", 0) != r.get("model_params", 1)
    if dom == "collective":
        if fam_moe:
            return "fuse EP dispatch a2a; bf16 collectives; widen capacity locality"
        return "bf16 grad all-reduce; reduce-scatter instead of AR; overlap with compute"
    if dom == "memory":
        if r["mode"] == "decode":
            return "KV-cache reads dominate — quantize cache / fuse attention"
        return "bf16 intermediates + fewer fusion round-trips (remat policy)"
    return "already compute-bound — increase arithmetic intensity only"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"cells: {len(rows)} total, {len(ok)} ok, "
          f"{sum(1 for r in rows if r['status']=='skip')} skip, "
          f"{sum(1 for r in rows if r['status']=='error')} error\n")
    print("## Dry-run\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16×16)\n")
    print(roofline_table(rows, "single"))
    print("\n## Roofline (multi-pod 2×16×16)\n")
    print(roofline_table(rows, "multi"))


if __name__ == "__main__":
    main()
