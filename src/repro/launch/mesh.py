"""Production meshes. TPU v5e pod = 16×16 = 256 chips; multi-pod = 2 pods.

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state). Axis semantics:
  pod   — data parallel across pods (DCN); gradient all-reduce crosses it
  data  — FSDP + data parallel within a pod (ICI)
  model — tensor/expert parallel within a pod (ICI)
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Version-tolerant mesh construction.

    Newer JAX exposes `jax.sharding.AxisType` and `jax.make_mesh(...,
    axis_types=...)`; older releases (e.g. 0.4.x) have neither. All our
    axes are Auto (the compiler is free to pick collectives), which is also
    the default when the parameter does not exist."""
    shape, axes = tuple(shape), tuple(axes)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass  # make_mesh predates the axis_types kwarg
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return _make_mesh(shape, axes)


def make_fleet_mesh(clients: int = 1, slabs: int = 1) -> jax.sharding.Mesh:
    """The cloud-serving mesh (repro.sharding.fleet). Axis semantics:
      clients — shards per-client service state on its leading slot axis
                (ServiceState / FleetState / stats / fallback frames)
      slabs   — shards the shared tree's slab attribute tables and the
                encode-once union codec rows
    clients*slabs must equal the available device count (multi-host CPU
    tests force it with --xla_force_host_platform_device_count)."""
    return _make_mesh((clients, slabs), ("clients", "slabs"))
