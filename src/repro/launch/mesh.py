"""Production meshes. TPU v5e pod = 16×16 = 256 chips; multi-pod = 2 pods.

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state). Axis semantics:
  pod   — data parallel across pods (DCN); gradient all-reduce crosses it
  data  — FSDP + data parallel within a pod (ICI)
  model — tensor/expert parallel within a pod (ICI)
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests use small fake-device meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
