# The 512 placeholder devices MUST be requested before jax initializes —
# before ANY other import, including `from repro...` (jax locks the device
# count on first init). Do NOT set this anywhere global (conftest/pyproject):
# smoke tests and benches must see the single real CPU device.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: .lower().compile() for every (arch × shape × mesh).

For each cell this lowers the real train_step / prefill / decode_step with
full in/out shardings onto the production mesh, compiles it, and records:
  memory_analysis()  — per-device bytes (proves it fits a 16 GB v5e chip)
  cost_analysis()    — HLO flops / bytes (feeds §Roofline)
  collective bytes   — parsed from the compiled HLO text (launch/roofline.py)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.roofline import HW, roofline_terms
from repro.models.config import (SHAPES, ShapeConfig, get_shape,
                                 long_context_capable)
from repro.models.model_zoo import (ModelBundle, batch_logical_axes, get_model,
                                    input_specs)
from repro.sharding.context import activation_rules, use_rules
from repro.sharding.partitioning import LOGICAL_RULES, make_shardings
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def skip_reason(cfg, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not long_context_capable(cfg):
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §4)")
    return None


def _batch_shardings(mesh, cfg, shape):
    rules = dict(LOGICAL_RULES)
    ax = batch_logical_axes(cfg, shape)
    specs = input_specs(cfg, shape)
    return make_shardings(mesh, specs, ax, rules), specs


def lower_cell(arch: str, shape_name: str, mesh, *, for_compile: bool = True):
    """Lower one (arch × shape) cell on `mesh`. Returns (lowered, meta)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    model = get_model(cfg)

    p_shapes, p_axes = model.abstract_params()
    p_shard = make_shardings(mesh, p_shapes, p_axes)
    b_shard, b_specs = _batch_shardings(mesh, cfg, shape)
    rules = activation_rules(mesh)

    if shape.mode == "train":
        ocfg = opt.OptimizerConfig()
        step = make_train_step(model, ocfg, compress_grads=False)
        o_abstract = jax.eval_shape(opt.init, p_shapes)
        o_shard = make_shardings(mesh, o_abstract,
                                 opt.state_axes(p_axes))

        def train_fn(params, opt_state, batch):
            return step(params, opt_state, None, batch)

        with mesh:
            with use_rules(rules):
                lowered = jax.jit(
                    train_fn,
                    in_shardings=(p_shard, o_shard, b_shard),
                    donate_argnums=(0, 1),
                ).lower(p_shapes, o_abstract, b_specs)
        return lowered, dict(mode="train", tokens=shape.tokens)

    if shape.mode == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        with mesh:
            with use_rules(rules):
                lowered = jax.jit(
                    prefill_fn,
                    in_shardings=(p_shard, b_shard),
                ).lower(p_shapes, b_specs)
        return lowered, dict(mode="prefill", tokens=shape.tokens)

    # decode: one token against a seq_len cache
    c_abstract = model.abstract_cache(shape.global_batch, shape.seq_len)
    c_shard = make_shardings(mesh, c_abstract, model.cache_axes())

    def decode_fn(params, cache, batch):
        return model.decode_step(params, cache, batch)

    with mesh:
        with use_rules(rules):
            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_shard, c_shard, b_shard),
                donate_argnums=(1,),
            ).lower(p_shapes, c_abstract, b_specs)
    return lowered, dict(mode="decode", tokens=shape.global_batch)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = True) -> dict:
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind, status="ok")

    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        _write(out_path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        ana = hlo_analyze(hlo)  # loop-aware: scan bodies × trip counts

        rec.update(
            meta,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=int(mem.argument_size_in_bytes),
                output_bytes=int(mem.output_size_in_bytes),
                temp_bytes=int(mem.temp_size_in_bytes),
                alias_bytes=int(mem.alias_size_in_bytes),
            ),
            flops=float(ana["flops"]),
            bytes_accessed=float(ana["hbm_bytes"]),
            xla_flops_looponce=float(cost.get("flops", 0.0)),
            collectives={k: v for k, v in ana.items()
                         if k.endswith("_bytes") or k.endswith("_count")},
            model_params=cfg.param_count,
            model_params_active=cfg.active_param_count,
        )
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:  # record the failure — these are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    _write(out_path, rec)
    return rec


def _write(path: str, rec: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.out,
                               skip_existing=not args.force)
                tag = rec["status"]
                if tag == "ok":
                    n_ok += 1
                    mem_gb = (rec["memory"]["argument_bytes"]
                              + rec["memory"]["temp_bytes"]) / 1e9
                    print(f"[ok]   {arch:24s} {shape:12s} {mesh_kind:6s} "
                          f"mem/dev={mem_gb:6.2f}GB flops={rec['flops']:.3e} "
                          f"compile={rec['compile_s']:.1f}s", flush=True)
                elif tag == "skip":
                    n_skip += 1
                    print(f"[skip] {arch:24s} {shape:12s} {mesh_kind:6s} "
                          f"({rec['reason'][:60]})", flush=True)
                else:
                    n_err += 1
                    print(f"[ERR]  {arch:24s} {shape:12s} {mesh_kind:6s} "
                          f"{rec['error'][:120]}", flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skip, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
