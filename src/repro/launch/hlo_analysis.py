"""Loop-aware cost analysis over compiled (post-SPMD, post-fusion) HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE — for scanned
layer stacks that under-counts flops/bytes/collective traffic by the trip
count (× n_layers × kv-chunks …). This module parses the compiled HLO text,
extracts every computation, recovers loop trip counts from the loop-condition
compare constants, and aggregates metrics recursively:

  eff(comp) = direct(comp) + Σ_while trip × eff(body) + Σ_call eff(callee)

Metrics:
  flops      — 2·M·N·K for every dot (fusion-internal dots included);
  hbm bytes  — Σ (operand + result bytes) of top-level instructions
               (post-fusion, so fusion internals correctly do NOT count);
  collective — result-type bytes per collective kind (all-gather /
               all-reduce / reduce-scatter / all-to-all / collective-permute).

The per-device SPMD module is what's parsed, so every number is per-device.
Validated against unrolled-loop cost_analysis in tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES and dt != "token":
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    param_types: Dict[str, str]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_instr_line(line: str):
    """Robust 'name = TYPE op(args...)' split (tuple types may contain
    '/*index=N*/' comments and nested braces)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple type: balanced-paren scan
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rtype = rest[: i + 1]
                    tail = rest[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    mo = re.match(r"([\w\-]+)\((.*)$", tail)
    if not mo:
        return None
    return name, rtype, mo.group(1), mo.group(2)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"({[^}]*}|%?[\w.\-]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
                # parameter types from the signature (bracket-aware)
                sig = line[line.find("("):line.rfind("->")]
                for pm in re.finditer(
                        r"%?([\w.\-]+):\s*([a-z][a-z0-9]*\[[0-9,]*\](?:{[^}]*})?)",
                        sig):
                    cur.param_types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        parsed = _parse_instr_line(line)
        if parsed:
            name, rtype, op, rest = parsed
            # operand names: inside the first balanced paren region
            depth, i, args = 1, 0, rest
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        args = rest[:i]
                        break
            operands = _OPERAND_RE.findall(args)
            cur.instrs.append(Instr(name, rtype, op, operands, line))
    comps["__entry__"] = comps.get(entry, next(iter(comps.values()))) if comps else None
    return comps


def _dot_flops(instr: Instr, comp: Computation,
               types: Dict[str, str]) -> float:
    """2 × (result elements) × (contracted size)."""
    res = _shape_dims(instr.result_type)
    if not res:
        return 0.0
    _, rdims = res[0]
    n_out = 1
    for d in rdims:
        n_out *= d
    # contracted size from lhs type + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.line)
    if not m or not instr.operands:
        return 2.0 * n_out  # fallback
    lhs_t = types.get(instr.operands[0])
    if not lhs_t:
        return 2.0 * n_out
    lshape = _shape_dims(lhs_t)
    if not lshape:
        return 2.0 * n_out
    _, ldims = lshape[0]
    k = 1
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(ldims):
            k *= ldims[int(ci)]
    return 2.0 * n_out * k


def _trip_count(cond: Computation) -> int:
    """Loop bound from the condition computation (max s32 constant)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.result_type.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_count[k] += other.coll_count[k] * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _comp_types(comp: Computation) -> Dict[str, str]:
    types = dict(comp.param_types)
    for ins in comp.instrs:
        types[ins.name] = ins.result_type
    return types


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: Dict[Tuple[str, bool], Costs] = {}

    def _called(self, instr: Instr) -> List[str]:
        names = []
        for m in _CALL_ATTR.finditer(instr.line):
            grp = m.group(1)
            for nm in _OPERAND_RE.findall(grp):
                names.append(nm)
            if not grp.startswith("{") and not grp.startswith("%"):
                names.append(grp)
        return [n for n in names if n in self.comps]

    def _fusion_bytes(self, ins: Instr, types: Dict[str, str]) -> float:
        """HBM traffic of one fusion call site.

        Operands that are only dynamic-sliced inside the fused computation
        count at slice size (the scanned stacked-weights pattern); a fusion
        whose root is dynamic-update-slice is in-place (count 2× update)."""
        callees = self._called(ins)
        callee = self.comps.get(callees[0]) if callees else None
        # result side
        total = float(_type_bytes(ins.result_type))
        if callee and callee.instrs:
            root = callee.instrs[-1]
            if root.op == "dynamic-update-slice" and len(root.operands) > 1:
                upd_t = _comp_types(callee).get(root.operands[1])
                if upd_t:
                    total = 2.0 * _type_bytes(upd_t)
        # operand side
        if callee is None:
            for opnd in ins.operands:
                t = types.get(opnd)
                if t:
                    total += _type_bytes(t)
            return total
        ctypes = _comp_types(callee)
        params: Dict[int, str] = {}
        for pname in callee.param_types:
            m = re.search(r"param_(\d+)", pname)
            if m:
                params[int(m.group(1))] = pname
        for i, opnd in enumerate(ins.operands):
            t = types.get(opnd)
            if not t:
                continue
            pname = params.get(i)
            if pname:
                uses = [u for u in callee.instrs if pname in u.operands]
                if uses and all(u.op == "dynamic-slice" for u in uses):
                    total += sum(_type_bytes(u.result_type) for u in uses)
                    continue
            total += _type_bytes(t)
        return total

    def eff(self, comp_name: str, in_fusion: bool = False) -> Costs:
        key = (comp_name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Costs()  # cycle guard
        comp = self.comps[comp_name]
        types = _comp_types(comp)
        total = Costs()
        for ins in comp.instrs:
            if ins.op == "dot":
                total.flops += _dot_flops(ins, comp, types)
            for ckind in _COLLECTIVES:
                if ins.op == ckind or ins.op.startswith(ckind + "-start") or \
                   ins.op.startswith(ckind + "."):
                    total.coll[ckind] += _type_bytes(ins.result_type)
                    total.coll_count[ckind] += 1
                    break
            if not in_fusion and ins.op not in _SKIP_BYTES_OPS and \
                    not ins.op.endswith("-done"):
                if ins.op == "dynamic-slice":
                    # reads only the sliced window, not the whole operand
                    total.hbm_bytes += 2 * _type_bytes(ins.result_type)
                elif ins.op == "dynamic-update-slice":
                    # in-place: read+write of the update window
                    upd_t = (types.get(ins.operands[1])
                             if len(ins.operands) > 1 else None)
                    total.hbm_bytes += 2 * _type_bytes(upd_t or ins.result_type)
                elif ins.op == "while":
                    pass  # loop state is aliased in place; body accounts for it
                elif ins.op == "fusion":
                    total.hbm_bytes += self._fusion_bytes(ins, types)
                else:
                    b = _type_bytes(ins.result_type)
                    for opnd in ins.operands:
                        t = types.get(opnd)
                        if t:
                            b += _type_bytes(t)
                    total.hbm_bytes += b

            if ins.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if mb and mb.group(1) in self.comps:
                    body = mb.group(1)
                if mc and mc.group(1) in self.comps:
                    cond = mc.group(1)
                # primary: XLA's own annotation; fallback: cond constant
                mt = re.search(r'known_trip_count[^0-9]*(\d+)', ins.line)
                if mt:
                    trip = int(mt.group(1))
                else:
                    trip = _trip_count(self.comps[cond]) if cond else 1
                if body:
                    total.add(self.eff(body, in_fusion), trip)
            elif ins.op in ("fusion",):
                for callee in self._called(ins):
                    sub = self.eff(callee, True)   # internals: flops/coll only
                    total.flops += sub.flops
                    for k in _COLLECTIVES:
                        total.coll[k] += sub.coll[k]
                        total.coll_count[k] += sub.coll_count[k]
            elif ins.op in ("call", "conditional", "async-start", "custom-call"):
                for callee in self._called(ins):
                    total.add(self.eff(callee, in_fusion), 1.0)
        self._memo[key] = total
        return total

    def entry_costs(self) -> Costs:
        entry = self.comps["__entry__"]
        return self.eff(entry.name)


def analyze(hlo_text: str) -> Dict[str, float]:
    c = HloCost(hlo_text).entry_costs()
    out = dict(flops=c.flops, hbm_bytes=c.hbm_bytes,
               collective_bytes=c.coll_bytes)
    for k in _COLLECTIVES:
        out[f"{k}_bytes"] = c.coll[k]
        out[f"{k}_count"] = c.coll_count[k]
    return out
