"""Synthetic LM data pipeline with deterministic sharding + prefetch.

Generates a Zipf-distributed Markov token stream (enough structure that a
~100M model's loss visibly drops within a few hundred steps — used by the
end-to-end example). Deterministic per (seed, step, shard): a restarted or
re-sharded job regenerates exactly the same global batch, which the
elastic-restore test relies on."""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_order: int = 1
    n_states: int = 64


class SyntheticTokens:
    """Markov-chain token source: state s → Zipf over a state-specific slice."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # state transition matrix + per-state vocab offset
        self.trans = rng.dirichlet(np.ones(cfg.n_states) * 0.3,
                                   size=cfg.n_states)
        self.offsets = rng.integers(0, max(cfg.vocab - 256, 1), cfg.n_states)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        states = rng.integers(0, cfg.n_states, b)
        toks = np.zeros((b, s + 1), np.int64)
        # vectorized over batch, sequential over time (cheap at test scales)
        zipf_cache = rng.zipf(cfg.zipf_a, size=(b, s + 1)) % 256
        for t in range(s + 1):
            toks[:, t] = (self.offsets[states] + zipf_cache[:, t]) % cfg.vocab
            u = rng.random(b)
            cum = np.cumsum(self.trans[states], axis=1)
            states = (cum < u[:, None]).sum(1).clip(0, cfg.n_states - 1)
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "targets": jnp.asarray(toks[:, 1:], jnp.int32)}


class PrefetchLoader:
    """Background-thread prefetch (double buffered) over a batch source."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.step = start_step
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
