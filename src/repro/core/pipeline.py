"""End-to-end collaborative rendering session (paper Fig. 9 / Fig. 10).

Cloud side (per LoD sync, every `w` frames):
  temporal-aware LoD search → cut → management-table sync → Δcut compression.
Client side (every frame):
  decode Δcut into the local store → render queue = received cut →
  shared stereo preprocessing → left raster → triangulation shift-merge →
  right raster. Only client-side work is on the motion-to-photon path.

The session is a **pure functional core** — `SessionState` is a pytree and
`cloud_sync_step` / `client_render_step` / `session_step` are pure functions
(state in, state out) — so one cloud can hold many sessions side by side:
`repro.serve.lod_service` stacks `SessionState`-style leaves on a leading
batch axis and vmaps the temporal LoD search across clients.
`CollaborativeSession` remains as a thin stateful wrapper over the core for
API compatibility (examples, benchmarks, older tests).

The session also keeps full byte/work accounting so the benchmarks can
reproduce the paper's bandwidth/speedup figures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.camera import StereoRig
from repro.core.gaussians import Gaussians
from repro.core.lod_tree import LodTree
from repro.core.stereo import alpha_skip_stats
from repro import render as rnd


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    tau: float = 48.0            # LoD threshold τ* in pixels
    w: int = 4                   # LoD sync interval in frames (paper default)
    w_star: int = 32             # reuse window w_r* in syncs (paper default)
    cut_budget: int = 4096
    tile: int = 16
    list_len: int = 256
    max_pairs: int = 1 << 16
    k_codes: int = 256
    use_compression: bool = True


@dataclasses.dataclass
class FrameStats:
    frame: int
    synced: bool
    cut_size: int
    delta_size: int
    sync_bytes: float
    nodes_touched: int
    resweeps: int
    client_resident: int
    stereo: Optional[object] = None


# ---------------------------------------------------------------------------
# functional core
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SessionState:
    """Complete per-client session state as a single pytree.

    mgr_state:    cloud-side management table
    client:       client-side mirror (reconstructed from wire data only)
    temporal:     per-subtree LoD-search reuse state
    client_store: client-side decoded attribute store (codec error included)
    cut_gids:     (cut_budget,) int32 current render queue, -1 padded
    sync_index:   () int32 — LoD syncs performed so far
    frame_index:  () int32 — frames stepped so far
    """

    mgr_state: mgr.ManagerState
    client: mgr.ClientState
    temporal: ls.TemporalState
    client_store: Gaussians
    cut_gids: jax.Array
    sync_index: jax.Array
    frame_index: jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StepStats:
    """One frame's accounting, as arrays (pytree — safe to vmap/stack)."""

    synced: jax.Array          # () bool
    cut_size: jax.Array        # () int32
    delta_size: jax.Array      # () int32
    sync_bytes: jax.Array      # () float32
    nodes_touched: jax.Array   # () int32
    resweeps: jax.Array        # () int32
    client_resident: jax.Array  # () int32


def session_init(tree: LodTree, cfg: SessionConfig) -> SessionState:
    """Fresh session state. The initial TemporalState has `swept=False`
    everywhere, so the first `cloud_sync_step` performs a full sweep —
    bit-identical to `ls.full_search` (no special first-frame case)."""
    m = tree.meta
    n = tree.n_pad
    z = tree.gaussians
    store = Gaussians(
        mu=jnp.zeros_like(z.mu), log_scale=jnp.zeros_like(z.log_scale),
        quat=jnp.zeros_like(z.quat).at[:, 0].set(1.0),
        opacity=jnp.zeros_like(z.opacity), sh=jnp.zeros_like(z.sh))
    return SessionState(
        mgr_state=mgr.ManagerState.initial(n),
        client=mgr.ClientState.initial(n),
        temporal=ls.TemporalState.initial(m.Ns, m.S),
        client_store=store,
        cut_gids=jnp.full((cfg.cut_budget,), -1, jnp.int32),
        sync_index=jnp.int32(0),
        frame_index=jnp.int32(0),
    )


def session_wire_format(tree: LodTree, cfg: SessionConfig
                        ) -> Tuple[comp.Codec, float]:
    """(codec, bytes-per-Gaussian) shared by cloud and client. The codec is
    scene-level — one fit serves every client of the tree."""
    codec = comp.fit_codec(tree.gaussians, k_codes=cfg.k_codes, iters=6)
    bytes_per_g = (comp.wire_bytes_per_gaussian(codec)
                   if cfg.use_compression
                   else 4 * (3 + 3 + 4 + 1 + 3 * tree.gaussians.sh.shape[1]))
    return codec, float(bytes_per_g)


def cloud_sync_step(tree: LodTree, codec: comp.Codec, cfg: SessionConfig,
                    state: SessionState, cam_pos, focal,
                    bytes_per_g: float) -> Tuple[SessionState, StepStats]:
    """One LoD sync: temporal-aware search → management sync → Δcut payload →
    client mirror + store update. Pure (composed of jitted pieces)."""
    cam_pos = jnp.asarray(cam_pos, jnp.float32)
    cut, temporal = ls.temporal_search(tree, state.temporal, cam_pos,
                                       jnp.float32(focal), jnp.float32(cfg.tau))
    mask = cut.mask(tree)
    t = state.sync_index
    mgr_state, plan = mgr.cloud_sync(state.mgr_state, mask, t,
                                     jnp.int32(cfg.w_star))
    # wire: Δcut payload (compressed) + cut membership deltas
    # single-client shim: the legacy unicast wire format (one per-client
    # stream, implicit Δ ids) — the fleet service dedups this per sync via
    # repro.serve.delta_path, through the same compression.encode_rows
    ids, n_delta = mgr.gather_payload(tree.gaussians, plan.delta_data,
                                      cfg.cut_budget)
    sh_k = tree.gaussians.sh.shape[1]
    if cfg.use_compression:
        enc = comp.encode_rows(codec, tree.gaussians, ids)
        dec = comp.decode(codec, enc, sh_k)
    else:
        dec = tree.gaussians.slice_rows(jnp.clip(ids, 0))
    # client applies the sync
    client = mgr.client_sync(state.client, plan.delta_data, plan.cut_add,
                             plan.cut_remove, t, jnp.int32(cfg.w_star))
    client_store = _apply_payload(state.client_store, ids, dec)
    gids, count, _overflow = ls.cut_gids(cut, tree, cfg.cut_budget)
    new_state = SessionState(
        mgr_state=mgr_state, client=client, temporal=temporal,
        client_store=client_store, cut_gids=gids,
        sync_index=t + 1, frame_index=state.frame_index + 1)
    stats = StepStats(
        synced=jnp.asarray(True),
        cut_size=count,
        delta_size=n_delta,
        sync_bytes=plan.wire_bytes(bytes_per_g),
        nodes_touched=cut.nodes_touched,
        resweeps=cut.resweep.sum().astype(jnp.int32),
        client_resident=plan.n_resident)
    return new_state, stats


def idle_step(state: SessionState) -> Tuple[SessionState, StepStats]:
    """A non-sync frame: the client renders its cached cut; the only uplink
    traffic is the pose."""
    new_state = dataclasses.replace(state, frame_index=state.frame_index + 1)
    stats = StepStats(
        synced=jnp.asarray(False),
        cut_size=(state.cut_gids >= 0).sum().astype(jnp.int32),
        delta_size=jnp.int32(0),
        sync_bytes=jnp.float32(mgr.POSE_UPLINK_BYTES),
        nodes_touched=jnp.int32(0),
        resweeps=jnp.int32(0),
        client_resident=state.client.has.sum().astype(jnp.int32))
    return new_state, stats


def session_step(tree: LodTree, codec: comp.Codec, cfg: SessionConfig,
                 state: SessionState, cam_pos, focal, bytes_per_g: float
                 ) -> Tuple[SessionState, StepStats]:
    """Advance one VR frame (host-driven sync cadence: every cfg.w frames)."""
    if int(state.frame_index) % cfg.w == 0:
        return cloud_sync_step(tree, codec, cfg, state, cam_pos, focal,
                               bytes_per_g)
    return idle_step(state)


@jax.jit
def _fresh_session_like(state: SessionState) -> SessionState:
    """A freshly-initialized SessionState with `state`'s leaf shapes —
    bitwise identical to `session_init` for the same tree/config, but
    jittable (shapes come from the traced state, not host objects)."""
    n = state.mgr_state.client_has.shape[0]
    ns, s = state.temporal.slab_cut0.shape
    store = state.client_store
    return SessionState(
        mgr_state=mgr.ManagerState.initial(n),
        client=mgr.ClientState.initial(n),
        temporal=ls.TemporalState.initial(ns, s),
        client_store=Gaussians(
            mu=jnp.zeros_like(store.mu),
            log_scale=jnp.zeros_like(store.log_scale),
            quat=jnp.zeros_like(store.quat).at[:, 0].set(1.0),
            opacity=jnp.zeros_like(store.opacity),
            sh=jnp.zeros_like(store.sh)),
        cut_gids=jnp.full_like(state.cut_gids, -1),
        sync_index=jnp.int32(0),
        frame_index=jnp.int32(0),
    )


def admit_step(state: SessionState) -> SessionState:
    """Functional client admission for the session core (the single-client
    primitive behind the fleet lifecycle of repro.serve.fleet): returns the
    freshly-admitted session occupying this state's slot. The temporal state
    is fully unswept, so the admitted client's FIRST sync is a cold full
    sweep and a cold Δcut — no special first-frame case anywhere."""
    return _fresh_session_like(state)


def evict_step(state: SessionState) -> SessionState:
    """Functional client eviction: clear the session back to its fresh
    value. Eviction and admission reset to the SAME state by construction —
    `admit_step(evict_step(s)) == evict_step(s)` bitwise — which is the
    contract that makes a recycled fleet slot indistinguishable from a
    brand-new one (tests/test_fleet_churn.py)."""
    return _fresh_session_like(state)


def client_render_step(cfg: SessionConfig, state: SessionState,
                       rig: StereoRig):
    """Render the client's current queue from its *decoded* store (pure)."""
    gids = state.cut_gids
    queue = state.client_store.slice_rows(jnp.clip(gids, 0))
    # mask out padding rows by zero opacity
    queue = dataclasses.replace(
        queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))
    return render_stereo(queue, rig, tile=cfg.tile, list_len=cfg.list_len,
                         max_pairs=cfg.max_pairs)


def _apply_payload(store: Gaussians, ids: jax.Array, dec: Gaussians
                   ) -> Gaussians:
    """Scatter decoded Δcut rows into the client store (-1 ids are padding)."""
    valid = (ids >= 0)[:, None]
    safe_ids = jnp.clip(ids, 0)
    return Gaussians(
        mu=store.mu.at[safe_ids].set(jnp.where(valid, dec.mu, store.mu[safe_ids])),
        log_scale=store.log_scale.at[safe_ids].set(
            jnp.where(valid, dec.log_scale, store.log_scale[safe_ids])),
        quat=store.quat.at[safe_ids].set(
            jnp.where(valid, dec.quat, store.quat[safe_ids])),
        opacity=store.opacity.at[safe_ids].set(
            jnp.where(valid[:, 0], dec.opacity, store.opacity[safe_ids])),
        sh=store.sh.at[safe_ids].set(
            jnp.where(valid[:, :, None], dec.sh, store.sh[safe_ids])),
    )


# ---------------------------------------------------------------------------
# stateful wrapper (API compatibility)
# ---------------------------------------------------------------------------


class CollaborativeSession:
    """Thin stateful wrapper over the functional core (single client)."""

    def __init__(self, tree: LodTree, cfg: SessionConfig, rig_template: StereoRig):
        self.tree = tree
        self.cfg = cfg
        self.codec, self.bytes_per_g = session_wire_format(tree, cfg)
        self.rig_template = rig_template
        self.state = session_init(tree, cfg)

    # legacy attribute views ---------------------------------------------------

    @property
    def mgr_state(self) -> mgr.ManagerState:
        return self.state.mgr_state

    @property
    def client(self) -> mgr.ClientState:
        return self.state.client

    @property
    def temporal(self) -> ls.TemporalState:
        return self.state.temporal

    @property
    def client_store(self) -> Gaussians:
        return self.state.client_store

    @property
    def sync_index(self) -> int:
        return int(self.state.sync_index)

    @property
    def frame_index(self) -> int:
        return int(self.state.frame_index)

    @property
    def current_cut_ids(self) -> Optional[jax.Array]:
        return self.state.cut_gids if self.sync_index > 0 else None

    # -- client ----------------------------------------------------------------

    def render(self, rig: StereoRig, gids: jax.Array):
        cfg = self.cfg
        queue = self.state.client_store.slice_rows(jnp.clip(gids, 0))
        queue = dataclasses.replace(
            queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))
        return render_stereo(queue, rig, tile=cfg.tile, list_len=cfg.list_len,
                             max_pairs=cfg.max_pairs)

    # -- frame loop ------------------------------------------------------------

    def step(self, rig: StereoRig, render: bool = True):
        """Advance one VR frame. LoD sync happens every cfg.w frames."""
        frame = int(self.state.frame_index)
        focal = jnp.float32(self.rig_template.left.focal)
        self.state, st = session_step(
            self.tree, self.codec, self.cfg, self.state,
            np.asarray(rig.left.pos), focal, self.bytes_per_g)
        stats = FrameStats(
            frame=frame, synced=bool(st.synced),
            cut_size=int(st.cut_size), delta_size=int(st.delta_size),
            sync_bytes=float(st.sync_bytes),
            nodes_touched=int(st.nodes_touched), resweeps=int(st.resweeps),
            client_resident=int(st.client_resident))
        out = client_render_step(self.cfg, self.state, rig) if render else None
        return stats, out


def render_stereo(queue: Gaussians, rig: StereoRig, *, tile: int = 16,
                  list_len: int = 256, max_pairs: int = 1 << 16):
    """Client stereo pipeline: shared preprocessing → left raster →
    triangulation shift-merge → right raster. Returns (left, right, stats).

    Legacy single-client surface over the `repro.render` subsystem: builds a
    `RenderConfig` + `RenderPlan` and rasterizes — the same stages
    `repro.render.batched.batched_render_stereo` vmaps across a fleet
    (bit-identical per client, proven in tests)."""
    cfg = rnd.RenderConfig.for_rig(rig, tile=tile, list_len=list_len,
                                   max_pairs=max_pairs)
    plan = rnd.build_plan(queue, rig, cfg)
    img_l, img_r, hits = rnd.render_stereo(plan, cfg)
    stats = alpha_skip_stats(plan.left, plan.right, hits, plan.splats)
    return img_l, img_r, (plan.splats, plan.left, plan.right, stats)


def render_stereo_reference(queue: Gaussians, rig: StereoRig):
    """Two fully independent eye renders (the BASE baseline of Fig. 16)."""
    return rnd.render_stereo_reference(queue, rig)
