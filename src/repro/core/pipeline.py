"""End-to-end collaborative rendering session (paper Fig. 9 / Fig. 10).

Cloud side (per LoD sync, every `w` frames):
  temporal-aware LoD search → cut → management-table sync → Δcut compression.
Client side (every frame):
  decode Δcut into the local store → render queue = received cut →
  shared stereo preprocessing → left raster → triangulation shift-merge →
  right raster. Only client-side work is on the motion-to-photon path.

The session also keeps full byte/work accounting so the benchmarks can
reproduce the paper's bandwidth/speedup figures."""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.binning import BinConfig, bin_left, bin_right
from repro.core.camera import Camera, StereoRig
from repro.core.gaussians import Gaussians
from repro.core.lod_tree import LodTree
from repro.core.projection import Splats, depth_ranks, project
from repro.core.raster import render_reference, render_tiles
from repro.core.stereo import alpha_skip_stats, n_categories, stereo_lists


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    tau: float = 48.0            # LoD threshold τ* in pixels
    w: int = 4                   # LoD sync interval in frames (paper default)
    w_star: int = 32             # reuse window w_r* in syncs (paper default)
    cut_budget: int = 4096
    tile: int = 16
    list_len: int = 256
    max_pairs: int = 1 << 16
    k_codes: int = 256
    use_compression: bool = True


@dataclasses.dataclass
class FrameStats:
    frame: int
    synced: bool
    cut_size: int
    delta_size: int
    sync_bytes: float
    nodes_touched: int
    resweeps: int
    client_resident: int
    stereo: Optional[object] = None


class CollaborativeSession:
    """Host-level driver pairing a cloud state machine with a client mirror."""

    def __init__(self, tree: LodTree, cfg: SessionConfig, rig_template: StereoRig):
        self.tree = tree
        self.cfg = cfg
        self.codec = comp.fit_codec(tree.gaussians, k_codes=cfg.k_codes, iters=6)
        self.bytes_per_g = (comp.wire_bytes_per_gaussian(self.codec)
                            if cfg.use_compression
                            else 4 * (3 + 3 + 4 + 1 + 3 * tree.gaussians.sh.shape[1]))
        n = tree.n_pad
        self.mgr_state = mgr.ManagerState.initial(n)
        self.client = mgr.ClientState.initial(n)
        self.temporal: Optional[ls.TemporalState] = None
        # client-side attribute store (decoded values — quality includes codec)
        z = tree.gaussians
        self.client_store = Gaussians(
            mu=jnp.zeros_like(z.mu), log_scale=jnp.zeros_like(z.log_scale),
            quat=jnp.zeros_like(z.quat).at[:, 0].set(1.0),
            opacity=jnp.zeros_like(z.opacity), sh=jnp.zeros_like(z.sh))
        self.rig_template = rig_template
        self.sync_index = 0
        self.frame_index = 0
        self.current_cut_ids: Optional[jax.Array] = None

    # -- cloud ---------------------------------------------------------------

    def _lod_search(self, cam_pos) -> ls.CutResult:
        focal = jnp.float32(self.rig_template.left.focal)
        tau = jnp.float32(self.cfg.tau)
        if self.temporal is None:
            cut, self.temporal = ls.full_search(self.tree, cam_pos, focal, tau)
        else:
            cut, self.temporal = ls.temporal_search(self.tree, self.temporal,
                                                    cam_pos, focal, tau)
        return cut

    def _sync(self, cam_pos) -> Tuple[FrameStats, jax.Array]:
        cut = self._lod_search(jnp.asarray(cam_pos, jnp.float32))
        mask = cut.mask(self.tree)
        t = jnp.int32(self.sync_index)
        self.mgr_state, plan = mgr.cloud_sync(self.mgr_state, mask, t,
                                              jnp.int32(self.cfg.w_star))
        # wire: Δcut payload (compressed) + cut membership deltas
        ids, n_delta = mgr.gather_payload(self.tree.gaussians, plan.delta_data,
                                          self.cfg.cut_budget)
        payload = self.tree.gaussians.slice_rows(jnp.clip(ids, 0))
        if self.cfg.use_compression:
            enc = comp.encode(self.codec, payload)
            dec = comp.decode(self.codec, enc, payload.sh.shape[1])
        else:
            dec = payload
        # client applies the sync
        self.client = mgr.client_sync(self.client, plan.delta_data, plan.cut_add,
                                      plan.cut_remove, t, jnp.int32(self.cfg.w_star))
        valid = (ids >= 0)[:, None]
        safe_ids = jnp.clip(ids, 0)
        st = self.client_store
        self.client_store = Gaussians(
            mu=st.mu.at[safe_ids].set(jnp.where(valid, dec.mu, st.mu[safe_ids])),
            log_scale=st.log_scale.at[safe_ids].set(
                jnp.where(valid, dec.log_scale, st.log_scale[safe_ids])),
            quat=st.quat.at[safe_ids].set(jnp.where(valid, dec.quat, st.quat[safe_ids])),
            opacity=st.opacity.at[safe_ids].set(
                jnp.where(valid[:, 0], dec.opacity, st.opacity[safe_ids])),
            sh=st.sh.at[safe_ids].set(
                jnp.where(valid[:, :, None], dec.sh, st.sh[safe_ids])),
        )
        gids, count, overflow = ls.cut_gids(cut, self.tree, self.cfg.cut_budget)
        self.current_cut_ids = gids
        stats = FrameStats(
            frame=self.frame_index, synced=True,
            cut_size=int(count), delta_size=int(n_delta),
            sync_bytes=float(plan.wire_bytes(self.bytes_per_g)),
            nodes_touched=int(cut.nodes_touched),
            resweeps=int(np.asarray(cut.resweep).sum()),
            client_resident=int(plan.n_resident))
        self.sync_index += 1
        return stats, gids

    # -- client --------------------------------------------------------------

    def render(self, rig: StereoRig, gids: jax.Array):
        cfg = self.cfg
        queue = self.client_store.slice_rows(jnp.clip(gids, 0))
        # mask out padding rows by zero opacity
        queue = dataclasses.replace(
            queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))
        return render_stereo(queue, rig, tile=cfg.tile, list_len=cfg.list_len,
                             max_pairs=cfg.max_pairs)

    # -- frame loop ------------------------------------------------------------

    def step(self, rig: StereoRig, render: bool = True):
        """Advance one VR frame. LoD sync happens every cfg.w frames."""
        synced = self.frame_index % self.cfg.w == 0 or self.current_cut_ids is None
        if synced:
            stats, gids = self._sync(np.asarray(rig.left.pos))
        else:
            gids = self.current_cut_ids
            stats = FrameStats(frame=self.frame_index, synced=False,
                               cut_size=int((np.asarray(gids) >= 0).sum()),
                               delta_size=0,
                               sync_bytes=float(mgr.POSE_UPLINK_BYTES),
                               nodes_touched=0, resweeps=0,
                               client_resident=int(self.client.has.sum()))
        out = self.render(rig, gids) if render else None
        self.frame_index += 1
        return stats, out


def render_stereo(queue: Gaussians, rig: StereoRig, *, tile: int = 16,
                  list_len: int = 256, max_pairs: int = 1 << 16):
    """Client stereo pipeline: shared preprocessing → left raster →
    triangulation shift-merge → right raster. Returns (left, right, stats)."""
    cam = rig.left
    max_disp = rig.max_disparity_px()
    n_cat = n_categories(max_disp, tile)
    tiles_x_r = -(-cam.width // tile)
    wide_width = (tiles_x_r + n_cat - 1) * tile
    wide = dataclasses.replace(cam, width=wide_width)

    splats = project(queue, rig, wide)
    ranks = depth_ranks(splats)
    bcfg = BinConfig(tile=tile, max_pairs=max_pairs, list_len=list_len)

    left_lists = bin_left(splats, wide_width, cam.height, bcfg, ranks)
    img_l, hits = render_tiles(left_lists, splats, width=cam.width,
                               height=cam.height, tile=tile, eye="left")
    right_lists = stereo_lists(left_lists, splats, ranks, tile=tile,
                               width=cam.width, n_cat=n_cat)
    img_r, _ = render_tiles(right_lists, splats, width=cam.width,
                            height=cam.height, tile=tile, eye="right")
    stats = alpha_skip_stats(left_lists, right_lists, hits, splats)
    return img_l, img_r, (splats, left_lists, right_lists, stats)


def render_stereo_reference(queue: Gaussians, rig: StereoRig):
    """Two fully independent eye renders (the BASE baseline of Fig. 16)."""
    cam = rig.left
    max_disp = rig.max_disparity_px()
    n_cat = n_categories(max_disp, 16)
    tiles_x_r = -(-cam.width // 16)
    wide = dataclasses.replace(cam, width=(tiles_x_r + n_cat - 1) * 16)
    splats = project(queue, rig, wide)
    img_l = render_reference(splats, width=cam.width, height=cam.height, eye="left")
    img_r = render_reference(splats, width=cam.width, height=cam.height, eye="right")
    return img_l, img_r
