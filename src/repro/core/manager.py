"""Runtime Gaussian management (paper §4.3).

The cloud keeps a management table tracking which Gaussians the client holds;
per LoD-sync (every `w` frames) it transmits only:

  * the **Δcut** — Gaussians newly needed and not cached on the client
    (attribute payload, compressed by repro.core.compression);
  * the **cut-membership delta** — ids entering/leaving the render queue
    (ids only; Fig. 7 temporal similarity makes this ~1% of the cut).

Both sides then run the *same* reuse-window eviction rule (w_r* = 32 syncs by
default) on identical inputs, so no eviction traffic is needed and the two
tables stay consistent — the GC-like co-design of the paper. The client
renders its exact received cut between syncs (DESIGN.md §7: with the radial
LoD metric the cut is orientation-free, so head rotation needs no new data).

State is a dense bitmap over padded node ids (5 bytes/node on the cloud —
~5 MB per million Gaussians), sharded with the tree on the cloud mesh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

ID_BYTES = 4          # plain 32-bit ids on the wire
ID_BYTES_DELTA = 2    # delta-coded ids (sorted ascending, varint-ish) — model
SYNC_HEADER_BYTES = 64
POSE_UPLINK_BYTES = 100  # client → cloud pose per frame (paper §2.1)
PAGE_HEADER_BYTES = 16  # per priority page of the paged multicast stream
#                         (page rank, row count, first gid, checksum)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ManagerState:
    """Cloud-side management table (the client mirrors it deterministically)."""

    client_has: jax.Array   # (N,) bool — which Gaussians the client stores
    last_used: jax.Array    # (N,) int32 — sync index when last in a cut
    cut_prev: jax.Array     # (N,) bool — previous cut (for membership deltas)

    @staticmethod
    def initial(n: int) -> "ManagerState":
        return ManagerState(
            client_has=jnp.zeros((n,), bool),
            last_used=jnp.full((n,), -(2**30), jnp.int32),
            cut_prev=jnp.zeros((n,), bool),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SyncPlan:
    """What one LoD sync transmits (masks over node ids + byte accounting)."""

    delta_data: jax.Array    # (N,) bool — Δcut: attribute payload to send
    cut_add: jax.Array       # (N,) bool — ids entering the render queue
    cut_remove: jax.Array    # (N,) bool — ids leaving the render queue
    evicted: jax.Array       # (N,) bool — dropped by the shared reuse rule
    n_delta: jax.Array       # () int32
    n_resident: jax.Array    # () int32 — client occupancy after the sync
    payload_bytes: jax.Array  # () float32 — given bytes/Gaussian (see below)

    def wire_bytes(self, bytes_per_gaussian: float) -> jax.Array:
        ids = (self.cut_add.sum() + self.cut_remove.sum()).astype(jnp.float32)
        return (self.n_delta.astype(jnp.float32) * bytes_per_gaussian
                + ids * ID_BYTES_DELTA + SYNC_HEADER_BYTES)


@functools.partial(jax.jit, static_argnames=())
def cloud_sync(state: ManagerState, cut_mask: jax.Array, t: jax.Array,
               w_star: jax.Array) -> Tuple[ManagerState, SyncPlan]:
    """One management-table update on the cloud (paper Fig. 9, left).

    t is the sync counter; w_star the shared reuse threshold (in syncs)."""
    delta_data = cut_mask & ~state.client_has
    cut_add = cut_mask & ~state.cut_prev
    cut_remove = state.cut_prev & ~cut_mask

    last_used = jnp.where(cut_mask, t, state.last_used)
    has = state.client_has | cut_mask
    evicted = has & ((t - last_used) > w_star)
    has = has & ~evicted

    new_state = ManagerState(client_has=has, last_used=last_used, cut_prev=cut_mask)
    plan = SyncPlan(
        delta_data=delta_data, cut_add=cut_add, cut_remove=cut_remove,
        evicted=evicted,
        n_delta=delta_data.sum().astype(jnp.int32),
        n_resident=has.sum().astype(jnp.int32),
        payload_bytes=jnp.float32(0.0),
    )
    return new_state, plan


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClientState:
    """Client-side mirror: reconstructs the same table from the wire data only
    (Δcut ids + cut add/remove ids) — used to *prove* consistency in tests."""

    has: jax.Array
    last_used: jax.Array
    cut: jax.Array  # current render queue (bool mask)

    @staticmethod
    def initial(n: int) -> "ClientState":
        return ClientState(
            has=jnp.zeros((n,), bool),
            last_used=jnp.full((n,), -(2**30), jnp.int32),
            cut=jnp.zeros((n,), bool),
        )


@functools.partial(jax.jit, static_argnames=())
def client_sync(state: ClientState, delta_data: jax.Array, cut_add: jax.Array,
                cut_remove: jax.Array, t: jax.Array, w_star: jax.Array
                ) -> ClientState:
    """Apply one received sync. Inputs are exactly what came off the wire."""
    cut = (state.cut | cut_add) & ~cut_remove
    has = state.has | delta_data          # insert received Gaussians
    last_used = jnp.where(cut, t, state.last_used)
    has = has | cut                       # cut members are resident by definition
    keep = (t - last_used) <= w_star
    has = has & keep
    return ClientState(has=has, last_used=last_used, cut=cut)


def gather_payload(tree_gaussians, delta_mask: jax.Array, budget: int):
    """Compact Δcut ids (sorted, -1 padded) for payload gather/compression."""
    (ids,) = jnp.nonzero(delta_mask, size=budget, fill_value=-1)
    count = delta_mask.sum().astype(jnp.int32)
    return ids.astype(jnp.int32), count


# ---------------------------------------------------------------------------
# batched multi-client tables
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def batched_cloud_sync(states: ManagerState, cut_masks: jax.Array,
                       ts: jax.Array, w_star: jax.Array
                       ) -> Tuple[ManagerState, SyncPlan]:
    """`cloud_sync` vmapped over B clients (one table per headset, one shared
    tree). `states` leaves are (B, N); cut_masks (B, N); ts (B,). The reuse
    window is shared. Returns batched (ManagerState, SyncPlan) — each client's
    slice is bit-identical to a sequential per-client `cloud_sync`."""
    return jax.vmap(cloud_sync, in_axes=(0, 0, 0, None))(
        states, cut_masks, ts, w_star)


def batched_wire_bytes(plan: SyncPlan, bytes_per_gaussian: float, *,
                       shared_payload: bool = False,
                       active=None, delivered=None,
                       client_pages=None) -> jax.Array:
    """(B,) per-client downlink bytes for a batched SyncPlan.

    (`SyncPlan.wire_bytes` reduces over every axis and is only correct for the
    unbatched case.)

    shared_payload=False — the legacy unicast format: every client receives
    its own encoded Δcut stream (payload bytes ∝ its n_delta; Δ row ids are
    implicit, recomputable client-side from cut_add & ~has).

    shared_payload=True — the encode-once fleet format
    (repro.serve.delta_path): the union Δcut is multicast ONCE as
    [union gids + encoded rows]; clients filter the stream themselves, so the
    only per-client traffic stays the membership ids + header. Each shared
    row's cost (attributes + its id) is split evenly across the clients that
    requested it, so per-client figures still sum to the fleet total:
    Σ_b bytes_b = U·(bytes_per_gaussian + ID_BYTES_DELTA) + Σ_b(ids_b·2 + hdr)
    — downlink grows with *unique* Gaussians, not with B. Crossover: a row
    with a SINGLE requester costs ID_BYTES_DELTA more than on the unicast
    path (whose Δ ids are implicit), so a fully disjoint fleet pays a small
    id overhead; sharing by ≥2 clients is always a win.

    `delivered` is an optional (B, N) bool mask of the rows each client
    ACTUALLY ingested this sync (`DeltaBatch.delivered` from the paged
    stream, repro.serve.delta_path). Without it the shared split charges
    `plan.delta_data` — every requested row, INCLUDING rows a tight
    `delta_budget` paged out of the stream; pass it so deferred rows cost
    nothing until the sync that ships them (the silent-overcharge bug the
    paged stream fixes). `client_pages` ((B,) int32, same source) adds the
    per-page framing: PAGE_HEADER_BYTES for each priority page the client
    pulled rows from.

    `active` is an optional (B,) bool slot mask (ragged fleets,
    repro.serve.fleet): an inactive slot receives NOTHING — not even the
    sync header — so its row is exactly 0.0 bytes, and inactive slots are
    excluded from the shared-row requester split."""
    delta = plan.delta_data if delivered is None else delivered
    if active is not None:
        delta = delta & active[:, None]
    ids = (plan.cut_add.sum(axis=1) + plan.cut_remove.sum(axis=1)
           ).astype(jnp.float32)
    base = ids * ID_BYTES_DELTA + SYNC_HEADER_BYTES
    if not shared_payload:
        out = plan.n_delta.astype(jnp.float32) * bytes_per_gaussian + base
    else:
        share = delta.sum(axis=0)                            # (N,) requesters
        frac = jnp.where(delta,
                         1.0 / jnp.maximum(share, 1)[None, :], 0.0).sum(axis=1)
        out = frac * (bytes_per_gaussian + ID_BYTES_DELTA) + base
        if client_pages is not None:
            out = out + client_pages.astype(jnp.float32) * PAGE_HEADER_BYTES
    if active is not None:
        out = jnp.where(active, out, 0.0)
    return out


# ---------------------------------------------------------------------------
# numpy reference (independent oracle for the property tests)
# ---------------------------------------------------------------------------


def reference_manager_np(cut_masks: np.ndarray, w_star: int):
    """Straight-line trace of the paper's table semantics over a cut sequence.

    cut_masks: (F, N) bool. Returns per-sync (delta_counts, resident_counts)."""
    f, n = cut_masks.shape
    has = np.zeros(n, bool)
    last = np.full(n, -(2**30), np.int64)
    deltas, residents = [], []
    for t in range(f):
        cut = cut_masks[t]
        deltas.append(int((cut & ~has).sum()))
        last[cut] = t
        has |= cut
        has &= (t - last) <= w_star
        residents.append(int(has.sum()))
    return np.asarray(deltas), np.asarray(residents)
