"""Pinhole + rectified stereo camera model, and VR head trajectories.

Conventions: world is Z-up for the city scene; camera looks along +z of its
own frame (OpenCV style: x right, y down, z forward). `c2w` is a 3x3 rotation
whose columns are the camera axes expressed in world coordinates.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Camera:
    """Single pinhole camera.

    pos:    (3,) world position
    rot:    (3, 3) camera-to-world rotation (columns = cam axes in world)
    focal:  scalar focal length in pixels (fx == fy)
    width, height: image size in pixels (static python ints)
    near, far: clip planes (meters)
    """

    pos: jax.Array
    rot: jax.Array
    focal: jax.Array
    width: int = dataclasses.field(metadata=dict(static=True))
    height: int = dataclasses.field(metadata=dict(static=True))
    near: float = dataclasses.field(default=0.2, metadata=dict(static=True))
    far: float = dataclasses.field(default=1000.0, metadata=dict(static=True))
    # principal point is EXPLICIT (static) so widening the image plane for the
    # shared-FoV stereo preprocessing does NOT shift the projection center.
    cx: float = dataclasses.field(default=-1.0, metadata=dict(static=True))
    cy: float = dataclasses.field(default=-1.0, metadata=dict(static=True))

    def world_to_cam(self, p: jax.Array) -> jax.Array:
        """(N,3) world → camera frame."""
        return (p - self.pos) @ self.rot  # rot columns are axes → p·R == R^T p

    def translated(self, offset_world: jax.Array) -> "Camera":
        return dataclasses.replace(self, pos=self.pos + offset_world)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StereoRig:
    """Rectified stereo pair: right camera = left translated by `baseline`
    along the camera x axis. Same rotation → depth (z) identical for both eyes,
    disparity = baseline * focal / depth (triangulation, paper §4.4)."""

    left: Camera
    baseline: float = dataclasses.field(default=0.06, metadata=dict(static=True))

    @property
    def right(self) -> Camera:
        offset = self.left.rot[:, 0] * self.baseline  # cam x-axis in world
        return self.left.translated(offset)

    def max_disparity_px(self, near: float | None = None) -> float:
        """Disparity is bounded by the near plane: d = B f / z <= B f / near."""
        near = self.left.near if near is None else near
        return float(self.baseline) * float(self.left.focal) / near

    def widened_left(self, max_disparity_px: int) -> Camera:
        """Widened-FoV camera used for shared preprocessing/binning (paper
        Fig. 13): covers the union of both eyes' frusta by extending the left
        camera's image plane to the right by `max_disparity_px` columns.

        A point at pixel x_R in the right image sits at x_L = x_R + d with
        d in [0, max_disp), so the union of both image x-ranges, expressed in
        LEFT-camera pixel coordinates, is [0, W + max_disp)."""
        return dataclasses.replace(self.left, width=self.left.width + int(max_disparity_px))


def look_at(pos, target, up=(0.0, 0.0, 1.0)) -> np.ndarray:
    """Camera-to-world rotation with +z toward target, x right, y down."""
    pos = np.asarray(pos, np.float64)
    fwd = np.asarray(target, np.float64) - pos
    fwd /= np.linalg.norm(fwd) + 1e-12
    upv = np.asarray(up, np.float64)
    right = np.cross(fwd, upv)
    nr = np.linalg.norm(right)
    if nr < 1e-6:  # looking straight along up
        right = np.array([1.0, 0.0, 0.0])
    else:
        right /= nr
    down = np.cross(fwd, right)
    return np.stack([right, down, fwd], axis=1).astype(np.float32)


def make_camera(pos, target, focal_px: float, width: int, height: int,
                near: float = 0.2, far: float = 2000.0) -> Camera:
    return Camera(
        pos=jnp.asarray(pos, jnp.float32),
        rot=jnp.asarray(look_at(pos, target)),
        focal=jnp.asarray(focal_px, jnp.float32),
        width=width,
        height=height,
        near=near,
        far=far,
        cx=width / 2.0,
        cy=height / 2.0,
    )


# VR resolutions (per eye). Quest-3 class default, per the paper's setup.
VR_EYE_RES = (2064, 2208)


@dataclasses.dataclass(frozen=True)
class TrajectoryConfig:
    """Street-level VR walk with head bob and smooth yaw — 90 FPS samples."""

    fps: float = 90.0
    speed_mps: float = 1.4          # walking speed
    yaw_rate_dps: float = 12.0      # slow look-around
    head_bob_hz: float = 1.8
    head_bob_m: float = 0.015
    eye_height: float = 1.7
    seed: int = 0


def walk_trajectory(cfg: TrajectoryConfig, n_frames: int, extent_xy: Tuple[float, float],
                    focal_px: float = 1400.0, width: int = 512, height: int = 512,
                    ) -> Iterator[Camera]:
    """Generate a smooth street-level camera path inside the scene extent."""
    rng = np.random.default_rng(cfg.seed)
    ex, ey = extent_xy
    pos = np.array([ex * 0.3, ey * 0.3, cfg.eye_height])
    heading = rng.uniform(0, 2 * np.pi)
    dt = 1.0 / cfg.fps
    for t in range(n_frames):
        heading += np.deg2rad(cfg.yaw_rate_dps) * dt * np.sin(0.2 * t * dt * 2 * np.pi + 1.0)
        step = cfg.speed_mps * dt
        pos = pos + step * np.array([np.cos(heading), np.sin(heading), 0.0])
        # reflect at scene borders
        for i, e in enumerate((ex, ey)):
            if pos[i] < 0.05 * e or pos[i] > 0.95 * e:
                heading += np.pi / 2
                pos[i] = np.clip(pos[i], 0.05 * e, 0.95 * e)
        bob = cfg.head_bob_m * np.sin(2 * np.pi * cfg.head_bob_hz * t * dt)
        p = pos + np.array([0, 0, bob])
        target = p + np.array([np.cos(heading), np.sin(heading), -0.05])
        yield make_camera(p, target, focal_px, width, height)
