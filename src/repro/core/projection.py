"""Shared stereo preprocessing: EWA splat projection (paper Fig. 13 left).

One pass over the render queue serves BOTH eyes: projection happens on the
*widened* left camera (covers the union of the two frusta); the right-eye
splat center is obtained later by the triangulation shift x_R = x_L − B·f/z.
Depth (camera z) is identical across a rectified pair, so one depth sort
serves both eyes. View-dependent SH color is evaluated per eye inside this
same pass (two cheap SH dots; see DESIGN.md §2 — required for bit-accuracy).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.camera import Camera, StereoRig
from repro.core.gaussians import Gaussians, covariance, eval_sh

COV_BLUR = 0.3        # low-pass dilation added to the 2D covariance (3DGS std)
ALPHA_MIN = 1.0 / 255.0
ALPHA_MAX = 0.99


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Splats:
    """Projected 2D Gaussians in widened-left pixel coordinates."""

    mean2d: jax.Array     # (M, 2)
    depth: jax.Array      # (M,) camera z (same for both eyes)
    conic: jax.Array      # (M, 3) inverse-covariance (A, B, C): [[A,B],[B,C]]
    ext: jax.Array        # (M, 2) conservative half-extents of the α≥α_min ellipse
    color_l: jax.Array    # (M, 3)
    color_r: jax.Array    # (M, 3)
    opacity: jax.Array    # (M,)
    disparity: jax.Array  # (M,) B·f/z ≥ 0
    visible: jax.Array    # (M,) bool

    @property
    def m(self) -> int:
        return self.mean2d.shape[0]


def project(g: Gaussians, rig: StereoRig, wide: Camera) -> Splats:
    """EWA projection of the render queue onto the widened camera."""
    cam = wide
    t = cam.world_to_cam(g.mu)                      # (M, 3)
    z = t[:, 2]
    f = cam.focal
    inv_z = 1.0 / jnp.maximum(z, 1e-6)
    mean2d = jnp.stack([f * t[:, 0] * inv_z + cam.cx,
                        f * t[:, 1] * inv_z + cam.cy], axis=-1)

    # Jacobian of the perspective map at the splat center
    zero = jnp.zeros_like(z)
    j = jnp.stack([
        jnp.stack([f * inv_z, zero, -f * t[:, 0] * inv_z * inv_z], -1),
        jnp.stack([zero, f * inv_z, -f * t[:, 1] * inv_z * inv_z], -1),
    ], axis=-2)                                      # (M, 2, 3)
    w = cam.rot.T                                    # world→cam
    cov3 = covariance(g)                             # (M, 3, 3)
    jw = j @ w
    cov2 = jw @ cov3 @ jnp.swapaxes(jw, -1, -2)      # (M, 2, 2)
    a = cov2[:, 0, 0] + COV_BLUR
    b = cov2[:, 0, 1]
    c = cov2[:, 1, 1] + COV_BLUR

    det = a * c - b * b
    det = jnp.maximum(det, 1e-12)
    conic = jnp.stack([c / det, -b / det, a / det], axis=-1)

    # conservative AABB of the α ≥ α_min iso-ellipse (identical for both eyes)
    tau = 2.0 * jnp.log(jnp.maximum(g.opacity, ALPHA_MIN) / ALPHA_MIN)
    ext = jnp.sqrt(jnp.maximum(tau[:, None], 0.0)
                   * jnp.stack([a, c], axis=-1))     # (M, 2)

    # per-eye view-dependent color
    dir_l = g.mu - rig.left.pos
    dir_r = g.mu - rig.right.pos
    dir_l = dir_l / (jnp.linalg.norm(dir_l, axis=-1, keepdims=True) + 1e-12)
    dir_r = dir_r / (jnp.linalg.norm(dir_r, axis=-1, keepdims=True) + 1e-12)
    color_l = eval_sh(g.sh, dir_l)
    color_r = eval_sh(g.sh, dir_r)

    disparity = rig.baseline * f * inv_z

    visible = ((z > cam.near) & (z < cam.far)
               & (g.opacity > ALPHA_MIN)
               & (mean2d[:, 0] + ext[:, 0] >= 0.0)
               & (mean2d[:, 0] - ext[:, 0] <= cam.width)
               & (mean2d[:, 1] + ext[:, 1] >= 0.0)
               & (mean2d[:, 1] - ext[:, 1] <= cam.height))

    return Splats(mean2d=mean2d, depth=z, conic=conic, ext=ext,
                  color_l=color_l, color_r=color_r, opacity=g.opacity,
                  disparity=disparity, visible=visible)


def depth_ranks(s: Splats) -> jax.Array:
    """(M,) front-to-back rank shared by both eyes (invisible rank last).

    Ties broken by index (stable) so blend order is deterministic."""
    key = jnp.where(s.visible, s.depth, jnp.inf)
    order = jnp.argsort(key, stable=True)
    ranks = jnp.zeros((s.m,), jnp.int32).at[order].set(
        jnp.arange(s.m, dtype=jnp.int32))
    return ranks
