"""LoD tree construction + slab layout for streaming traversal.

The paper (§2.2, §4.2) uses an irregular LoD tree — every node is one Gaussian
with an arbitrary number of children; finer detail lives deeper. Traversal
must find the "cut": nodes whose projected size drops below τ while their
parent's is still above (leaves are selected as soon as their parent is
expanded).

TPU-oriented layout (DESIGN.md §2):
  * the tree is partitioned offline at level P into `Ns` balanced subtrees;
  * the *top-tree* (levels < P) is small and laid out level-major;
  * each subtree is a fixed-size *slab* of `S` nodes (BFS order inside the
    slab, padded), so the per-frame sweep is a fully streaming, regular scan —
    the TPU analogue of the paper's "blocks that fit in GPU shared memory";
  * parent pointers inside a slab are slab-local (always a smaller index), and
    the slab root's parent lives in the top-tree — so a shard holding whole
    slabs never needs remote parents (cloud-side sharding, DESIGN.md §2).

Construction is an offline numpy step (vectorized with `np.add.reduceat` and
batched `eigh`, so million-leaf city scenes build in seconds).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gaussians import Gaussians, quat_to_rotmat

K_SIGMA = 3.0  # world radius of a Gaussian = K_SIGMA * max stddev


@dataclasses.dataclass(frozen=True)
class TreeMeta:
    """Static layout metadata (python ints — safe to close over in jit)."""

    T: int            # top-tree node count (levels < P)
    Ns: int           # number of subtrees
    S: int            # padded slab size
    P: int            # partition level (subtree roots live at level P)
    depth: int        # max level (root = 0)
    n_real: int       # real (non-padding) node count
    n_leaves: int
    top_level_offsets: Tuple[int, ...]  # len P+1; top nodes of level l are [off[l], off[l+1])
    slab_max_depth: int                 # max levels inside a slab (root = 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LodTree:
    """City-scale Gaussian LoD tree in top-tree + slab layout.

    gaussians: (N_pad,) Gaussian SoA; rows [0,T) are top-tree nodes, row
               T + s*S + j is slab s local node j. Padding rows are zeros.
    size:      (N_pad,) world-space bounding radius per node.
    top_parent:(T,) parent index within top-tree (-1 for root).
    top_is_leaf: (T,) bool.
    slab_parent: (Ns, S) slab-local parent index (-1 for the slab root).
    slab_is_leaf, slab_valid: (Ns, S) bool.
    slab_level: (Ns, S) int32 level inside the slab (root = 0; padding = big).
    slab_root_parent_top: (Ns,) index into top-tree of each slab root's parent.
    meta: TreeMeta (static).
    """

    gaussians: Gaussians
    size: jax.Array
    top_parent: jax.Array
    top_is_leaf: jax.Array
    slab_parent: jax.Array
    slab_is_leaf: jax.Array
    slab_valid: jax.Array
    slab_level: jax.Array
    slab_root_parent_top: jax.Array
    meta: TreeMeta = dataclasses.field(metadata=dict(static=True))

    # -- global-id helpers ---------------------------------------------------
    @property
    def n_pad(self) -> int:
        return self.meta.T + self.meta.Ns * self.meta.S

    def slab_gid(self, s, j):
        return self.meta.T + s * self.meta.S + j

    def top_mu(self) -> jax.Array:
        return self.gaussians.mu[: self.meta.T]

    def top_size(self) -> jax.Array:
        return self.size[: self.meta.T]

    def slab_mu(self) -> jax.Array:
        m = self.meta
        return self.gaussians.mu[m.T :].reshape(m.Ns, m.S, 3)

    def slab_size(self) -> jax.Array:
        m = self.meta
        return self.size[m.T :].reshape(m.Ns, m.S)

    def valid_mask(self) -> jax.Array:
        """(N_pad,) bool — real nodes."""
        m = self.meta
        return jnp.concatenate(
            [jnp.ones((m.T,), bool), self.slab_valid.reshape(-1)], axis=0
        )

    def node_levels(self) -> jax.Array:
        """(N_pad,) int32 — global tree depth of every padded node id (root
        = 0; padding rows get a huge sentinel so they sort last). Top-tree
        rows read their level off `top_level_offsets`; slab rows are the
        partition level P plus their slab-local level. This is the
        coarse-first priority key of the paged Δ-union stream
        (repro.serve.delta_path): low depth = coarse LoD = ships first."""
        m = self.meta
        bounds = np.asarray(m.top_level_offsets[1:], np.int64)  # ends of 0..P-1
        top = np.searchsorted(bounds, np.arange(m.T), side="right")
        top_lv = jnp.asarray(top.astype(np.int32))
        slab_lv = jnp.minimum(self.slab_level, jnp.int32(2**30 - m.P)) + m.P
        return jnp.concatenate([top_lv, slab_lv.reshape(-1)], axis=0)


# ---------------------------------------------------------------------------
# Offline construction
# ---------------------------------------------------------------------------


def _morton_order(mu: np.ndarray, bits: int = 10) -> np.ndarray:
    """Z-order sort indices for spatial grouping."""
    lo, hi = mu.min(0), mu.max(0)
    q = ((mu - lo) / np.maximum(hi - lo, 1e-9) * ((1 << bits) - 1)).astype(np.uint64)
    code = np.zeros(mu.shape[0], np.uint64)
    for b in range(bits):
        for a in range(3):
            code |= ((q[:, a] >> np.uint64(b)) & np.uint64(1)) << np.uint64(3 * b + a)
    return np.argsort(code, kind="stable")


def _rotmat_to_quat(r: np.ndarray) -> np.ndarray:
    """Batched (M,3,3) rotation → (M,4) wxyz quaternion (numerically safe)."""
    m = r
    t = 1.0 + m[:, 0, 0] + m[:, 1, 1] + m[:, 2, 2]
    q = np.zeros((r.shape[0], 4), np.float64)
    safe = t > 1e-6
    s = np.sqrt(np.where(safe, t, 1.0)) * 2
    q[safe, 0] = 0.25 * s[safe]
    q[safe, 1] = (m[safe, 2, 1] - m[safe, 1, 2]) / s[safe]
    q[safe, 2] = (m[safe, 0, 2] - m[safe, 2, 0]) / s[safe]
    q[safe, 3] = (m[safe, 1, 0] - m[safe, 0, 1]) / s[safe]
    # fallback for near-180° rotations: pick largest diagonal
    bad = ~safe
    if bad.any():
        mb = m[bad]
        i = np.argmax(np.stack([mb[:, 0, 0], mb[:, 1, 1], mb[:, 2, 2]], 1), 1)
        qb = np.zeros((mb.shape[0], 4))
        for k in range(mb.shape[0]):
            a = i[k]
            b_, c = (a + 1) % 3, (a + 2) % 3
            sk = np.sqrt(max(1.0 + mb[k, a, a] - mb[k, b_, b_] - mb[k, c, c], 1e-12)) * 2
            qb[k, 1 + a] = 0.25 * sk
            qb[k, 0] = (mb[k, c, b_] - mb[k, b_, c]) / sk
            qb[k, 1 + b_] = (mb[k, b_, a] + mb[k, a, b_]) / sk
            qb[k, 1 + c] = (mb[k, c, a] + mb[k, a, c]) / sk
        q[bad] = qb
    n = np.linalg.norm(q, axis=1, keepdims=True)
    return (q / np.maximum(n, 1e-12)).astype(np.float32)


def _merge_round(mu, log_scale, quat, opacity, sh, size, rng, b_lo, b_hi):
    """Merge consecutive groups of children into parent Gaussians (one round).

    Returns parent arrays + `group_id` per child (index of its parent)."""
    n = mu.shape[0]
    # group boundaries with random branching factor
    branches = rng.integers(b_lo, b_hi + 1, size=n)  # oversampled
    ends = np.cumsum(branches)
    m = int(np.searchsorted(ends, n))
    starts = np.concatenate([[0], ends[:m]])
    starts = starts[starts < n]
    if len(starts) == 0 or starts[0] != 0:
        starts = np.concatenate([[0], starts])
    starts = np.unique(starts)
    group_id = np.zeros(n, np.int64)
    group_id[starts[1:]] = 1
    group_id = np.cumsum(group_id)
    n_groups = int(group_id[-1]) + 1

    w = opacity * np.exp(log_scale).prod(1)  # opacity-volume weights
    w = np.maximum(w, 1e-8)
    sw = np.add.reduceat(w, starts)
    p_mu = np.add.reduceat(w[:, None] * mu, starts) / sw[:, None]

    # covariance merge: Σ_p = Σ w (Σ_c + d dᵀ) / Σ w
    rot = np.asarray(quat_to_rotmat(jnp.asarray(quat)))
    sdiag = np.exp(log_scale)
    rs = rot * sdiag[:, None, :]
    cov = rs @ np.swapaxes(rs, 1, 2)
    d = mu - p_mu[group_id]
    outer = d[:, :, None] * d[:, None, :]
    p_cov = np.add.reduceat(w[:, None, None] * (cov + outer), starts) / sw[:, None, None]
    p_cov = 0.5 * (p_cov + np.swapaxes(p_cov, 1, 2))  # symmetrize
    evals, evecs = np.linalg.eigh(p_cov)
    evals = np.maximum(evals, 1e-10)
    # ensure right-handed rotation
    det = np.linalg.det(evecs)
    evecs[:, :, 0] *= np.where(det < 0, -1.0, 1.0)[:, None]
    p_quat = _rotmat_to_quat(evecs)
    p_log_scale = 0.5 * np.log(evals).astype(np.float32)

    p_opacity = (np.add.reduceat(w * opacity, starts) / sw).astype(np.float32)
    p_sh = (np.add.reduceat(w[:, None, None] * sh, starts) / sw[:, None, None]).astype(np.float32)

    # bounding-sphere union radius
    dist = np.linalg.norm(d, axis=1)
    p_size = np.maximum.reduceat(dist + size, starts).astype(np.float32)

    return (p_mu.astype(np.float32), p_log_scale, p_quat, p_opacity, p_sh, p_size,
            group_id, n_groups)


def build_lod_tree(
    leaves: Gaussians,
    *,
    branching: Tuple[int, int] = (3, 7),
    target_subtrees: int = 64,
    slab_pad_to: int = 8,
    seed: int = 0,
) -> LodTree:
    """Agglomerate leaves bottom-up and emit the top-tree + slab layout."""
    rng = np.random.default_rng(seed)
    mu = np.asarray(leaves.mu, np.float64)
    log_scale = np.asarray(leaves.log_scale, np.float64)
    quat = np.asarray(leaves.quat, np.float32)
    opacity = np.asarray(leaves.opacity, np.float64)
    sh = np.asarray(leaves.sh, np.float64)
    n0 = mu.shape[0]
    order = _morton_order(mu.astype(np.float32))
    mu, log_scale, quat, opacity, sh = (
        mu[order], log_scale[order], quat[order], opacity[order], sh[order])
    size = (K_SIGMA * np.exp(log_scale).max(1)).astype(np.float32)

    # rounds[k] = dict of node arrays created at round k (k=0 → leaves)
    rounds = [dict(mu=mu.astype(np.float32), log_scale=log_scale.astype(np.float32),
                   quat=quat, opacity=opacity.astype(np.float32),
                   sh=sh.astype(np.float32), size=size,
                   parent_in_next=None, is_leaf=np.ones(n0, bool))]
    cur = rounds[0]
    while cur["mu"].shape[0] > 1:
        (p_mu, p_ls, p_q, p_op, p_sh, p_size, group_id, _ng) = _merge_round(
            cur["mu"].astype(np.float64), cur["log_scale"].astype(np.float64),
            cur["quat"], cur["opacity"].astype(np.float64),
            cur["sh"].astype(np.float64), cur["size"], rng, *branching)
        cur["parent_in_next"] = group_id
        nxt = dict(mu=p_mu, log_scale=p_ls, quat=p_q, opacity=p_op, sh=p_sh,
                   size=p_size, parent_in_next=None,
                   is_leaf=np.zeros(p_mu.shape[0], bool))
        rounds.append(nxt)
        cur = nxt

    n_rounds = len(rounds)
    depth = n_rounds - 1  # root level is 0, leaves at `depth`

    # ---- global node table (level = depth - round) -------------------------
    counts = [r["mu"].shape[0] for r in rounds]
    offs = np.concatenate([[0], np.cumsum(counts[::-1])])  # level-major: level 0 first
    n_real = int(offs[-1])

    def level_of_round(k):
        return depth - k

    # global index of node i in round k
    def gidx(k, i):
        lvl = level_of_round(k)
        return offs[lvl] + i

    g_mu = np.zeros((n_real, 3), np.float32)
    g_ls = np.zeros((n_real, 3), np.float32)
    g_q = np.zeros((n_real, 4), np.float32)
    g_op = np.zeros((n_real,), np.float32)
    g_sh = np.zeros((n_real,) + rounds[0]["sh"].shape[1:], np.float32)
    g_size = np.zeros((n_real,), np.float32)
    g_parent = np.full((n_real,), -1, np.int64)
    g_level = np.zeros((n_real,), np.int32)
    g_is_leaf = np.zeros((n_real,), bool)

    for k, r in enumerate(rounds):
        lvl = level_of_round(k)
        sl = slice(offs[lvl], offs[lvl] + counts[k])
        g_mu[sl] = r["mu"]
        g_ls[sl] = r["log_scale"]
        g_q[sl] = r["quat"]
        g_op[sl] = r["opacity"]
        g_sh[sl] = r["sh"]
        g_size[sl] = r["size"]
        g_level[sl] = lvl
        g_is_leaf[sl] = r["is_leaf"]
        if r["parent_in_next"] is not None:
            g_parent[sl] = offs[lvl - 1] + r["parent_in_next"]

    child_count = np.zeros(n_real, np.int64)
    np.add.at(child_count, g_parent[g_parent >= 0], 1)
    g_is_leaf = child_count == 0

    # ---- choose partition level P ------------------------------------------
    level_counts = [offs[l + 1] - offs[l] for l in range(depth + 1)]
    P = 1
    for l in range(1, depth + 1):
        if level_counts[l] >= target_subtrees or l == depth:
            P = l
            break
    P = max(1, min(P, depth))  # slab roots at level P; top-tree holds levels < P

    T = int(offs[P])
    roots = np.arange(offs[P], offs[P + 1]) if P < depth + 1 else np.array([], np.int64)
    Ns = len(roots)

    # subtree id per node (levels >= P): propagate down
    sub_of = np.full(n_real, -1, np.int64)
    sub_of[roots] = np.arange(Ns)
    for l in range(P + 1, depth + 1):
        sl = slice(offs[l], offs[l + 1])
        sub_of[sl] = sub_of[g_parent[sl]]

    # slab-local BFS order: nodes of each subtree sorted by (level, global idx)
    members = np.where(sub_of >= 0)[0]
    order2 = np.lexsort((members, g_level[members], sub_of[members]))
    members = members[order2]
    sub_sorted = sub_of[members]
    sub_starts = np.searchsorted(sub_sorted, np.arange(Ns))
    sub_counts = np.searchsorted(sub_sorted, np.arange(Ns) + 1) - sub_starts
    S_raw = int(sub_counts.max()) if Ns else 1
    S = int(np.ceil(S_raw / slab_pad_to) * slab_pad_to)

    # local index of each member node within its slab
    local_idx = np.arange(len(members)) - sub_starts[sub_sorted]
    loc_of_global = np.full(n_real, -1, np.int64)
    loc_of_global[members] = local_idx

    slab_shape = (Ns, S)
    s_mu = np.zeros(slab_shape + (3,), np.float32)
    s_ls = np.zeros(slab_shape + (3,), np.float32)
    s_q = np.zeros(slab_shape + (4,), np.float32)
    s_q[..., 0] = 1.0
    s_op = np.zeros(slab_shape, np.float32)
    s_sh = np.zeros(slab_shape + g_sh.shape[1:], np.float32)
    s_size = np.zeros(slab_shape, np.float32)
    s_parent = np.full(slab_shape, -1, np.int32)
    s_level = np.full(slab_shape, 2**30, np.int32)
    s_is_leaf = np.zeros(slab_shape, bool)
    s_valid = np.zeros(slab_shape, bool)
    root_parent_top = np.zeros(Ns, np.int32)

    rows = sub_sorted
    cols = local_idx
    s_mu[rows, cols] = g_mu[members]
    s_ls[rows, cols] = g_ls[members]
    s_q[rows, cols] = g_q[members]
    s_op[rows, cols] = g_op[members]
    s_sh[rows, cols] = g_sh[members]
    s_size[rows, cols] = g_size[members]
    s_level[rows, cols] = g_level[members] - P
    s_is_leaf[rows, cols] = g_is_leaf[members]
    s_valid[rows, cols] = True
    # slab-local parents (root keeps -1)
    par = g_parent[members]
    non_root = g_level[members] > P
    s_parent[rows[non_root], cols[non_root]] = loc_of_global[par[non_root]].astype(np.int32)
    root_parent_top[:] = g_parent[roots].astype(np.int32) if P >= 1 else -1

    slab_max_depth = int((g_level[members].max() - P) if len(members) else 0)

    # ---- pack gaussians: [top ; slabs flattened] ---------------------------
    n_pad = T + Ns * S
    f_mu = np.zeros((n_pad, 3), np.float32)
    f_ls = np.full((n_pad, 3), np.log(1e-4), np.float32)
    f_q = np.zeros((n_pad, 4), np.float32)
    f_q[:, 0] = 1.0
    f_op = np.zeros((n_pad,), np.float32)
    f_sh = np.zeros((n_pad,) + g_sh.shape[1:], np.float32)
    f_size = np.zeros((n_pad,), np.float32)

    f_mu[:T] = g_mu[:T]
    f_ls[:T] = g_ls[:T]
    f_q[:T] = g_q[:T]
    f_op[:T] = g_op[:T]
    f_sh[:T] = g_sh[:T]
    f_size[:T] = g_size[:T]
    f_mu[T:] = s_mu.reshape(-1, 3)
    f_ls[T:] = s_ls.reshape(-1, 3)
    f_q[T:] = s_q.reshape(-1, 4)
    f_op[T:] = s_op.reshape(-1)
    f_sh[T:] = s_sh.reshape((-1,) + g_sh.shape[1:])
    f_size[T:] = s_size.reshape(-1)

    # top-tree levels are 0..P-1; offs[P] == T
    top_level_offsets = tuple(int(x) for x in offs[: P + 1])

    meta = TreeMeta(
        T=T, Ns=Ns, S=S, P=P, depth=depth, n_real=n_real, n_leaves=n0,
        top_level_offsets=top_level_offsets, slab_max_depth=slab_max_depth,
    )
    return LodTree(
        gaussians=Gaussians(
            mu=jnp.asarray(f_mu), log_scale=jnp.asarray(f_ls), quat=jnp.asarray(f_q),
            opacity=jnp.asarray(f_op), sh=jnp.asarray(f_sh)),
        size=jnp.asarray(f_size),
        top_parent=jnp.asarray(g_parent[:T].astype(np.int32)),
        top_is_leaf=jnp.asarray(g_is_leaf[:T]),
        slab_parent=jnp.asarray(s_parent),
        slab_is_leaf=jnp.asarray(s_is_leaf),
        slab_valid=jnp.asarray(s_valid),
        slab_level=jnp.asarray(s_level),
        slab_root_parent_top=jnp.asarray(root_parent_top),
        meta=meta,
    )
