"""Tile rasterization — legacy import shim over the `repro.render` subsystem.

The implementations moved to `repro.render.stages` (XLA renderers) and
`repro.render.common` (shared eye-view/α math) as part of the render-subsystem
extraction; this module re-exports them so existing imports keep working:

    repro.core.raster.render_tiles      -> repro.render.stages.render_tiles
    repro.core.raster.render_reference  -> repro.render.stages.render_reference
    repro.core.raster.eye_views         -> repro.render.common.eye_views
    repro.core.raster._alpha            -> repro.render.common.pixel_alpha
"""

from __future__ import annotations

from repro.render.common import eye_views, pixel_alpha
from repro.render.stages import render_reference, render_tiles

_alpha = pixel_alpha

__all__ = ["eye_views", "pixel_alpha", "_alpha", "render_tiles",
           "render_reference"]
