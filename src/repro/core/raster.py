"""Tile rasterization — pure-JAX client path (oracle-consistent).

`render_tiles` consumes depth-ordered per-tile lists; `render_reference`
blends *all* splats per pixel in global depth order with no tiling at all —
the independent oracle. Because the α_min threshold zeroes every contribution
the binning could have culled (the list AABB is the α≥α_min iso-ellipse
bound), the two produce bitwise-identical images; tests assert exact
equality. The Pallas kernel (repro.kernels.rasterize) adds per-tile early
termination on top of the same math."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.binning import BinConfig, TileLists
from repro.core.projection import ALPHA_MAX, ALPHA_MIN, Splats


def eye_views(s: Splats, eye: str) -> Tuple[jax.Array, jax.Array]:
    """(means, colors) for the requested eye. Right = triangulation shift."""
    if eye == "left":
        return s.mean2d, s.color_l
    shift = jnp.stack([s.disparity, jnp.zeros_like(s.disparity)], -1)
    return s.mean2d - shift, s.color_r


def _alpha(px: jax.Array, mean: jax.Array, conic: jax.Array, opa: jax.Array
           ) -> jax.Array:
    """α of one splat at pixel centers px (..., 2)."""
    d = px - mean
    power = 0.5 * (conic[0] * d[..., 0] ** 2
                   + 2.0 * conic[1] * d[..., 0] * d[..., 1]
                   + conic[2] * d[..., 1] ** 2)
    a = opa * jnp.exp(-power)
    a = jnp.minimum(a, ALPHA_MAX)
    return jnp.where(a >= ALPHA_MIN, a, 0.0)


@functools.partial(jax.jit, static_argnames=("width", "height", "tile", "eye"))
def render_tiles(lists: TileLists, s: Splats, *, width: int, height: int,
                 tile: int, eye: str) -> Tuple[jax.Array, jax.Array]:
    """Render from per-tile lists. Returns (image (H,W,3), alpha_hit (n_tiles, L)).

    alpha_hit[t, i] — entry i of tile t passed the α-check at ≥1 pixel; this is
    exactly what the paper's SRU forwards to the stereo buffer."""
    means, colors = eye_views(s, eye)
    tiles_x, tiles_y = lists.tiles_x, lists.tiles_y

    ty, tx = jnp.meshgrid(jnp.arange(tiles_y), jnp.arange(tiles_x), indexing="ij")
    origins = jnp.stack([tx.reshape(-1) * tile, ty.reshape(-1) * tile], -1)

    yy, xx = jnp.meshgrid(jnp.arange(tile), jnp.arange(tile), indexing="ij")
    px_local = jnp.stack([xx + 0.5, yy + 0.5], -1)   # (T, T, 2) pixel centers

    def tile_fn(list_row, origin):
        px = px_local + origin.astype(jnp.float32)

        def step(carry, idx):
            color_acc, t_acc = carry
            valid = idx >= 0
            g = jnp.clip(idx, 0, s.m - 1)
            a = _alpha(px, means[g], s.conic[g], s.opacity[g])
            a = jnp.where(valid, a, 0.0)
            contrib = t_acc * a
            color_acc = color_acc + contrib[..., None] * colors[g]
            t_acc = t_acc * (1.0 - a)
            return (color_acc, t_acc), (a > 0.0).any()

        init = (jnp.zeros((tile, tile, 3), jnp.float32),
                jnp.ones((tile, tile), jnp.float32))
        (color, _t), hit = jax.lax.scan(step, init, list_row)
        return color, hit

    colors_t, hits = jax.vmap(tile_fn)(lists.lists, origins)   # (n_tiles, T, T, 3)
    img = colors_t.reshape(tiles_y, tiles_x, tile, tile, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(tiles_y * tile, tiles_x * tile, 3)
    return img[:height, :width], hits


@functools.partial(jax.jit, static_argnames=("width", "height", "eye"))
def render_reference(s: Splats, *, width: int, height: int, eye: str) -> jax.Array:
    """Oracle: per-pixel blend of every splat in global depth order (no tiles)."""
    means, colors = eye_views(s, eye)
    key = jnp.where(s.visible, s.depth, jnp.inf)
    order = jnp.argsort(key, stable=True)

    yy, xx = jnp.meshgrid(jnp.arange(height), jnp.arange(width), indexing="ij")
    px = jnp.stack([xx + 0.5, yy + 0.5], -1).astype(jnp.float32)

    def step(carry, g):
        color_acc, t_acc = carry
        a = _alpha(px, means[g], s.conic[g], s.opacity[g])
        a = jnp.where(s.visible[g], a, 0.0)
        contrib = t_acc * a
        color_acc = color_acc + contrib[..., None] * colors[g]
        t_acc = t_acc * (1.0 - a)
        return (color_acc, t_acc), None

    init = (jnp.zeros((height, width, 3), jnp.float32),
            jnp.ones((height, width), jnp.float32))
    (img, _), _ = jax.lax.scan(step, init, order)
    return img
