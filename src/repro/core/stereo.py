"""Stereo rasterization (paper §4.4): triangulation-based right-eye list
construction from the left-eye tile lists, with a k-way sorted merge.

The SRU/line-buffer dataflow of §5 is reproduced exactly:
  * every splat in a left tile T_c (widened grid) has disparity d = B·f/z;
    its right-eye footprint is its left footprint shifted by −d, so from the
    right tile T_cx's perspective, candidates come ONLY from left columns
    cx .. cx+n_cat−1 (n_cat = ⌊max_disparity/tile⌋ + 2 line-buffer rows);
  * each source list is already depth-sorted (shared ranks), so the right
    list is a duplicate-removing k-way merge — no re-sort;
  * an x-overlap test (the SRU's re-projection check) drops entries whose
    shifted footprint misses the tile.

`stereo_lists` is proven (tests) to equal `binning.bin_right` — an
independent construction that re-bins shifted centers directly — which is in
turn proven to make the right-eye render bitwise-equal to the full per-eye
reference. Hence the pipeline is bit-accurate end to end while sharing
projection, SH, sorting and binning work across eyes."""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.binning import BinConfig, TileLists, corner_r2
from repro.core.projection import Splats


def n_categories(max_disparity_px: float, tile: int) -> int:
    """Line-buffer rows needed (paper uses 4 at tile=4, max disparity 16)."""
    return int(max_disparity_px // tile) + 2


@functools.partial(jax.jit, static_argnames=("tile", "width", "n_cat"))
def stereo_lists(left: TileLists, s: Splats, ranks: jax.Array, *, tile: int,
                 width: int, n_cat: int) -> TileLists:
    """Build right-eye tile lists by shift-merging the left (widened) lists."""
    tiles_x_r = -(-width // tile)
    tiles_y = left.tiles_y
    tiles_x_w = left.tiles_x
    l_len = left.lists.shape[1]
    m = s.m

    wide = left.lists.reshape(tiles_y, tiles_x_w, l_len)

    # source lists for right tile column cx: left columns cx .. cx+n_cat-1
    def gather_sources(cx):
        cols = jnp.clip(cx + jnp.arange(n_cat), 0, tiles_x_w - 1)
        src = wide[:, cols, :]                      # (tiles_y, n_cat, L)
        # mark out-of-range clipped columns invalid
        ok = (cx + jnp.arange(n_cat)) < tiles_x_w
        return jnp.where(ok[None, :, None], src, -1)

    src = jax.vmap(gather_sources, out_axes=1)(jnp.arange(tiles_x_r))
    # src: (tiles_y, tiles_x_r, n_cat, L)
    cand = src.reshape(tiles_y * tiles_x_r, n_cat * l_len)

    g = jnp.clip(cand, 0, m - 1)
    valid = cand >= 0

    # SRU re-projection: does the shifted footprint overlap this right tile?
    x_r = s.mean2d[g, 0] - s.disparity[g]
    ext_x = s.ext[g, 0]
    cx_of = (jnp.arange(tiles_y * tiles_x_r) % tiles_x_r)
    cy_of = (jnp.arange(tiles_y * tiles_x_r) // tiles_x_r)
    lo = (cx_of * tile).astype(jnp.float32)[:, None]
    hi = lo + tile
    overlap = (x_r + ext_x >= lo) & (x_r - ext_x <= hi)
    # same conservative corner-circle cull as binning (keeps merge == rebin)
    r2 = corner_r2(s.conic, s.opacity)[g]
    y_r = s.mean2d[g, 1]
    ylo = (cy_of * tile).astype(jnp.float32)[:, None]
    dx = jnp.maximum(jnp.maximum(lo - x_r, x_r - hi), 0.0)
    dy = jnp.maximum(jnp.maximum(ylo - y_r, y_r - (ylo + tile)), 0.0)
    include = valid & overlap & (dx * dx + dy * dy <= r2)

    # k-way merge with duplicate removal: sort by (rank, source slot) and keep
    # the first occurrence of each splat. Each source list is already sorted,
    # so ranks are the line-buffer head-selection order.
    rank_key = jnp.where(include, ranks[g], jnp.iinfo(jnp.int32).max)
    # stable sort by rank ⇒ ties (same splat, multiple sources) keep slot order
    order = jnp.argsort(rank_key, axis=1, stable=True)
    sorted_g = jnp.take_along_axis(g, order, axis=1)
    sorted_inc = jnp.take_along_axis(include, order, axis=1)
    sorted_rank = jnp.take_along_axis(rank_key, order, axis=1)
    dup = jnp.concatenate([
        jnp.zeros((cand.shape[0], 1), bool),
        sorted_rank[:, 1:] == sorted_rank[:, :-1]], axis=1)
    keep = sorted_inc & ~dup

    # compact: stable re-sort by keep-flag keeps merge order among kept
    comp_key = jnp.where(keep, jnp.arange(n_cat * l_len)[None, :], jnp.iinfo(jnp.int32).max)
    comp_order = jnp.argsort(comp_key, axis=1)
    comp_g = jnp.take_along_axis(sorted_g, comp_order, axis=1)
    comp_keep = jnp.take_along_axis(keep, comp_order, axis=1)
    out = jnp.where(comp_keep, comp_g, -1)[:, :l_len]
    counts = comp_keep.sum(axis=1).astype(jnp.int32)

    overflow = left.overflow | (counts > l_len).any()
    return TileLists(lists=out.astype(jnp.int32),
                     counts=jnp.minimum(counts, l_len),
                     overflow=overflow, tiles_x=tiles_x_r, tiles_y=tiles_y)


@dataclasses.dataclass(frozen=True)
class StereoStats:
    """Work-sharing accounting for the client (feeds Figs. 18/21/22)."""

    shared_preprocess: int      # splats projected once instead of twice
    left_blends: int            # (tile, entry) pairs blended for the left eye
    right_candidates: int       # entries merged for the right eye
    right_alpha_skipped: int    # right candidates that failed every left α-check


def alpha_skip_stats(left: TileLists, right: TileLists, left_hits: jax.Array,
                     s: Splats) -> StereoStats:
    """How much right-eye work the α-check forwarding removes (paper step ②)."""
    m = s.m
    hit_any = jnp.zeros((m + 1,), bool)
    g = jnp.where(left.lists >= 0, left.lists, m)
    hit_any = hit_any.at[g.reshape(-1)].max(left_hits.reshape(-1))
    rg = jnp.where(right.lists >= 0, right.lists, m)
    r_valid = right.lists >= 0
    r_hit = hit_any[rg] & r_valid
    return StereoStats(
        shared_preprocess=int(s.visible.sum()),
        left_blends=int((left.lists >= 0).sum()),
        right_candidates=int(r_valid.sum()),
        right_alpha_skipped=int((r_valid & ~r_hit).sum()),
    )
