"""Remote-rendering baseline models: H.265 video streaming + wireless link.

The paper's Figs. 4/5/17/19 compare Nebula against streaming fully rendered
stereo video. The container has no NVENC/network, so (exactly like the paper's
own analytical treatment of the link) we model:

  * H.265 bitrate = bits-per-pixel preset × pixels × 2 eyes × FPS.
    Presets follow published HEVC operating points for high-motion content
    (Minallah'15 / Sullivan'12-class numbers):
      lossy-L   ≈ 0.05 bpp  (visible artifacts, ~35 dB)
      lossy-H   ≈ 0.15 bpp  (paper's default comparison point)
      lossless  ≈ 3.2  bpp
  * link: 100 Mbps high-speed Wi-Fi, 100 nJ/byte radio energy (paper §6).

Every consumer reports both bytes/frame and sustained bandwidth so Nebula's
Δcut traffic can be compared 1:1 (benchmarks/bench_bandwidth.py)."""

from __future__ import annotations

import dataclasses

H265_BPP = {"lossy-L": 0.05, "lossy-H": 0.15, "lossless": 3.2}
LINK_RATE_BPS = 100e6           # 100 Mbps Wi-Fi (paper §6)
COMM_ENERGY_J_PER_BYTE = 100e-9  # 100 nJ/B (paper §6, ISSCC'22 AR sensor study)
ENCODE_LATENCY_S = 4.0e-3        # HW HEVC encode (per stereo frame)
DECODE_LATENCY_S = 2.5e-3        # HW HEVC decode


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    width: int = 2064
    height: int = 2208
    fps: float = 90.0
    preset: str = "lossy-H"


def video_bytes_per_frame(cfg: StreamConfig) -> float:
    bpp = H265_BPP[cfg.preset]
    return bpp * cfg.width * cfg.height * 2 / 8.0  # stereo pair


def video_bandwidth_bps(cfg: StreamConfig) -> float:
    return video_bytes_per_frame(cfg) * 8.0 * cfg.fps


def video_frame_latency_s(cfg: StreamConfig, link_bps: float = LINK_RATE_BPS) -> float:
    """Motion-to-photon contribution of the streaming path for one frame."""
    tx = video_bytes_per_frame(cfg) * 8.0 / link_bps
    return ENCODE_LATENCY_S + tx + DECODE_LATENCY_S


def nebula_bandwidth_bps(sync_bytes_mean: float, w: int, fps: float) -> float:
    """Δcut traffic amortized over the w-frame sync interval + pose uplink."""
    from repro.core.manager import POSE_UPLINK_BYTES
    per_frame = sync_bytes_mean / max(w, 1) + POSE_UPLINK_BYTES
    return per_frame * 8.0 * fps


def nebula_sync_latency_s(sync_bytes: float, link_bps: float = LINK_RATE_BPS) -> float:
    return sync_bytes * 8.0 / link_bps
