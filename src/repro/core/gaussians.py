"""Gaussian primitive container + procedural city-scale scene generation.

The scene generator stands in for the Urban/Mega/HierGS captures (not shipped
offline). It produces leaf Gaussians with city statistics: a ground plane, a
grid of buildings (walls/roofs), and street clutter, with view-dependent color
via spherical harmonics. Scale is a parameter — tests use hundreds of leaves,
benchmarks use up to millions.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# SH constants (degree <= 3 supported; default degree 1 keeps tests light).
SH_C0 = 0.28209479177387814
SH_C1 = 0.4886025119029199


def sh_dim(degree: int) -> int:
    return (degree + 1) ** 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Gaussians:
    """Structure-of-arrays Gaussian container (the smallest rendering primitive).

    mu:        (N, 3) float32 world-space centers
    log_scale: (N, 3) float32 per-axis log std-dev
    quat:      (N, 4) float32 rotation quaternion (w, x, y, z), normalized
    opacity:   (N,)   float32 in (0, 1)
    sh:        (N, K, 3) float32 spherical-harmonic color coefficients
    """

    mu: jax.Array
    log_scale: jax.Array
    quat: jax.Array
    opacity: jax.Array
    sh: jax.Array

    @property
    def n(self) -> int:
        return self.mu.shape[0]

    @property
    def sh_degree(self) -> int:
        return int(np.sqrt(self.sh.shape[1])) - 1

    def __getitem__(self, idx) -> "Gaussians":
        return Gaussians(
            mu=self.mu[idx],
            log_scale=self.log_scale[idx],
            quat=self.quat[idx],
            opacity=self.opacity[idx],
            sh=self.sh[idx],
        )

    def slice_rows(self, idx: jax.Array) -> "Gaussians":
        """Gather rows by (possibly traced) index array."""
        return Gaussians(
            mu=jnp.take(self.mu, idx, axis=0),
            log_scale=jnp.take(self.log_scale, idx, axis=0),
            quat=jnp.take(self.quat, idx, axis=0),
            opacity=jnp.take(self.opacity, idx, axis=0),
            sh=jnp.take(self.sh, idx, axis=0),
        )

    @staticmethod
    def concat(parts: Tuple["Gaussians", ...]) -> "Gaussians":
        return Gaussians(
            mu=jnp.concatenate([p.mu for p in parts], axis=0),
            log_scale=jnp.concatenate([p.log_scale for p in parts], axis=0),
            quat=jnp.concatenate([p.quat for p in parts], axis=0),
            opacity=jnp.concatenate([p.opacity for p in parts], axis=0),
            sh=jnp.concatenate([p.sh for p in parts], axis=0),
        )

    def nbytes(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in (self.mu, self.log_scale, self.quat, self.opacity, self.sh))


def bytes_per_gaussian(sh_degree: int, raw: bool = True) -> int:
    """Uncompressed storage per Gaussian in float32 (mu3+ls3+q4+op1 + sh)."""
    k = sh_dim(sh_degree)
    return 4 * (3 + 3 + 4 + 1 + 3 * k)


def quat_to_rotmat(q: jax.Array) -> jax.Array:
    """(…, 4) wxyz quaternion → (…, 3, 3) rotation matrix."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r = jnp.stack(
        [
            1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y),
            2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x),
            2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y),
        ],
        axis=-1,
    )
    return r.reshape(q.shape[:-1] + (3, 3))


def covariance(g: Gaussians) -> jax.Array:
    """(N, 3, 3) world-space covariance R S S^T R^T."""
    rot = quat_to_rotmat(g.quat)
    s = jnp.exp(g.log_scale)
    rs = rot * s[..., None, :]
    return rs @ jnp.swapaxes(rs, -1, -2)


def eval_sh(sh: jax.Array, dirs: jax.Array) -> jax.Array:
    """Evaluate SH color along unit view directions.

    sh:   (..., K, 3), dirs: (..., 3) unit vectors → (..., 3) RGB (clipped >= 0).
    Supports K in {1, 4, 9, 16}; higher bands of the basis are standard real SH.
    """
    k = sh.shape[-2]
    c = SH_C0 * sh[..., 0, :]
    if k >= 4:
        x, y, z = dirs[..., 0:1], dirs[..., 1:2], dirs[..., 2:3]
        c = c - SH_C1 * y * sh[..., 1, :] + SH_C1 * z * sh[..., 2, :] - SH_C1 * x * sh[..., 3, :]
    if k >= 9:
        x, y, z = dirs[..., 0:1], dirs[..., 1:2], dirs[..., 2:3]
        xx, yy, zz = x * x, y * y, z * z
        xy, yz, xz = x * y, y * z, x * z
        c = (c
             + 1.0925484305920792 * xy * sh[..., 4, :]
             + (-1.0925484305920792) * yz * sh[..., 5, :]
             + 0.31539156525252005 * (2.0 * zz - xx - yy) * sh[..., 6, :]
             + (-1.0925484305920792) * xz * sh[..., 7, :]
             + 0.5462742152960396 * (xx - yy) * sh[..., 8, :])
    c = c + 0.5
    return jnp.maximum(c, 0.0)


# ---------------------------------------------------------------------------
# Procedural city scene
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CityConfig:
    """Procedural city parameters (world units are meters)."""

    blocks_x: int = 4
    blocks_y: int = 4
    block_size: float = 40.0
    street_width: float = 12.0
    max_height: float = 45.0
    leaf_density: float = 0.6       # Gaussians per square meter of surface
    sh_degree: int = 1
    seed: int = 0

    @property
    def extent(self) -> Tuple[float, float]:
        pitch = self.block_size + self.street_width
        return (self.blocks_x * pitch, self.blocks_y * pitch)


def _surface_points(rng: np.random.Generator, n: int, origin, u_vec, v_vec) -> np.ndarray:
    """Sample n points on a parallelogram surface patch."""
    uv = rng.random((n, 2))
    return (np.asarray(origin)[None, :]
            + uv[:, :1] * np.asarray(u_vec)[None, :]
            + uv[:, 1:] * np.asarray(v_vec)[None, :])


def generate_city(cfg: CityConfig) -> Gaussians:
    """Generate leaf Gaussians for a procedural city (numpy; offline step)."""
    rng = np.random.default_rng(cfg.seed)
    pitch = cfg.block_size + cfg.street_width
    pts, scales, colors = [], [], []

    def add_patch(origin, u_vec, v_vec, base_color, scale_m):
        area = np.linalg.norm(np.cross(u_vec, v_vec))
        n = max(4, int(area * cfg.leaf_density))
        p = _surface_points(rng, n, origin, u_vec, v_vec)
        pts.append(p)
        scales.append(np.full((n, 3), scale_m) * rng.uniform(0.6, 1.6, (n, 3)))
        col = np.clip(base_color + rng.normal(0, 0.08, (n, 3)), 0.02, 0.98)
        colors.append(col)

    # Ground plane per block cell (streets included)
    ex, ey = cfg.extent
    n_ground = max(16, int(ex * ey * cfg.leaf_density * 0.08))
    gp = rng.random((n_ground, 2)) * np.array([ex, ey])
    pts.append(np.concatenate([gp, np.zeros((n_ground, 1))], axis=1))
    scales.append(np.full((n_ground, 3), 1.2) * rng.uniform(0.7, 1.4, (n_ground, 3)))
    colors.append(np.clip(0.35 + rng.normal(0, 0.05, (n_ground, 3)), 0.05, 0.9))

    for bx in range(cfg.blocks_x):
        for by in range(cfg.blocks_y):
            x0 = bx * pitch + cfg.street_width / 2
            y0 = by * pitch + cfg.street_width / 2
            w = cfg.block_size * rng.uniform(0.5, 0.95)
            d = cfg.block_size * rng.uniform(0.5, 0.95)
            h = cfg.max_height * rng.uniform(0.15, 1.0)
            base = np.clip(rng.uniform(0.25, 0.8, 3), 0, 1)
            sc = 0.8
            # four walls + roof
            add_patch([x0, y0, 0], [w, 0, 0], [0, 0, h], base, sc)
            add_patch([x0, y0 + d, 0], [w, 0, 0], [0, 0, h], base * 0.9, sc)
            add_patch([x0, y0, 0], [0, d, 0], [0, 0, h], base * 0.95, sc)
            add_patch([x0 + w, y0, 0], [0, d, 0], [0, 0, h], base * 0.85, sc)
            add_patch([x0, y0, h], [w, 0, 0], [0, d, 0], base * 1.1, sc)

    mu = np.concatenate(pts, axis=0).astype(np.float32)
    scale = np.concatenate(scales, axis=0).astype(np.float32)
    col = np.concatenate(colors, axis=0).astype(np.float32)
    n = mu.shape[0]

    quat = rng.normal(size=(n, 4)).astype(np.float32)
    quat /= np.linalg.norm(quat, axis=1, keepdims=True)
    opacity = rng.uniform(0.35, 0.95, n).astype(np.float32)

    k = sh_dim(cfg.sh_degree)
    sh = np.zeros((n, k, 3), dtype=np.float32)
    sh[:, 0, :] = (col - 0.5) / SH_C0  # DC term reproduces base color
    if k > 1:
        # view dependence is LOW-RANK in real captures (a few material/BRDF
        # prototypes per scene) — sample from a small dictionary + jitter.
        # This is also the property Compact3DGS-style VQ exploits.
        n_mat = 32
        protos = rng.normal(0, 0.12, (n_mat, k - 1, 3))
        mat = rng.integers(0, n_mat, n)
        sh[:, 1:, :] = protos[mat] + rng.normal(0, 0.015, (n, k - 1, 3))

    return Gaussians(
        mu=jnp.asarray(mu),
        log_scale=jnp.asarray(np.log(np.maximum(scale, 1e-4))),
        quat=jnp.asarray(quat),
        opacity=jnp.asarray(opacity),
        sh=jnp.asarray(sh),
    )


def random_gaussians(rng: np.random.Generator, n: int, sh_degree: int = 1,
                     extent: float = 10.0) -> Gaussians:
    """Uniform random Gaussians — used by unit tests and kernels sweeps."""
    k = sh_dim(sh_degree)
    quat = rng.normal(size=(n, 4)).astype(np.float32)
    quat /= np.linalg.norm(quat, axis=1, keepdims=True) + 1e-12
    return Gaussians(
        mu=jnp.asarray(rng.uniform(-extent, extent, (n, 3)).astype(np.float32)),
        log_scale=jnp.asarray(np.log(rng.uniform(0.05, 0.6, (n, 3))).astype(np.float32)),
        quat=jnp.asarray(quat),
        opacity=jnp.asarray(rng.uniform(0.2, 0.95, n).astype(np.float32)),
        sh=jnp.asarray(rng.normal(0, 0.35, (n, k, 3)).astype(np.float32)),
    )
