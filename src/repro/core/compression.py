"""Δcut compression (paper §4.3 "Compression" — the paper claims no novelty
here and neither do we; this follows Compact3DGS-style attribute coding).

  * SH: DC band kept at fp16; AC bands vector-quantized against a k-means
    codebook fit offline on the scene (the client holds the codebook — the
    hardware decoder's "codebook buffer" of §5).
  * position / log-scale: 16-bit fixed point over the scene range;
  * quaternion: 16-bit per component in [-1, 1];
  * opacity: 16-bit in [0, 1].

Everything is jittable; the VQ assignment hot spot also exists as a Pallas
kernel (repro.kernels.vq_assign) with this module as its oracle-consistent
fallback.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gaussians import Gaussians


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Codec:
    codebook: jax.Array     # (Kc, D) f32, D = (K-1)*3 SH AC dims (Kc>=1)
    pos_lo: jax.Array       # (3,)
    pos_hi: jax.Array       # (3,)
    scale_lo: jax.Array     # ()
    scale_hi: jax.Array     # ()

    @property
    def k_codes(self) -> int:
        return self.codebook.shape[0]

    def code_bytes(self) -> int:
        return max(1, int(np.ceil(np.log2(max(self.k_codes, 2)) / 8)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EncodedGaussians:
    dc: jax.Array        # (M, 3) f16
    code: jax.Array      # (M,) int32 — VQ index (wire width = codec.code_bytes())
    pos_q: jax.Array     # (M, 3) uint16
    scale_q: jax.Array   # (M, 3) uint16
    quat_q: jax.Array    # (M, 4) int16
    opa_q: jax.Array     # (M,) uint16

    @property
    def m(self) -> int:
        return self.dc.shape[0]


def wire_bytes_per_gaussian(codec: Codec) -> int:
    """16-bit attrs + fp16 DC + VQ code index (paper §4.3 layout)."""
    return 3 * 2 + codec.code_bytes() + 3 * 2 + 3 * 2 + 4 * 2 + 2


# ---------------------------------------------------------------------------
# k-means codebook (offline)
# ---------------------------------------------------------------------------


def vq_assign_ref(x: jax.Array, codebook: jax.Array) -> jax.Array:
    """(M, D) × (Kc, D) → (M,) nearest-codeword indices (pure jnp oracle)."""
    # argmin ||x - c||² = argmin (||c||² − 2 x·c)
    c2 = jnp.sum(codebook * codebook, axis=-1)
    scores = c2[None, :] - 2.0 * (x @ codebook.T)
    return jnp.argmin(scores, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("iters",))
def _kmeans(x: jax.Array, init: jax.Array, iters: int) -> jax.Array:
    def body(codebook, _):
        idx = vq_assign_ref(x, codebook)
        k = codebook.shape[0]
        sums = jax.ops.segment_sum(x, idx, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), idx,
                                   num_segments=k)
        new = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts[:, None], 1.0),
                        codebook)
        return new, None

    cb, _ = jax.lax.scan(body, init, None, length=iters)
    return cb


def fit_codec(g: Gaussians, k_codes: int = 256, iters: int = 8,
              seed: int = 0, sample: int = 65536) -> Codec:
    """Fit the codec on scene statistics (offline; cloud side)."""
    rng = np.random.default_rng(seed)
    n, k = g.sh.shape[0], g.sh.shape[1]
    d = max((k - 1) * 3, 1)
    if k > 1:
        ac = np.asarray(g.sh[:, 1:, :].reshape(n, -1))
    else:
        ac = np.zeros((n, 1), np.float32)
    take = rng.choice(n, size=min(sample, n), replace=False)
    xs = jnp.asarray(ac[take])
    init = jnp.asarray(ac[rng.choice(n, size=min(k_codes, n), replace=False)])
    if init.shape[0] < k_codes:  # tiny scenes: tile
        reps = int(np.ceil(k_codes / init.shape[0]))
        init = jnp.tile(init, (reps, 1))[:k_codes]
        init = init + 1e-4 * jnp.asarray(rng.normal(size=init.shape), jnp.float32)
    codebook = _kmeans(xs, init, iters)

    mu = np.asarray(g.mu)
    ls = np.asarray(g.log_scale)
    pad = 1e-3
    return Codec(
        codebook=codebook.reshape(k_codes, d),
        pos_lo=jnp.asarray(mu.min(0) - pad),
        pos_hi=jnp.asarray(mu.max(0) + pad),
        scale_lo=jnp.asarray(np.float32(ls.min() - pad)),
        scale_hi=jnp.asarray(np.float32(ls.max() + pad)),
    )


# ---------------------------------------------------------------------------
# encode / decode (jittable)
# ---------------------------------------------------------------------------


def _quant16(x, lo, hi):
    q = (x - lo) / jnp.maximum(hi - lo, 1e-12) * 65535.0
    return jnp.clip(jnp.round(q), 0, 65535).astype(jnp.uint16)


def _dequant16(q, lo, hi):
    return q.astype(jnp.float32) / 65535.0 * (hi - lo) + lo


@jax.jit
def encode(codec: Codec, g: Gaussians) -> EncodedGaussians:
    n, k = g.sh.shape[0], g.sh.shape[1]
    if k > 1:
        ac = g.sh[:, 1:, :].reshape(n, -1)
        code = vq_assign_ref(ac, codec.codebook)
    else:
        code = jnp.zeros((n,), jnp.int32)
    quat = g.quat / (jnp.linalg.norm(g.quat, axis=-1, keepdims=True) + 1e-12)
    return EncodedGaussians(
        dc=g.sh[:, 0, :].astype(jnp.float16),
        code=code,
        pos_q=_quant16(g.mu, codec.pos_lo, codec.pos_hi),
        scale_q=_quant16(g.log_scale, codec.scale_lo, codec.scale_hi),
        quat_q=jnp.clip(jnp.round(quat * 32767.0), -32767, 32767).astype(jnp.int16),
        opa_q=_quant16(g.opacity, 0.0, 1.0),
    )


@functools.partial(jax.jit, static_argnames=("sh_k",))
def decode(codec: Codec, e: EncodedGaussians, sh_k: int) -> Gaussians:
    m = e.m
    dc = e.dc.astype(jnp.float32)
    if sh_k > 1:
        ac = jnp.take(codec.codebook, e.code, axis=0).reshape(m, sh_k - 1, 3)
        sh = jnp.concatenate([dc[:, None, :], ac], axis=1)
    else:
        sh = dc[:, None, :]
    quat = e.quat_q.astype(jnp.float32) / 32767.0
    quat = quat / (jnp.linalg.norm(quat, axis=-1, keepdims=True) + 1e-12)
    return Gaussians(
        mu=_dequant16(e.pos_q, codec.pos_lo, codec.pos_hi),
        log_scale=_dequant16(e.scale_q, codec.scale_lo, codec.scale_hi),
        quat=quat,
        opacity=_dequant16(e.opa_q, 0.0, 1.0),
        sh=sh,
    )


def encode_rows(codec: Codec, g: Gaussians, ids: jax.Array
                ) -> EncodedGaussians:
    """Gather rows `ids` (-1 padded → row 0) from a Gaussian table and encode
    them: the ONE gather + quantize/pack helper behind every wire path — the
    single-client pipeline's unicast Δcut, the per-client reference encoder,
    and the fleet encode-once union stream (repro.serve.delta_path)."""
    return encode(codec, g.slice_rows(jnp.clip(ids, 0)))


def roundtrip(codec: Codec, g: Gaussians) -> Gaussians:
    return decode(codec, encode(codec, g), g.sh.shape[1])


def max_position_error(codec: Codec) -> float:
    """Worst-case quantization error in meters (half an LSB per axis)."""
    rng = np.asarray(codec.pos_hi) - np.asarray(codec.pos_lo)
    return float(np.linalg.norm(rng / 65535.0 / 2.0))
