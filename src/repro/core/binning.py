"""Depth-ordered tile binning (shared between eyes up to the disparity shift).

Produces per-tile fixed-length index lists, front-to-back. The same routine
bins the left eye (widened image, unshifted means) and — because the
conservative α-extent is disparity-invariant — the right eye (means shifted
by −disparity, unwidened width). Depth ranks are shared, so every produced
list is sorted by construction (the paper's "already sorted" invariant that
the 4-way merge relies on)."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.projection import Splats


@dataclasses.dataclass(frozen=True)
class BinConfig:
    tile: int = 16           # tile side in pixels
    max_pairs: int = 1 << 16  # (gaussian, tile) pair budget
    list_len: int = 256       # per-tile list capacity
    precise_cull: bool = True  # GSCore-style shape-aware tile test (§Perf):
    # on top of the α-ellipse AABB span, drop (splat, tile) pairs whose tile
    # rectangle lies beyond the conservative corner circle r² = 2·λ_max·
    # ln(opa/α_min). Strictly conservative ⇒ bit-accuracy preserved (tested).


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TileLists:
    """lists[t, i] = splat index (−1 padded), front-to-back within each tile."""

    lists: jax.Array       # (n_tiles, list_len) int32
    counts: jax.Array      # (n_tiles,) int32
    overflow: jax.Array    # () bool — any budget exceeded
    tiles_x: int = dataclasses.field(metadata=dict(static=True))
    tiles_y: int = dataclasses.field(metadata=dict(static=True))


def corner_r2(conic: jax.Array, opacity: jax.Array) -> jax.Array:
    """Conservative cull radius²: tile rects farther than this from the splat
    center cannot reach α ≥ α_min anywhere (uses λ_max of the 2D covariance =
    1/λ_min of the conic)."""
    from repro.core.projection import ALPHA_MIN
    a_, b_, c_ = conic[:, 0], conic[:, 1], conic[:, 2]
    lam_min_conic = (a_ + c_) / 2 - jnp.sqrt(((a_ - c_) / 2) ** 2 + b_ ** 2)
    lam_max = 1.0 / jnp.maximum(lam_min_conic, 1e-12)
    return 2.0 * lam_max * jnp.log(jnp.maximum(opacity, ALPHA_MIN) / ALPHA_MIN)


def tile_span(mean2d, ext, tile: int, tiles_x: int, tiles_y: int):
    """Inclusive tile index ranges covered by each splat's α-AABB."""
    x0 = jnp.floor((mean2d[:, 0] - ext[:, 0]) / tile).astype(jnp.int32)
    x1 = jnp.floor((mean2d[:, 0] + ext[:, 0]) / tile).astype(jnp.int32)
    y0 = jnp.floor((mean2d[:, 1] - ext[:, 1]) / tile).astype(jnp.int32)
    y1 = jnp.floor((mean2d[:, 1] + ext[:, 1]) / tile).astype(jnp.int32)
    x0 = jnp.clip(x0, 0, tiles_x - 1)
    x1 = jnp.clip(x1, 0, tiles_x - 1)
    y0 = jnp.clip(y0, 0, tiles_y - 1)
    y1 = jnp.clip(y1, 0, tiles_y - 1)
    return x0, x1, y0, y1


def bin_tiles(mean2d: jax.Array, ext: jax.Array, ranks: jax.Array,
              visible: jax.Array, width: int, height: int, cfg: BinConfig,
              conic: jax.Array = None, opacity: jax.Array = None
              ) -> TileLists:
    """Bin splats into per-tile depth-ordered lists (jittable, static budgets)."""
    tile = cfg.tile
    tiles_x = -(-width // tile)
    tiles_y = -(-height // tile)
    n_tiles = tiles_x * tiles_y
    m = mean2d.shape[0]

    # visibility for THIS image (binning may be called with shifted means)
    vis = (visible
           & (mean2d[:, 0] + ext[:, 0] >= 0.0)
           & (mean2d[:, 0] - ext[:, 0] <= width)
           & (mean2d[:, 1] + ext[:, 1] >= 0.0)
           & (mean2d[:, 1] - ext[:, 1] <= height))

    x0, x1, y0, y1 = tile_span(mean2d, ext, tile, tiles_x, tiles_y)
    span_w = jnp.where(vis, x1 - x0 + 1, 0)
    span_h = jnp.where(vis, y1 - y0 + 1, 0)
    counts = (span_w * span_h).astype(jnp.int32)

    offsets = jnp.cumsum(counts)
    total = offsets[-1] if m > 0 else jnp.int32(0)
    starts = offsets - counts

    # expand (gaussian, tile) pairs into a fixed budget
    p = jnp.arange(cfg.max_pairs, dtype=jnp.int32)
    gid = jnp.searchsorted(offsets, p, side="right").astype(jnp.int32)
    gid_c = jnp.clip(gid, 0, m - 1)
    local = p - starts[gid_c]
    w_g = jnp.maximum(span_w[gid_c], 1)
    dx = local % w_g
    dy = local // w_g
    tx = x0[gid_c] + dx
    ty = y0[gid_c] + dy
    pair_valid = (p < total) & (gid < m)

    if cfg.precise_cull and conic is not None and opacity is not None:
        r2 = corner_r2(conic, opacity)
        # distance² from the pair's tile rect to the splat center
        mx = mean2d[gid_c, 0]
        my = mean2d[gid_c, 1]
        cx0 = (tx * tile).astype(jnp.float32)
        cy0 = (ty * tile).astype(jnp.float32)
        dx = jnp.maximum(jnp.maximum(cx0 - mx, mx - (cx0 + tile)), 0.0)
        dy = jnp.maximum(jnp.maximum(cy0 - my, my - (cy0 + tile)), 0.0)
        pair_valid = pair_valid & (dx * dx + dy * dy <= r2[gid_c])

    tile_id = jnp.where(pair_valid, ty * tiles_x + tx, n_tiles)  # n_tiles = trash

    # sort pairs by (tile, depth-rank) via two stable passes (no wide ints)
    rank_key = jnp.where(pair_valid, ranks[gid_c], m)
    order1 = jnp.argsort(rank_key, stable=True)
    order = order1[jnp.argsort(tile_id[order1], stable=True)]
    s_tile = tile_id[order]
    s_gid = gid_c[order]
    s_valid = pair_valid[order]

    # position of each pair within its tile
    tile_start = jnp.searchsorted(s_tile, jnp.arange(n_tiles + 1, dtype=jnp.int32))
    pos = jnp.arange(cfg.max_pairs, dtype=jnp.int32) - tile_start[jnp.clip(s_tile, 0, n_tiles)]
    in_list = s_valid & (pos < cfg.list_len)

    flat = jnp.where(in_list, s_tile * cfg.list_len + pos, n_tiles * cfg.list_len)
    lists = jnp.full((n_tiles * cfg.list_len + 1,), -1, jnp.int32)
    lists = lists.at[flat].set(jnp.where(in_list, s_gid, -1))
    lists = lists[:-1].reshape(n_tiles, cfg.list_len)

    tile_counts = (tile_start[1:] - tile_start[:-1]).astype(jnp.int32)
    tile_counts = jnp.minimum(tile_counts, cfg.list_len)

    overflow = (total > cfg.max_pairs) | ((tile_start[1:] - tile_start[:-1]) > cfg.list_len).any()
    return TileLists(lists=lists, counts=tile_counts, overflow=overflow,
                     tiles_x=tiles_x, tiles_y=tiles_y)


def bin_left(s: Splats, wide_width: int, height: int, cfg: BinConfig,
             ranks: jax.Array) -> TileLists:
    return bin_tiles(s.mean2d, s.ext, ranks, s.visible, wide_width, height,
                     cfg, conic=s.conic, opacity=s.opacity)


def bin_right(s: Splats, width: int, height: int, cfg: BinConfig,
              ranks: jax.Array) -> TileLists:
    shifted = s.mean2d - jnp.stack([s.disparity, jnp.zeros_like(s.disparity)], -1)
    return bin_tiles(shifted, s.ext, ranks, s.visible, width, height, cfg,
                     conic=s.conic, opacity=s.opacity)
