"""Energy model for the client device (paper §6 / Fig. 19 methodology).

All constants are modeled (no RTL here): DRAM from Micron LPDDR3 power-calc
class numbers, compute from 8nm-scaled per-MAC energy used in the accelerator
literature the paper builds on (GSCore/GBU). Numbers are *relative* — the
benchmark reports ratios against the same model evaluated for the baselines,
mirroring how the paper normalizes Fig. 19 to its GPU baseline."""

from __future__ import annotations

import dataclasses

# modeled energy constants (J)
DRAM_J_PER_BYTE = 20e-12 * 8      # ~20 pJ/bit LPDDR3 access
SRAM_J_PER_BYTE = 1.2e-12 * 8     # on-chip buffer
MAC_J = 0.8e-12                   # 8nm fused MAC (bf16-class)
COMM_J_PER_BYTE = 100e-9          # wireless (paper §6)


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    dram_j: float
    sram_j: float
    compute_j: float
    comm_j: float

    @property
    def total_j(self) -> float:
        return self.dram_j + self.sram_j + self.compute_j + self.comm_j


def client_frame_energy(dram_bytes: float, sram_bytes: float, macs: float,
                        comm_bytes: float) -> EnergyBreakdown:
    return EnergyBreakdown(
        dram_j=dram_bytes * DRAM_J_PER_BYTE,
        sram_j=sram_bytes * SRAM_J_PER_BYTE,
        compute_j=macs * MAC_J,
        comm_j=comm_bytes * COMM_J_PER_BYTE,
    )
