"""Fully-streaming + temporal-aware LoD search (paper §4.2), TPU-native.

Semantics (identical to HierGS-style traversal):
  proj(n)    = size(n) * focal / dist(cam, n)          (radial ⇒ rotation-free)
  expand(n)  = expand(parent(n)) AND proj(n) > τ        (root parent ≡ True)
  in_cut(n)  = expand(parent(n)) AND (proj(n) ≤ τ OR leaf(n))

*Fully-streaming traversal* — the tree is laid out as a replicated top-tree
plus fixed-size subtree slabs (see lod_tree.py). One frame = a level-major
sweep of the top-tree + a vmapped level-synchronous sweep of each slab. All
memory access is regular; the only gathers are slab-local (VMEM-resident by
construction) — the TPU analogue of the paper's shared-memory streaming.

*Temporal-aware search* — per subtree we maintain a provably-safe reuse bound:
after sweeping subtree s at camera position c0, ρ_s = min over its nodes of
|dist(c0, n) − r*(n)| with r*(n) = size(n)·focal/τ (the node's LoD-boundary
sphere radius). While the camera stays within ρ_s of c0 *and* the slab root's
parent-expand bit (recomputed exactly every frame from the cheap top sweep)
is unchanged, no comparison inside the subtree can flip, so the cached cut
slab is **bit-accurate**. This replaces the paper's previous-cut seeding with
an explicit invariant (same goal: skip untouched subtrees; DESIGN.md §2).

Two drivers are provided:
  * `temporal_search`        — fully jittable (vmap + select; exactness tests,
                               and composition into larger jitted pipelines);
  * `temporal_search_hybrid` — host-driven: gathers only the stale slabs and
                               sweeps them (bucketed shapes), delivering real
                               wall-clock savings proportional to staleness.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lod_tree import LodTree

_EPS_DIST = 1e-6


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CutResult:
    """One frame's LoD cut.

    top_cut:  (T,)    bool — cut nodes inside the top-tree
    slab_cut: (Ns, S) bool — cut nodes inside each subtree slab
    root_expand: (Ns,) bool — expand flag of each slab root (diagnostics)
    resweep:  (Ns,)   bool — which slabs were actually swept this frame
    nodes_touched: () int32 — streaming work metric (top + swept slabs)
    """

    top_cut: jax.Array
    slab_cut: jax.Array
    root_expand: jax.Array
    resweep: jax.Array
    nodes_touched: jax.Array

    def mask(self, tree: LodTree) -> jax.Array:
        """(N_pad,) global cut mask."""
        return jnp.concatenate([self.top_cut, self.slab_cut.reshape(-1)])

    def count(self) -> jax.Array:
        return self.top_cut.sum() + self.slab_cut.sum()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TemporalState:
    """Per-subtree reuse state for temporal-aware search."""

    cam0: jax.Array            # (Ns, 3) camera at last sweep
    rho: jax.Array             # (Ns,)  safe radius
    parent_expand0: jax.Array  # (Ns,)  top parent-expand bit at last sweep
    slab_cut0: jax.Array       # (Ns, S) cached cut
    root_expand0: jax.Array    # (Ns,)
    swept: jax.Array           # (Ns,)  ever swept

    @staticmethod
    def initial(Ns: int, S: int) -> "TemporalState":
        return TemporalState(
            cam0=jnp.zeros((Ns, 3), jnp.float32),
            rho=jnp.zeros((Ns,), jnp.float32),
            parent_expand0=jnp.zeros((Ns,), bool),
            slab_cut0=jnp.zeros((Ns, S), bool),
            root_expand0=jnp.zeros((Ns,), bool),
            swept=jnp.zeros((Ns,), bool),
        )

    @staticmethod
    def initial_batched(Ns: int, S: int, B: int) -> "TemporalState":
        """B independent clients' states stacked on a leading batch axis.
        (`swept=False` everywhere, so every client's first search is a full
        sweep — identical to `full_search`.)"""
        base = TemporalState.initial(Ns, S)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (B,) + a.shape), base)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlabTables:
    """Device-resident slab attribute tables, gathered once per tree.

    `tree.slab_mu()` / `tree.slab_size()` reshape the packed Gaussian arrays
    on every call; hot schedulers (repro.serve.lod_service) build these
    tables once at init and fuse the per-sync pair gather into the sweep
    program instead of re-deriving the views every sync."""

    mu: jax.Array        # (Ns, S, 3)
    size: jax.Array      # (Ns, S)
    parent: jax.Array    # (Ns, S) int32
    level: jax.Array     # (Ns, S) int32
    is_leaf: jax.Array   # (Ns, S) bool
    valid: jax.Array     # (Ns, S) bool

    @staticmethod
    def from_tree(tree: LodTree, mesh=None) -> "SlabTables":
        """`mesh` (a fleet mesh, repro.sharding.fleet) shards every table on
        its leading Ns axis over the `slabs` mesh axis — the city's attribute
        tables stop being bounded by one accelerator's HBM. Indivisible Ns
        (or no mesh) replicates: bitwise the single-device tables."""
        tables = SlabTables(
            mu=tree.slab_mu(), size=tree.slab_size(),
            parent=tree.slab_parent, level=tree.slab_level,
            is_leaf=tree.slab_is_leaf, valid=tree.slab_valid)
        if mesh is not None:
            from repro.sharding.fleet import shard_slab_tables
            tables = shard_slab_tables(mesh, tables)
        return tables


# ---------------------------------------------------------------------------
# sweeps
# ---------------------------------------------------------------------------


def _proj(size, dist, focal):
    return size * focal / jnp.maximum(dist, _EPS_DIST)


def top_sweep(tree: LodTree, cam_pos: jax.Array, focal, tau
              ) -> Tuple[jax.Array, jax.Array]:
    """Level-major sweep of the top-tree. Returns (expand, in_cut), both (T,)."""
    m = tree.meta
    mu = tree.top_mu()
    size = tree.top_size()
    dist = jnp.linalg.norm(mu - cam_pos, axis=-1)
    gt = _proj(size, dist, focal) > tau

    expand = jnp.zeros((m.T,), bool)
    in_cut = jnp.zeros((m.T,), bool)
    offs = m.top_level_offsets
    for l in range(m.P):
        lo, hi = offs[l], offs[l + 1]
        if l == 0:
            pe = jnp.ones((hi - lo,), bool)
        else:
            pe = expand[tree.top_parent[lo:hi]]
        expand = expand.at[lo:hi].set(pe & gt[lo:hi])
        in_cut = in_cut.at[lo:hi].set(pe & (~gt[lo:hi] | tree.top_is_leaf[lo:hi]))
    return expand, in_cut


def _slab_sweep_one(mu, size, parent, level, is_leaf, valid, root_parent_expand,
                    cam_pos, focal, tau, max_depth: int):
    """Sweep a single (S,)-slab. Returns (in_cut, root_expand, rho)."""
    dist = jnp.linalg.norm(mu - cam_pos, axis=-1)
    gt = _proj(size, dist, focal) > tau

    s = mu.shape[0]
    expand = jnp.zeros((s,), bool)
    pexp = jnp.zeros((s,), bool)
    for l in range(max_depth + 1):
        at = level == l
        pe_l = jnp.where(parent < 0, root_parent_expand,
                         expand[jnp.clip(parent, 0, s - 1)])
        pexp = jnp.where(at, pe_l, pexp)
        expand = jnp.where(at, pe_l & gt, expand)
    expand = expand & valid
    in_cut = pexp & (~gt | is_leaf) & valid

    # bit-accurate reuse bound: min distance-to-LoD-boundary over valid nodes
    rstar = size * focal / tau
    margin = jnp.where(valid, jnp.abs(dist - rstar), jnp.inf)
    rho = jnp.min(margin)
    return in_cut, expand[0], rho


def _slab_sweep_all(tree: LodTree, cam_pos, focal, tau, root_parent_expand):
    fn = functools.partial(_slab_sweep_one, cam_pos=cam_pos, focal=focal, tau=tau,
                           max_depth=tree.meta.slab_max_depth)
    return jax.vmap(fn)(
        tree.slab_mu(), tree.slab_size(), tree.slab_parent, tree.slab_level,
        tree.slab_is_leaf, tree.slab_valid, root_parent_expand)


def _root_parent_expand(tree: LodTree, top_expand: jax.Array) -> jax.Array:
    """Exact parent-expand bit for every slab root (from the full top sweep)."""
    if tree.meta.P == 0:  # degenerate: whole tree is one slab rooted at level 0
        return jnp.ones((tree.meta.Ns,), bool)
    return top_expand[tree.slab_root_parent_top]


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def full_search(tree: LodTree, cam_pos: jax.Array, focal: jax.Array,
                tau: jax.Array) -> Tuple[CutResult, TemporalState]:
    """Initial-frame fully-streaming traversal; also (re)initializes the
    temporal state (every subtree freshly swept)."""
    m = tree.meta
    cam_pos = jnp.asarray(cam_pos, jnp.float32)
    top_expand, top_cut = top_sweep(tree, cam_pos, focal, tau)
    rpe = _root_parent_expand(tree, top_expand)
    slab_cut, root_expand, rho = _slab_sweep_all(tree, cam_pos, focal, tau, rpe)

    cut = CutResult(
        top_cut=top_cut, slab_cut=slab_cut, root_expand=root_expand,
        resweep=jnp.ones((m.Ns,), bool),
        nodes_touched=jnp.asarray(m.T + m.Ns * m.S, jnp.int32),
    )
    state = TemporalState(
        cam0=jnp.broadcast_to(cam_pos, (m.Ns, 3)),
        rho=rho, parent_expand0=rpe, slab_cut0=slab_cut,
        root_expand0=root_expand, swept=jnp.ones((m.Ns,), bool),
    )
    return cut, state


@functools.partial(jax.jit, static_argnames=())
def temporal_search(tree: LodTree, state: TemporalState, cam_pos: jax.Array,
                    focal: jax.Array, tau: jax.Array
                    ) -> Tuple[CutResult, TemporalState]:
    """Temporal-aware search (jittable form). Bit-accurate vs full_search."""
    m = tree.meta
    cam_pos = jnp.asarray(cam_pos, jnp.float32)
    top_expand, top_cut = top_sweep(tree, cam_pos, focal, tau)
    rpe = _root_parent_expand(tree, top_expand)

    moved = jnp.linalg.norm(cam_pos - state.cam0, axis=-1)
    stale = (~state.swept) | (moved >= state.rho) | (rpe != state.parent_expand0)

    fresh_cut, fresh_root_expand, fresh_rho = _slab_sweep_all(
        tree, cam_pos, focal, tau, rpe)

    sel = stale[:, None]
    slab_cut = jnp.where(sel, fresh_cut, state.slab_cut0)
    root_expand = jnp.where(stale, fresh_root_expand, state.root_expand0)

    new_state = TemporalState(
        cam0=jnp.where(sel, cam_pos[None, :], state.cam0),
        rho=jnp.where(stale, fresh_rho, state.rho),
        parent_expand0=rpe,
        slab_cut0=slab_cut,
        root_expand0=root_expand,
        swept=jnp.ones((m.Ns,), bool),
    )
    cut = CutResult(
        top_cut=top_cut, slab_cut=slab_cut, root_expand=root_expand,
        resweep=stale,
        nodes_touched=(m.T + stale.sum().astype(jnp.int32) * m.S).astype(jnp.int32),
    )
    return cut, new_state


# -- batched multi-client search (leading batch axis = clients) --------------


@functools.partial(jax.jit, static_argnames=())
def batched_temporal_search(tree: LodTree, states: TemporalState,
                            cam_positions: jax.Array, focal: jax.Array,
                            tau: jax.Array) -> Tuple[CutResult, TemporalState]:
    """`temporal_search` vmapped over B clients sharing one tree.

    states' leaves carry a leading (B, ...) axis (see
    `TemporalState.initial_batched`); cam_positions is (B, 3). `tau` may be a
    scalar (one threshold for everyone) or a (B,) per-client vector —
    foveated / gaze-dependent LoD: a client with a looser (larger) τ expands
    less of the tree and receives a strictly coarser, smaller cut. Returns a
    CutResult / TemporalState whose leaves are batched the same way — each
    client's slice is bit-identical to a sequential per-client
    `temporal_search` at its own τ. Shared-tree reads are broadcast, so the
    whole batch is one fused device program."""
    cam_positions = jnp.asarray(cam_positions, jnp.float32)
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32),
                            (cam_positions.shape[0],))
    return jax.vmap(temporal_search, in_axes=(None, 0, 0, None, 0))(
        tree, states, cam_positions, focal, taus)


def batched_cut_mask(cut: CutResult, tree: LodTree) -> jax.Array:
    """(B, N_pad) global cut masks from a batched CutResult.

    (`CutResult.mask` flattens all axes of slab_cut and is only correct for
    the unbatched case.)"""
    b = cut.top_cut.shape[0]
    return jnp.concatenate([cut.top_cut, cut.slab_cut.reshape(b, -1)], axis=1)


# -- host-driven variant (real wall-clock savings) ---------------------------


def pow2_bucket(n: int, cap: int) -> int:
    """Round `n` up to a power of two, clamped to [1, cap].

    The ONE bounded-recompilation bucket policy shared by every host-driven
    scheduler: the hybrid stale-slab sweep here, the service's pooled
    (client, slab) compaction and encode-once union width
    (repro.serve), the fleet occupied-tile pooling (repro.render), and the
    fleet capacity buckets of the lifecycle layer (repro.serve.fleet) —
    regression-pinned by tests/test_lod_search.py."""
    b = 1 << int(np.ceil(np.log2(max(n, 1))))
    return max(1, min(b, cap))


@functools.partial(jax.jit, static_argnames=("max_depth",))
def _sweep_selected(slab_mu, slab_size, slab_parent, slab_level, slab_is_leaf,
                    slab_valid, rpe_sel, cam_pos, focal, tau, max_depth: int):
    fn = functools.partial(_slab_sweep_one, cam_pos=cam_pos, focal=focal, tau=tau,
                           max_depth=max_depth)
    return jax.vmap(fn)(slab_mu, slab_size, slab_parent, slab_level,
                        slab_is_leaf, slab_valid, rpe_sel)


@functools.partial(jax.jit, static_argnames=())
def _top_and_staleness(tree: LodTree, state: TemporalState, cam_pos, focal, tau):
    top_expand, top_cut = top_sweep(tree, cam_pos, focal, tau)
    rpe = _root_parent_expand(tree, top_expand)
    moved = jnp.linalg.norm(cam_pos - state.cam0, axis=-1)
    stale = (~state.swept) | (moved >= state.rho) | (rpe != state.parent_expand0)
    return top_cut, rpe, stale


@functools.partial(jax.jit, static_argnames=("mesh",))
def batched_top_and_staleness(tree: LodTree, states: TemporalState,
                              cam_positions: jax.Array, focal, tau,
                              active=None, *, mesh=None):
    """Per-client cheap phase of the hybrid search: exact top-tree sweep +
    per-subtree staleness predicate, vmapped over B clients. `tau` is a
    scalar or a (B,) per-client vector (foveated LoD).

    Returns (top_cut (B,T), rpe (B,Ns), stale (B,Ns)). The expensive phase —
    sweeping only the stale (client, slab) pairs — is host-scheduled across
    clients by repro.serve.lod_service.

    `active` is an optional (B,) bool slot mask (the ragged-fleet lifecycle
    of repro.serve.fleet): inactive slots report ZERO staleness, so they add
    no pairs to the pooled sweep bucket and no pressure to the pool-size
    scalar the host awaits — sweep work tracks the fleet's *active*
    staleness, not its slot capacity.

    `mesh` (STATIC; a fleet mesh, repro.sharding.fleet) constrains the
    per-client outputs on the `clients` axis, so each client shard computes
    its own staleness rows — the cross-host staleness pool's cheap phase
    never gathers the fleet."""
    cam_positions = jnp.asarray(cam_positions, jnp.float32)
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32),
                            (cam_positions.shape[0],))
    top_cut, rpe, stale = jax.vmap(
        _top_and_staleness, in_axes=(None, 0, 0, None, 0))(
        tree, states, cam_positions, focal, taus)
    if active is not None:
        stale = stale & active[:, None]
    if mesh is not None:
        from repro.sharding.fleet import constrain_fleet
        top_cut = constrain_fleet(top_cut, ("clients", None), mesh)
        rpe = constrain_fleet(rpe, ("clients", None), mesh)
        stale = constrain_fleet(stale, ("clients", None), mesh)
    return top_cut, rpe, stale


@functools.partial(jax.jit, static_argnames=())
def predicted_stale_counts(tree: LodTree, states: TemporalState,
                           cam_positions: jax.Array, focal, tau,
                           active=None) -> jax.Array:
    """(B,) int32 — how many slab subtrees each client WOULD resweep if it
    were synced right now, without touching any state.

    A pure read-only preview of the staleness predicate of
    `batched_top_and_staleness`: the same top sweep + per-subtree staleness
    test runs, but nothing is scattered back, so calling this between syncs
    is side-effect free. This is the feature the deadline scheduler's
    per-slot sync-cost model consumes (repro.serve.scheduler): predicted
    sweep cost is affine in the stale-pair count, so the scheduler can
    budget a tick's participation set before dispatching the real sync.
    Inactive slots (and slots masked out by `active`) predict zero."""
    _, _, stale = jax.vmap(
        _top_and_staleness, in_axes=(None, 0, 0, None, 0))(
        tree, states, jnp.asarray(cam_positions, jnp.float32), focal,
        jnp.broadcast_to(jnp.asarray(tau, jnp.float32),
                         (jnp.asarray(cam_positions).shape[0],)))
    if active is not None:
        stale = stale & active[:, None]
    return stale.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_depth",))
def sweep_slab_camera_pairs(slab_mu, slab_size, slab_parent, slab_level,
                            slab_is_leaf, slab_valid, rpe_sel, cam_sel,
                            focal, tau, max_depth: int):
    """Sweep K (slab, camera) pairs in one vmapped program.

    Unlike `_sweep_selected` (one shared camera), every pair carries its own
    camera position — and its own τ when `tau` is a (K,) vector (foveated
    fleets pool pairs of clients with different thresholds into the same
    bucket) — the primitive behind the cross-client pooled scheduler, where
    stale slabs of *different* clients share one bucketed dispatch.
    Returns (in_cut (K,S), root_expand (K,), rho (K,))."""
    k = slab_size.shape[0]
    taus = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (k,))

    def fn(mu, size, parent, level, leaf, valid, rpe, cam, tau_k):
        return _slab_sweep_one(mu, size, parent, level, leaf, valid, rpe,
                               cam, focal, tau_k, max_depth=max_depth)

    return jax.vmap(fn)(slab_mu, slab_size, slab_parent, slab_level,
                        slab_is_leaf, slab_valid, rpe_sel, cam_sel, taus)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _apply_slab_updates(slab_cut, root_expand, rho, cam0, sel, f_cut, f_rexp,
                        f_rho, cam_pos):
    """In-place (donated) state update — avoids re-copying the whole slab
    state every frame in the host-driven loop."""
    return (slab_cut.at[sel].set(f_cut),
            root_expand.at[sel].set(f_rexp),
            rho.at[sel].set(f_rho),
            cam0.at[sel].set(cam_pos[None, :]))


def temporal_search_hybrid(tree: LodTree, state: TemporalState, cam_pos,
                           focal: float, tau: float
                           ) -> Tuple[CutResult, TemporalState]:
    """Host-driven temporal search: only stale slabs are gathered and swept.

    Shapes are bucketed to powers of two to bound recompilation. Returns the
    same bit-accurate result as `temporal_search`."""
    m = tree.meta
    cam_pos = jnp.asarray(cam_pos, jnp.float32)
    top_cut, rpe, stale = _top_and_staleness(tree, state, cam_pos, focal, tau)
    stale_np = np.asarray(stale)
    idx = np.nonzero(stale_np)[0]
    n_stale = len(idx)

    slab_cut = state.slab_cut0
    root_expand = state.root_expand0
    rho = state.rho
    cam0 = state.cam0

    if n_stale > 0:
        bucket = pow2_bucket(n_stale, m.Ns)
        pad = np.resize(idx, bucket)  # repeat-pad; duplicates are harmless
        sel = jnp.asarray(pad)
        f_cut, f_rexp, f_rho = _sweep_selected(
            tree.slab_mu()[sel], tree.slab_size()[sel], tree.slab_parent[sel],
            tree.slab_level[sel], tree.slab_is_leaf[sel], tree.slab_valid[sel],
            rpe[sel], cam_pos, jnp.float32(focal), jnp.float32(tau),
            tree.meta.slab_max_depth)
        slab_cut, root_expand, rho, cam0 = _apply_slab_updates(
            slab_cut, root_expand, rho, cam0, sel, f_cut, f_rexp, f_rho,
            cam_pos)

    new_state = TemporalState(
        cam0=cam0, rho=rho, parent_expand0=rpe, slab_cut0=slab_cut,
        root_expand0=root_expand, swept=jnp.ones((m.Ns,), bool))
    cut = CutResult(
        top_cut=top_cut, slab_cut=slab_cut, root_expand=root_expand,
        resweep=stale,
        nodes_touched=jnp.asarray(m.T + n_stale * m.S, jnp.int32))
    return cut, new_state


# ---------------------------------------------------------------------------
# cut extraction
# ---------------------------------------------------------------------------


def cut_gids(cut: CutResult, tree: LodTree, budget: int
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compact the cut mask to (budget,) sorted global ids padded with -1.

    Returns (gids, count, overflow)."""
    mask = cut.mask(tree)
    count = mask.sum().astype(jnp.int32)
    (gids,) = jnp.nonzero(mask, size=budget, fill_value=-1)
    return gids.astype(jnp.int32), count, count > budget


# ---------------------------------------------------------------------------
# independent reference oracle (numpy) — ground truth for tests
# ---------------------------------------------------------------------------


def global_parent_np(tree: LodTree) -> np.ndarray:
    """(N_pad,) global parent ids (-1 root, -2 padding)."""
    m = tree.meta
    sp = np.asarray(tree.slab_parent)
    valid = np.asarray(tree.slab_valid)
    base = m.T + np.arange(m.Ns)[:, None] * m.S
    gp_slab = np.where(sp >= 0, base + sp,
                       np.asarray(tree.slab_root_parent_top)[:, None])
    gp_slab = np.where(valid, gp_slab, -2)
    return np.concatenate([np.asarray(tree.top_parent), gp_slab.reshape(-1)])


def global_level_np(tree: LodTree) -> np.ndarray:
    m = tree.meta
    top_level = np.zeros(m.T, np.int32)
    offs = m.top_level_offsets
    for l in range(m.P):
        top_level[offs[l]:offs[l + 1]] = l
    sl = np.asarray(tree.slab_level) + m.P
    sl = np.where(np.asarray(tree.slab_valid), sl, 2**30)
    return np.concatenate([top_level, sl.reshape(-1)])


def reference_search_np(tree: LodTree, cam_pos, focal: float, tau: float
                        ) -> np.ndarray:
    """Brute-force level-iteration over the whole tree. Returns (N_pad,) cut mask."""
    m = tree.meta
    mu = np.asarray(tree.gaussians.mu)
    size = np.asarray(tree.size)
    valid = np.asarray(tree.valid_mask())
    parent = global_parent_np(tree)
    level = global_level_np(tree)
    is_leaf = np.concatenate([np.asarray(tree.top_is_leaf),
                              np.asarray(tree.slab_is_leaf).reshape(-1)])

    dist = np.linalg.norm(mu - np.asarray(cam_pos, np.float32), axis=1)
    gt = size * focal / np.maximum(dist, _EPS_DIST) > tau

    n = mu.shape[0]
    expand = np.zeros(n, bool)
    in_cut = np.zeros(n, bool)
    max_level = m.P + max(m.slab_max_depth, 0)
    for l in range(max_level + 1):
        at = (level == l) & valid
        pe = np.where(parent[at] < 0, l == 0, expand[np.clip(parent[at], 0, None)])
        expand[at] = pe & gt[at]
        in_cut[at] = pe & (~gt[at] | is_leaf[at])
    return in_cut
