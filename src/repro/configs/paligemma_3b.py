"""paligemma-3b [vlm] — SigLIP frontend STUB (precomputed patch embeddings),
gemma backbone, prefix-LM over the image tokens. [arXiv:2407.07726; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257216, head_dim=256, n_img_tokens=256,
)
