"""Assigned architecture registry: --arch <id> → ModelConfig."""

from repro.configs.qwen2_5_3b import CONFIG as QWEN25_3B
from repro.configs.mistral_large_123b import CONFIG as MISTRAL_LARGE_123B
from repro.configs.gemma3_4b import CONFIG as GEMMA3_4B
from repro.configs.stablelm_1_6b import CONFIG as STABLELM_16B
from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE_MOE
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_27B

ARCHS = {c.name: c for c in [
    QWEN25_3B, MISTRAL_LARGE_123B, GEMMA3_4B, STABLELM_16B, GRANITE_MOE,
    QWEN3_MOE, SEAMLESS_M4T, PALIGEMMA_3B, XLSTM_350M, ZAMBA2_27B,
]}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
