"""seamless-m4t-medium [audio] — enc-dec backbone; audio frontend is a STUB
(precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, head_dim=64, n_enc_layers=12, audio_downsample=4,
)
