"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (6:1 pattern). [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=6, mamba_expand=2,
)
