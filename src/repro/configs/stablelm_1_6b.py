"""stablelm-1.6b [dense] — MHA, partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, head_dim=64, rotary_pct=0.25,
)
