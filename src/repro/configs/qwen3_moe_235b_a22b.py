"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-*; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151936, head_dim=64, n_experts=128, top_k=8, rope_theta=1e6,
)
