"""Jitted train/serve steps with full sharding annotations.

`make_train_step` builds the donated, sharded step used by both the real
trainer and the 512-device dry-run: in_shardings come from the logical-axis
trees, activations are constrained via the ambient rules context."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import ModelBundle
from repro.sharding.context import activation_rules, use_rules
from repro.train import grad_compress, optimizer as opt


def make_loss_fn(model: ModelBundle):
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(model: ModelBundle, ocfg: opt.OptimizerConfig,
                    compress_grads: bool = False):
    """(params, opt_state[, grad_error], batch) → (params, opt_state[, err],
    metrics). Pure; jit/shard outside."""

    def step(params, opt_state, grad_error, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        if compress_grads:
            grads, grad_error, qerr = grad_compress.compress_grads_ef(
                grads, grad_error)
        else:
            qerr = jnp.float32(0.0)
        params, opt_state, metrics = opt.apply_updates(params, grads,
                                                       opt_state, ocfg)
        metrics = dict(metrics, loss=loss, quant_err=qerr)
        return params, opt_state, grad_error, metrics

    return step


def make_serve_step(model: ModelBundle, mode: str):
    """decode: (params, cache, batch) → (logits, cache);
    prefill: (params, batch) → (logits, cache)."""
    if mode == "decode":
        def step(params, cache, batch):
            return model.decode_step(params, cache, batch)
        return step
    if mode == "prefill":
        def step(params, batch):
            return model.prefill(params, batch)
        return step
    raise ValueError(mode)
