"""int8 gradient compression with error feedback.

This is the framework-level transfer of the paper's Δ-streaming idea (send
only what matters, quantized, with state that keeps both sides consistent —
DESIGN.md §4): per-tensor symmetric int8 quantization before the cross-pod
gradient reduction, with the quantization residual fed back into the next
step (error feedback preserves convergence). On a real fleet the int8
payload crosses DCN between pods; in-pod reductions stay bf16/fp32."""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_ef(grads: Any, error: Any) -> Tuple[Any, Any, jax.Array]:
    """Quantize (grads + carried error); return (dequantized grads that the
    optimizer consumes, new error, mean relative quantization error)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress(gf)
        deq = decompress(q, s)
        return deq, gf - deq

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    num = sum(jnp.sum(jnp.abs(e)) for e in jax.tree.leaves(new_err))
    den = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)) + 1e-12
    return deq, new_err, num / den


def wire_bytes(grads: Any) -> int:
    """int8 payload size (vs 4 bytes fp32 / 2 bytes bf16)."""
    return sum(int(jnp.size(g)) for g in jax.tree.leaves(grads)) + \
        8 * len(jax.tree.leaves(grads))
