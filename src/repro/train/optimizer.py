"""AdamW with fp32 state over (possibly bf16) params + schedule + clipping.

Optimizer state shards exactly like the params (same logical axes), which is
what makes the big configs fit: m/v inherit the FSDP×TP layout."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def state_axes(param_axes: Any) -> Any:
    """Optimizer-state logical axes = param axes (m and v mirror params)."""
    return AdamWState(step=(), m=param_axes, v=param_axes)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: OptimizerConfig) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
