"""Fault-tolerant training loop.

At 1000-node scale the controller must survive: worker exceptions (restore
latest checkpoint and continue), preemption (atomic async checkpoints +
deterministic data), and stragglers (per-step wall-time watchdog with EMA
outlier detection). All three behaviors are implemented here and unit-tested
with fault injection (tests/test_trainer.py)."""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import DataConfig, PrefetchLoader, SyntheticTokens
from repro.models.model_zoo import ModelBundle
from repro.train import grad_compress, optimizer as opt
from repro.train.train_step import make_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    compress_grads: bool = False
    max_restarts: int = 3
    straggler_ema: float = 0.9
    straggler_factor: float = 3.0   # step > factor × EMA ⇒ flagged


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA step-time monitor. On a fleet this feeds the controller's
    replace/deschedule decision; here it records + logs flags."""

    ema: float = 0.0
    factor: float = 3.0
    alpha: float = 0.9
    warmup: int = 2  # first steps include jit compile — never representative
    _seen: int = 0
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self._seen < self.warmup:
            self._seen += 1
            self.ema = dt  # overwrite: last warmup step seeds the EMA
            return False
        is_straggler = dt > self.factor * self.ema
        if is_straggler:
            self.flagged.append(step)
            log.warning("straggler step %d: %.3fs vs EMA %.3fs", step, dt,
                        self.ema)
        else:  # don't pollute the EMA with outliers
            self.ema = self.alpha * self.ema + (1 - self.alpha) * dt
        return is_straggler


class Trainer:
    def __init__(self, model: ModelBundle, ocfg: opt.OptimizerConfig,
                 tcfg: TrainerConfig, data_cfg: DataConfig,
                 step_hook: Optional[Callable[[int], None]] = None):
        self.model = model
        self.ocfg = ocfg
        self.tcfg = tcfg
        self.data_cfg = data_cfg
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints)
        self.watchdog = StragglerWatchdog(factor=tcfg.straggler_factor,
                                          alpha=tcfg.straggler_ema)
        self.step_hook = step_hook  # fault-injection point for tests
        self._step_fn = jax.jit(make_train_step(model, ocfg,
                                                tcfg.compress_grads))
        self.history: List[Dict[str, float]] = []
        self.restarts = 0

    # -- state ----------------------------------------------------------------

    def init_state(self, seed: int = 0):
        params, _ = self.model.init(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
        err = (grad_compress.init_error(params)
               if self.tcfg.compress_grads else None)
        return {"params": params, "opt": opt_state, "err": err}

    def _save(self, step: int, state):
        tree = {"params": state["params"], "opt": state["opt"]}
        if state["err"] is not None:
            tree["err"] = state["err"]
        self.ckpt.save_async(step, tree, extras={"step": step,
                                                 "data_seed": self.data_cfg.seed})

    def _restore(self, state):
        step = self.ckpt.latest()
        if step is None:
            return 0, state
        like = {"params": state["params"], "opt": state["opt"]}
        if state["err"] is not None:
            like["err"] = state["err"]
        tree = self.ckpt.restore(like, step)
        out = {"params": tree["params"], "opt": tree["opt"],
               "err": tree.get("err", state["err"])}
        return step, out

    # -- loop -----------------------------------------------------------------

    def run(self, resume: bool = True) -> Dict[str, Any]:
        state = self.init_state()
        start = 0
        if resume and self.ckpt.latest() is not None:
            start, state = self._restore(state)
            log.info("resumed from step %d", start)

        source = SyntheticTokens(self.data_cfg)
        loader = PrefetchLoader(source, start_step=start)
        step = start
        try:
            while step < self.tcfg.total_steps:
                try:
                    step, state = self._run_span(loader, step, state)
                except KeyboardInterrupt:
                    raise
                except Exception as e:  # worker failure → restore & continue
                    self.restarts += 1
                    log.exception("step %d failed (%s); restart %d/%d", step,
                                  e, self.restarts, self.tcfg.max_restarts)
                    if self.restarts > self.tcfg.max_restarts:
                        raise
                    loader.close()
                    step, state = self._restore(self.init_state())
                    loader = PrefetchLoader(source, start_step=step)
        finally:
            loader.close()
            self.ckpt.wait()
        self._save(step, state)
        self.ckpt.wait()
        return {"state": state, "history": self.history,
                "stragglers": self.watchdog.flagged, "restarts": self.restarts,
                "final_step": step}

    def _run_span(self, loader, step: int, state):
        while step < self.tcfg.total_steps:
            got_step, batch = next(loader)
            assert got_step == step, (got_step, step)
            t0 = time.perf_counter()
            if self.step_hook is not None:  # inside the timed+guarded region
                self.step_hook(step)
            params, opt_state, err, metrics = self._step_fn(
                state["params"], state["opt"], state["err"], batch)
            loss = float(metrics["loss"])  # blocks → true step time
            dt = time.perf_counter() - t0
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            state = {"params": params, "opt": opt_state, "err": err}
            self.watchdog.observe(step, dt)
            self.history.append({"step": step, "loss": loss, "time": dt,
                                 "grad_norm": float(metrics["grad_norm"])})
            step += 1
            if step % self.tcfg.checkpoint_every == 0:
                self._save(step, state)
            if step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", step, loss, dt * 1e3)
        return step, state
