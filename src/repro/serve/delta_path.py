"""Encode-once fleet Δcut delivery (cross-client payload dedup).

The per-client service path encodes and ships every client's Δcut
independently — B co-located viewers pay B× codec work and B× downlink for
the *same* Gaussians. This module rebuilds that data path around the fleet's
**unique** work:

  * `build_delta_batch` computes the fleet-union of Δcut gids for one sync
    (the batched `SyncPlan.delta_data` masks already expose the overlap),
    gathers the union rows from the shared tree ONCE, and runs the codec
    quantize/pack ONCE per distinct Gaussian — a single batched
    `compression.encode` regardless of client count;
  * per-client payloads are fanned out as *(union-offset, mask)* references
    (`DeltaBatch.ref_mask`): client b's Δcut is exactly the union rows where
    `ref_mask[b]` is set, in the same ascending-gid order the per-client
    path would have produced — so decode-side payloads are bitwise identical
    to encode-per-client (proven in tests/test_delta_path.py);
  * when the sync's union exceeds the stream budget the union is **paged**,
    never truncated: rows are ranked coarse-LoD-first (low tree depth, ties
    by fleet requester count, then gid), the top `budget` ranks ship this
    sync as `page_size`-row priority pages, and every row left behind is
    reported in `DeltaBatch.deferred` — the service carries it into the
    NEXT sync's union as forced-stale membership, so a client's store
    converges bitwise to the unbudgeted oracle in ≤ ⌈U/width⌉ syncs
    (tests/test_delta_path.py). Per-client `allowance` caps the rows a
    single client ingests per sync (the closed-loop bitrate controller in
    repro.serve.lod_service sets it from measured wire bytes);
  * the wire model is a shared multicast stream + thin per-client framing:

        shared   : page headers + union gids (delta-coded ids, ascending
                   within each page) + encoded attribute rows
        per-client: cut add/remove ids + sync header  (unchanged)

    A client filters the shared stream by itself: it knows its render cut
    (`cut_add`/`cut_remove` ids) and its own store, so its Δ membership
    (`needed & ~has`) is locally computable — no per-client row index list
    is ever transmitted. Shared-stream bytes therefore grow with the number
    of *unique* Gaussians in the sync, not with B.

`manager.batched_wire_bytes(..., shared_payload=True)` holds the byte
accounting for this format (each shared row's cost split across its
requesters, so per-client stats still sum to fleet totals) — it charges a
client only for rows it actually ingested this sync (`DeltaBatch.delivered`)
plus `PAGE_HEADER_BYTES` per priority page it pulled rows from; deferred
rows cost nothing until they ship.

The single-client `core.pipeline` path keeps the old unicast wire format via
`compression.encode_rows` (same gather + codec helper, B=1, no union
stream).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core.gaussians import Gaussians


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One sync's encode-once fleet payload (one page-set of the union).

    union_gids: (U,) int32 — ascending global ids of the rows SHIPPED this
                sync, -1 padded (U is the pow2 stream width ≤ the budget)
    n_union:    () int32 — TRUE union size this sync (shipped + deferred ==
                unique Gaussians wanted, including carried-over debt)
    n_shipped:  () int32 — rows actually in this sync's stream (≤ n_union;
                equal unless the union overflowed the budget)
    payload:    EncodedGaussians with U rows — the codec ran ONCE, on the
                shipped rows; rows past n_shipped are padding
    ref_mask:   (B, U) bool — stream rows client b INGESTS this sync (its
                wanted rows among the shipped set, clipped to its per-client
                row allowance), aligned with union_gids
    delivered:  (B, N) bool — node-indexed view of ref_mask (what lands in
                client b's store this sync; drives the wire accounting)
    deferred:   (B, N) bool — rows client b wanted that did NOT ship to it
                this sync (union overflow or allowance) — the carry-over the
                service folds into the next sync's union
    client_overflow: (B,) bool — client b has ≥1 deferred row this sync
    client_pages: (B,) int32 — priority pages client b pulled rows from
                (page-header framing charge)
    pages:      () int32 — priority pages in this sync's shared stream
                (⌈n_shipped/page_size⌉)
    row_page:   (U,) int32 — the PRIORITY page each wire-order row shipped
                in (-1 for padding rows past n_shipped). Wire order is
                ascending-gid but pages are priority ranks, so a page's rows
                are interleaved through the stream — this map is what lets a
                client turn "page p failed its checksum" into the exact row
                set to NACK.
    overflow:   () bool — some row was deferred somewhere in the fleet (the
                old truncation flag, now recoverable instead of a silent
                loss)
    """

    union_gids: jax.Array
    n_union: jax.Array
    n_shipped: jax.Array
    payload: comp.EncodedGaussians
    ref_mask: jax.Array
    delivered: jax.Array
    deferred: jax.Array
    client_overflow: jax.Array
    client_pages: jax.Array
    pages: jax.Array
    row_page: jax.Array
    overflow: jax.Array

    @property
    def n_clients(self) -> int:
        return self.ref_mask.shape[0]


@jax.jit
def _union_mask(delta_masks: jax.Array):
    union = jnp.any(delta_masks, axis=0)               # (N,)
    return union, union.sum().astype(jnp.int32)


_PRIO_PAD = jnp.int32(2**31 - 1)  # non-members sort after every real row


@functools.partial(jax.jit, static_argnames=("width", "page_size", "mesh"))
def _union_refs(wanted: jax.Array, union: jax.Array, priority: jax.Array,
                allowance: jax.Array, width: int, page_size: int, mesh=None):
    """Priority-ordered page selection of one sync's union.

    Ranks every union row by (tree depth asc, requester count desc, gid asc)
    — coarse LoD ships first, ties broken toward the most-shared rows — and
    ships the top `width` ranks. The stream itself stays ASCENDING by gid
    (delta-coded ids; each page is internally ascending), so the shipped
    subset decodes exactly like the unpaged format. `allowance` (B,) caps
    the rows each client ingests this sync, counted in priority order, so a
    bandwidth-tiered client takes the coarsest pages first and defers the
    rest. Returns everything the batch needs: the wire-order gids/refs, the
    node-indexed delivered/deferred masks, and the page accounting."""
    b, n = wanted.shape
    gid = jnp.arange(n, dtype=jnp.int32)
    req = wanted.sum(axis=0).astype(jnp.int32)
    k1 = jnp.where(union, priority.astype(jnp.int32), _PRIO_PAD)
    k1s, _, by_rank = jax.lax.sort((k1, -req, gid), num_keys=3)
    take = by_rank[:width]                       # gids, priority order
    valid = k1s[:width] != _PRIO_PAD             # rank is a real union row
    n_shipped = valid.sum().astype(jnp.int32)

    # per-client ingest: its wanted rows among the shipped ranks, first
    # `allowance` of them in priority order
    ref_rank = wanted[:, take] & valid[None, :]              # (B, width)
    cum = jnp.cumsum(ref_rank.astype(jnp.int32), axis=1)
    ingest = ref_rank & (cum <= allowance[:, None])

    # page accounting: rank r lives in page r // page_size
    n_pages = max(1, -(-width // page_size))
    page_of = jnp.arange(width, dtype=jnp.int32) // page_size
    pages_hit = jnp.zeros((b, n_pages), bool).at[:, page_of].max(ingest)
    client_pages = pages_hit.sum(axis=1).astype(jnp.int32)
    pages = ((n_shipped + page_size - 1) // page_size).astype(jnp.int32)

    # node-indexed views: what landed, what is owed
    delivered = jnp.zeros((b, n), bool).at[:, take].max(ingest)
    deferred = wanted & ~delivered
    client_overflow = deferred.any(axis=1)

    # wire order: shipped gids ascending (invalid ranks sort last, pad -1)
    order = jnp.argsort(jnp.where(valid, take, jnp.int32(n)))
    gids = jnp.where(valid[order], take[order], -1).astype(jnp.int32)
    ref = ingest[:, order]
    row_page = jnp.where(valid[order], page_of[order], -1).astype(jnp.int32)
    if mesh is not None:
        from repro.sharding.fleet import constrain_fleet
        # the union row axis shards over `slabs` (codec work parallelism);
        # per-client leaves stay with their client shard
        gids = constrain_fleet(gids, ("union",), mesh)
        ref = constrain_fleet(ref, ("clients", "union"), mesh)
        row_page = constrain_fleet(row_page, ("union",), mesh)
        delivered = constrain_fleet(delivered, ("clients", None), mesh)
        deferred = constrain_fleet(deferred, ("clients", None), mesh)
        client_overflow = constrain_fleet(client_overflow, ("clients",), mesh)
        client_pages = constrain_fleet(client_pages, ("clients",), mesh)
    return (gids, ref, delivered, deferred, client_overflow, client_pages,
            pages, n_shipped, row_page)


def build_delta_batch(gaussians: Gaussians, codec: comp.Codec,
                      delta_masks: jax.Array, budget: int,
                      active=None, mesh=None, *, pending=None, priority=None,
                      allowance=None, page_size=None) -> DeltaBatch:
    """Encode one sync's fleet Δcut once, paged under the budget.

    delta_masks: (B, N) bool — the batched `SyncPlan.delta_data`.
    budget: static cap on the encoded stream (rows). A union larger than the
    budget is NOT truncated: the coarsest `budget` priority ranks ship now
    and the rest comes back in `deferred` for the caller to fold into the
    next sync (`overflow` flags that some row was deferred).
    pending: optional (B, N) bool carry-over debt from earlier syncs
    (rows deferred then) — unioned into this sync's wanted set, so a
    deferred Gaussian keeps competing for stream slots until it ships.
    priority: optional (N,) int32 rank key, lower ships first (the service
    passes `LodTree.node_levels()` — coarse LoD first); default 0 everywhere
    (requester count / gid order only).
    allowance: optional (B,) int32 per-client row cap for this sync (the
    closed-loop bitrate controller's knob); default unlimited.
    page_size: rows per priority page (accounting granularity for the
    per-page wire header); default one page spanning the whole stream.
    active: optional (B,) bool slot mask (ragged fleets, repro.serve.fleet)
    — an inactive slot contributes NO rows to the union (its `ref_mask` row
    stays all-False and no Gaussian is encoded on its behalf), so the
    encode-once stream and its pow2 width track the *active* fleet only.

    The encode width is pow2-bucketed on the ACTUAL union size (one scalar
    await — the same bounded-recompilation pattern as the pooled stale-slab
    scheduler), so codec quantize/pack FLOPs track the sync's unique
    Gaussians, not the static budget: a steady-state sync with a tiny union
    encodes a tiny bucket, never the whole budget.

    Sharded fleets (`mesh`, repro.sharding.fleet): the union `any` over
    clients is a CROSS-SHARD reduction — the union mask, its gids, and the
    encoded payload come back REPLICATED across client shards (the
    replicated-union fallback: every host holds the full multicast stream,
    which is the wire model anyway — the stream is broadcast to every
    client). Codec quantize/pack work is sharded along the union row axis
    over the `slabs` mesh axis when the pow2 width divides; an indivisible
    width replicates the encode (bitwise identical either way —
    tests/test_sharding_fleet.py)."""
    if active is not None:
        delta_masks = delta_masks & active[:, None]
        if pending is not None:
            pending = pending & active[:, None]
    wanted = delta_masks if pending is None else delta_masks | pending
    union, n_union = _union_mask(wanted)
    n = int(jax.device_get(n_union))
    width = ls.pow2_bucket(n, budget)
    b = wanted.shape[0]
    if priority is None:
        priority = jnp.zeros((wanted.shape[1],), jnp.int32)
    allow = (jnp.full((b,), width, jnp.int32) if allowance is None
             else jnp.asarray(allowance, jnp.int32))
    psize = width if page_size is None else max(1, min(int(page_size), width))
    (gids, ref, delivered, deferred, client_overflow, client_pages, pages,
     n_shipped, row_page) = _union_refs(wanted, union, priority, allow,
                                        width=width, page_size=psize,
                                        mesh=mesh)
    payload = comp.encode_rows(codec, gaussians, gids)
    if mesh is not None:
        from repro.sharding.fleet import constrain_fleet
        payload = jax.tree_util.tree_map(
            lambda a: constrain_fleet(
                a, ("union",) + (None,) * (a.ndim - 1), mesh), payload)
    return DeltaBatch(union_gids=gids, n_union=n_union, n_shipped=n_shipped,
                      payload=payload, ref_mask=ref, delivered=delivered,
                      deferred=deferred, client_overflow=client_overflow,
                      client_pages=client_pages, pages=pages,
                      row_page=row_page, overflow=client_overflow.any())


def decode_client(codec: comp.Codec, batch: DeltaBatch, sh_k: int,
                  client: int) -> Tuple[jax.Array, Gaussians]:
    """One client's decoded Δcut from the shared stream.

    Returns (ids (U,) int32 — this client's gids, -1 where the union row is
    not referenced — and the decoded union rows (U,)). Scattering rows where
    ids >= 0 into the client store reproduces the encode-per-client path
    bit-for-bit (the codec is row-wise deterministic and union rows keep
    ascending-gid order)."""
    dec = comp.decode(codec, batch.payload, sh_k)
    ids = jnp.where(batch.ref_mask[client], batch.union_gids, -1)
    return ids, dec


def encode_per_client(gaussians: Gaussians, codec: comp.Codec,
                      delta_masks: jax.Array, budget: int):
    """Reference path: encode every client's Δcut independently (B codec
    calls). Returns per-client (ids (budget,) int32 -1 padded ascending,
    EncodedGaussians, overflow () bool). `overflow` is true when the
    client's Δ exceeded the budget and its unicast stream was TRUNCATED —
    parity fixtures must assert it false, otherwise dedup-vs-baseline
    comparisons can pass with both paths silently wrong (the bug this flag
    closes). Exists as the baseline the dedup path is proven against — and
    as the measuring stick for `dedup_bytes_saved`."""
    out = []
    for b in range(delta_masks.shape[0]):
        count = delta_masks[b].sum().astype(jnp.int32)
        (ids,) = jnp.nonzero(delta_masks[b], size=budget, fill_value=-1)
        ids = ids.astype(jnp.int32)
        out.append((ids, comp.encode_rows(codec, gaussians, ids),
                    count > jnp.int32(budget)))
    return out


# ---------------------------------------------------------------------------
# page integrity (loss detection + NACK retransmit)
# ---------------------------------------------------------------------------

# Knuth multiplicative hash constant — mixes each gid before the per-page
# sum so a swap of two gids between pages (same total) still flips both
# checksums; +1 makes the count of rows in the page part of the sum too
# (a dropped gid-0 row would otherwise hash to 0 and vanish).
_CKSUM_MIX = np.uint32(2654435761)


def page_checksums(batch: DeltaBatch) -> np.ndarray:
    """(pages,) uint32 — the per-page content checksum carried in each
    priority page's wire header (`manager.PAGE_HEADER_BYTES` already budgets
    the 4-byte slot). Host-side: checksums are wire framing, computed once
    per sync when the stream is serialized, never inside the jitted sync.

    A page's checksum covers the gids of its rows (order-independent
    wraparound sum of mixed gids), so a receiver that re-derives it over the
    rows it parsed detects any dropped/corrupted page without trusting the
    radio link's own CRC."""
    row_page = np.asarray(batch.row_page)
    gids = np.asarray(batch.union_gids)
    n_pages = int(np.asarray(batch.pages))
    out = np.zeros((max(n_pages, 1),), np.uint32)
    rows = row_page >= 0
    with np.errstate(over="ignore"):
        mix = gids[rows].astype(np.uint32) * _CKSUM_MIX + np.uint32(1)
    np.add.at(out, row_page[rows], mix)
    return out[:n_pages]


def lost_row_mask(batch: DeltaBatch, client: int, lost_pages) -> np.ndarray:
    """(N,) bool node mask of the rows slot `client` INGESTED this sync from
    the given priority pages — the retransmit set for a NACK naming pages
    whose checksum failed client-side. Rows of a lost page the client did
    not reference cost it nothing and are not re-queued."""
    row_page = np.asarray(batch.row_page)
    gids = np.asarray(batch.union_gids)
    ref = np.asarray(batch.ref_mask)[client]
    n = batch.delivered.shape[1]
    lost = np.asarray(sorted(set(int(p) for p in lost_pages)), np.int64)
    rows = ref & np.isin(row_page, lost) & (gids >= 0)
    out = np.zeros((n,), bool)
    out[gids[rows]] = True
    return out


# ---------------------------------------------------------------------------
# dedup accounting
# ---------------------------------------------------------------------------


@jax.jit
def first_owner_counts(delta_masks: jax.Array) -> jax.Array:
    """(B,) int32 — per client, the number of its Δ rows for which it is the
    fleet's *first* requester (lowest client index). Partitions the union:
    `first_owner_counts(m).sum() == unique Gaussians this sync` — the
    `ServiceStats.unique_delta` column."""
    first = delta_masks & (jnp.cumsum(delta_masks, axis=0) == 1)
    return first.sum(axis=1).astype(jnp.int32)
