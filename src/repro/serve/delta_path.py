"""Encode-once fleet Δcut delivery (cross-client payload dedup).

The per-client service path encodes and ships every client's Δcut
independently — B co-located viewers pay B× codec work and B× downlink for
the *same* Gaussians. This module rebuilds that data path around the fleet's
**unique** work:

  * `build_delta_batch` computes the fleet-union of Δcut gids for one sync
    (the batched `SyncPlan.delta_data` masks already expose the overlap),
    gathers the union rows from the shared tree ONCE, and runs the codec
    quantize/pack ONCE per distinct Gaussian — a single batched
    `compression.encode` regardless of client count;
  * per-client payloads are fanned out as *(union-offset, mask)* references
    (`DeltaBatch.ref_mask`): client b's Δcut is exactly the union rows where
    `ref_mask[b]` is set, in the same ascending-gid order the per-client
    path would have produced — so decode-side payloads are bitwise identical
    to encode-per-client (proven in tests/test_delta_path.py);
  * the wire model is a shared multicast stream + thin per-client framing:

        shared   : union gids (delta-coded ids) + encoded attribute rows
        per-client: cut add/remove ids + sync header  (unchanged)

    A client filters the shared stream by itself: it knows its render cut
    (`cut_add`/`cut_remove` ids) and its own store, so its Δ membership
    (`needed & ~has`) is locally computable — no per-client row index list
    is ever transmitted. Shared-stream bytes therefore grow with the number
    of *unique* Gaussians in the sync, not with B.

`manager.batched_wire_bytes(..., shared_payload=True)` holds the byte
accounting for this format (each shared row's cost split across its
requesters, so per-client stats still sum to fleet totals).

The single-client `core.pipeline` path keeps the old unicast wire format via
`compression.encode_rows` (same gather + codec helper, B=1, no union
stream).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core.gaussians import Gaussians


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One sync's encode-once fleet payload.

    union_gids: (U,) int32 — ascending global ids of the fleet-union Δcut,
                -1 padded (U is the static union budget)
    n_union:    () int32 — real union size (== unique Gaussians this sync)
    payload:    EncodedGaussians with U rows — the codec ran ONCE, on the
                union; rows past n_union are padding (never referenced)
    ref_mask:   (B, U) bool — client b's Δcut = union rows where ref_mask[b]
    overflow:   () bool — union exceeded the budget (payload truncated)
    """

    union_gids: jax.Array
    n_union: jax.Array
    payload: comp.EncodedGaussians
    ref_mask: jax.Array
    overflow: jax.Array

    @property
    def n_clients(self) -> int:
        return self.ref_mask.shape[0]


@jax.jit
def _union_mask(delta_masks: jax.Array):
    union = jnp.any(delta_masks, axis=0)               # (N,)
    return union, union.sum().astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("width", "mesh"))
def _union_refs(delta_masks: jax.Array, union: jax.Array, width: int,
                mesh=None):
    (gids,) = jnp.nonzero(union, size=width, fill_value=-1)
    gids = gids.astype(jnp.int32)
    ref = delta_masks[:, jnp.clip(gids, 0)] & (gids >= 0)[None, :]
    if mesh is not None:
        from repro.sharding.fleet import constrain_fleet
        # the union row axis shards over `slabs` (codec work parallelism);
        # ref_mask rows stay with their client shard
        gids = constrain_fleet(gids, ("union",), mesh)
        ref = constrain_fleet(ref, ("clients", "union"), mesh)
    return gids, ref


def build_delta_batch(gaussians: Gaussians, codec: comp.Codec,
                      delta_masks: jax.Array, budget: int,
                      active=None, mesh=None) -> DeltaBatch:
    """Encode one sync's fleet Δcut once.

    delta_masks: (B, N) bool — the batched `SyncPlan.delta_data`.
    budget: static cap on the encoded stream (rows). Correctness requires
    budget >= the true union size; `overflow` flags truncation.
    active: optional (B,) bool slot mask (ragged fleets, repro.serve.fleet)
    — an inactive slot contributes NO rows to the union (its `ref_mask` row
    stays all-False and no Gaussian is encoded on its behalf), so the
    encode-once stream and its pow2 width track the *active* fleet only.

    The encode width is pow2-bucketed on the ACTUAL union size (one scalar
    await — the same bounded-recompilation pattern as the pooled stale-slab
    scheduler), so codec quantize/pack FLOPs track the sync's unique
    Gaussians, not the static budget: a steady-state sync with a tiny union
    encodes a tiny bucket, never the whole budget.

    Sharded fleets (`mesh`, repro.sharding.fleet): the union `any` over
    clients is a CROSS-SHARD reduction — the union mask, its gids, and the
    encoded payload come back REPLICATED across client shards (the
    replicated-union fallback: every host holds the full multicast stream,
    which is the wire model anyway — the stream is broadcast to every
    client). Codec quantize/pack work is sharded along the union row axis
    over the `slabs` mesh axis when the pow2 width divides; an indivisible
    width replicates the encode (bitwise identical either way —
    tests/test_sharding_fleet.py)."""
    if active is not None:
        delta_masks = delta_masks & active[:, None]
    union, n_union = _union_mask(delta_masks)
    n = int(jax.device_get(n_union))
    width = ls.pow2_bucket(n, budget)
    gids, ref = _union_refs(delta_masks, union, width, mesh=mesh)
    payload = comp.encode_rows(codec, gaussians, gids)
    if mesh is not None:
        from repro.sharding.fleet import constrain_fleet
        payload = jax.tree_util.tree_map(
            lambda a: constrain_fleet(
                a, ("union",) + (None,) * (a.ndim - 1), mesh), payload)
    return DeltaBatch(union_gids=gids, n_union=n_union, payload=payload,
                      ref_mask=ref, overflow=n_union > jnp.int32(width))


def decode_client(codec: comp.Codec, batch: DeltaBatch, sh_k: int,
                  client: int) -> Tuple[jax.Array, Gaussians]:
    """One client's decoded Δcut from the shared stream.

    Returns (ids (U,) int32 — this client's gids, -1 where the union row is
    not referenced — and the decoded union rows (U,)). Scattering rows where
    ids >= 0 into the client store reproduces the encode-per-client path
    bit-for-bit (the codec is row-wise deterministic and union rows keep
    ascending-gid order)."""
    dec = comp.decode(codec, batch.payload, sh_k)
    ids = jnp.where(batch.ref_mask[client], batch.union_gids, -1)
    return ids, dec


def encode_per_client(gaussians: Gaussians, codec: comp.Codec,
                      delta_masks: jax.Array, budget: int):
    """Reference path: encode every client's Δcut independently (B codec
    calls). Returns per-client (ids (budget,) int32 -1 padded ascending,
    EncodedGaussians). Exists as the baseline the dedup path is proven
    against — and as the measuring stick for `dedup_bytes_saved`."""
    out = []
    for b in range(delta_masks.shape[0]):
        (ids,) = jnp.nonzero(delta_masks[b], size=budget, fill_value=-1)
        ids = ids.astype(jnp.int32)
        out.append((ids, comp.encode_rows(codec, gaussians, ids)))
    return out


# ---------------------------------------------------------------------------
# dedup accounting
# ---------------------------------------------------------------------------


@jax.jit
def first_owner_counts(delta_masks: jax.Array) -> jax.Array:
    """(B,) int32 — per client, the number of its Δ rows for which it is the
    fleet's *first* requester (lowest client index). Partitions the union:
    `first_owner_counts(m).sum() == unique Gaussians this sync` — the
    `ServiceStats.unique_delta` column."""
    first = delta_masks & (jnp.cumsum(delta_masks, axis=0) == 1)
    return first.sum(axis=1).astype(jnp.int32)
