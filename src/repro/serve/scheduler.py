"""Deadline-driven motion-to-photon scheduler for the fleet LoD service.

The service's `sync()` is a LOCKSTEP tick: every live client advances
together, so a fast-moving headset waits behind an idle phone and the only
latency the repo could measure was the mean fleet sync cost. This module is
the paper's motion-to-photon (MTP) story for the serving stack: each client
carries a FRAME DEADLINE and a motion-derived priority, and each scheduler
tick syncs only the subset that needs it — through the partial-fleet
participation mask of `LodService.sync(participate=...)`, whose
non-selected slots are provably (bitwise) untouched.

How a tick works (`DeadlineScheduler.tick`):

  1. candidates = live clients with UNSERVED MOTION (`observe_motion`
     queued a head pose the service hasn't synced yet). A client with no
     new motion is never synced — its cut is already right for its pose.
  2. each candidate is scored:
        staleness_ms = ms since its last completed sync
        priority     = staleness_ms * (1 + velocity)    (velocity: an EWMA
                       of |Δcam|/Δt from the observed pose history — the
                       "motion-derived" half: fast heads sort first)
        slack_ms     = deadline_ms - age of its oldest unserved motion
     and candidates sort EDF-style: least slack first, priority breaking
     ties.
  3. selection is BUDGETED by predicted sync cost: a fitted per-tick cost
     model (cost_ms = α + β·stale_pairs, refit online from measured ticks)
     prices each candidate via `lod_search.predicted_stale_counts` — a
     read-only staleness preview that touches no state — and candidates are
     admitted greedily until `tick_budget_ms` is spent. The most urgent
     candidate is ALWAYS selected (the budget shapes the batch, it never
     starves the head of the queue).
  4. one partial sync runs (`service.sync(cams, participate=selected)`),
     is timed to completion, and the measured (stale_pairs, ms) sample
     refits the cost model. The returned per-slot `ServiceStats` carry the
     scheduler-stamped `mtp_ms` (motion sample → sync completion) and
     `deadline_miss` columns for the served slots.

MTP accounting: a client's motion-to-photon sample is the wall-clock time
from its OLDEST unserved `observe_motion` to the completion of the sync
that served it — the serving-side half of the paper's MTP latency (client
decode/render ride on top). `stats_summary()` reduces the rolling window
to p50/p99 MTP and the deadline-miss rate. The clock is injectable, so
tests drive deterministic schedules.

Predicted-cost admission (`DeadlineScheduler.admit`): an admit is DENIED
(`AdmissionDenied`, or None with `required=False`) when the cost model says
the fleet cannot hold the newcomer's deadline — either its own cold first
sync (a full `Ns`-slab resweep) is predicted over its deadline, or the
fleet's aggregate utilization Σ predicted_cost/deadline would exceed 1.
This is backpressure BEFORE state mutation, like the byte-budget admission
of `LodService`.

Crash recovery: `state_dict()` is JSON-able and rides in snapshot extras
(`recovery.snapshot_service(scheduler_state=...)`, or pass
`RecoveryManager(scheduler=...)`); partial ticks journal their participant
ids, so replay re-executes the same partial syncs bitwise.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core import lod_search as ls
from repro.serve.lod_service import (AdmissionDenied, LodService,
                                     ServiceStats)

DEFAULT_DEADLINE_MS = 33.0  # ~30 Hz pose-to-update budget


class CostModel:
    """Per-tick sync cost model: cost_ms = alpha + beta * stale_pairs.

    `alpha` is the fixed per-tick overhead (dispatch, table update, encode
    tail), `beta` the marginal cost of one pooled (client, slab) pair
    sweep. Seeded with pessimistic defaults and refit by least squares over
    a rolling window of measured ticks once the window holds enough spread
    (>= `min_samples` samples with pair variance) — until then predictions
    come from the seed, so admission control works from the first tick."""

    def __init__(self, alpha_ms: float = 2.0, beta_ms: float = 0.02,
                 window: int = 128, min_samples: int = 8):
        self.alpha = float(alpha_ms)
        self.beta = float(beta_ms)
        self.min_samples = int(min_samples)
        self.samples: deque = deque(maxlen=int(window))

    def predict(self, stale_pairs) -> float:
        return float(self.alpha + self.beta * max(float(stale_pairs), 0.0))

    def observe(self, stale_pairs: float, measured_ms: float) -> None:
        """Record one measured tick and refit when the window has signal
        (beta needs pair-count spread; a constant-pairs window only
        re-estimates alpha)."""
        self.samples.append((float(stale_pairs), float(measured_ms)))
        if len(self.samples) < self.min_samples:
            return
        x = np.array([s[0] for s in self.samples], np.float64)
        y = np.array([s[1] for s in self.samples], np.float64)
        if np.ptp(x) > 0.0:
            a = np.stack([np.ones_like(x), x], axis=1)
            coef, *_ = np.linalg.lstsq(a, y, rcond=None)
            alpha, beta = float(coef[0]), float(coef[1])
        else:
            alpha, beta = float(y.mean()), self.beta
        # a degenerate fit (negative marginal cost / overhead) falls back
        # to the seed rather than predicting free work
        self.alpha = max(alpha, 0.0)
        self.beta = max(beta, 0.0)

    def state_dict(self) -> Dict[str, Any]:
        return {"alpha": self.alpha, "beta": self.beta,
                "samples": [list(s) for s in self.samples]}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.alpha = float(state["alpha"])
        self.beta = float(state["beta"])
        self.samples.clear()
        self.samples.extend((float(p), float(m))
                            for p, m in state.get("samples", []))


@dataclasses.dataclass
class _ClientSched:
    """Per-client scheduling state (host-side, keyed by stable id)."""

    deadline_ms: float
    last_cam: np.ndarray                      # last OBSERVED head pose
    velocity: float = 0.0                     # EWMA |Δcam|/Δt (units/s)
    last_sync_at: Optional[float] = None      # completion of last sync
    oldest_motion_at: Optional[float] = None  # oldest unserved pose time
    last_motion_at: Optional[float] = None
    pending_cam: Optional[np.ndarray] = None  # pose awaiting a sync
    ewma_pairs: float = 0.0                   # EWMA stale pairs per sync


class DeadlineScheduler:
    """Deadline/priority scheduler over a live `LodService` (see module
    docstring). `clock` is any zero-arg monotonic-seconds callable
    (default `time.monotonic`); tests inject a scripted one.
    `tick_budget_ms=None` removes the per-tick cost budget (pure EDF)."""

    VELOCITY_SMOOTHING = 0.3
    PAIRS_SMOOTHING = 0.3

    def __init__(self, service: LodService, *,
                 default_deadline_ms: float = DEFAULT_DEADLINE_MS,
                 tick_budget_ms: Optional[float] = None,
                 cost_model: Optional[CostModel] = None,
                 clock=None, window: int = 1024):
        self.service = service
        self.default_deadline_ms = float(default_deadline_ms)
        self.tick_budget_ms = (None if tick_budget_ms is None
                               else float(tick_budget_ms))
        self.cost = CostModel() if cost_model is None else cost_model
        self._clock = time.monotonic if clock is None else clock
        self._clients: Dict[int, _ClientSched] = {}
        # rolling (mtp_ms, missed) samples across the fleet
        self._mtp_samples: deque = deque(maxlen=int(window))
        self._ns = int(service.tree.meta.Ns)
        for cid in service.active_ids:
            self._register(cid, None)

    # -- client registry ------------------------------------------------------

    def _register(self, client_id: int, deadline_ms: Optional[float]):
        slot = self.service._slot_of(client_id)
        self._clients[int(client_id)] = _ClientSched(
            deadline_ms=(self.default_deadline_ms if deadline_ms is None
                         else float(deadline_ms)),
            last_cam=np.array(self.service._slot_cams[slot], np.float32),
            ewma_pairs=float(self._ns))  # pessimistic: cold ⇒ full resweep

    def set_deadline(self, client_id: int, deadline_ms: float) -> None:
        self._clients[int(client_id)].deadline_ms = float(deadline_ms)

    def deadline(self, client_id: int) -> float:
        return self._clients[int(client_id)].deadline_ms

    def forget(self, client_id: int) -> None:
        """Drop a client's scheduling state (pair with `service.evict`)."""
        self._clients.pop(int(client_id), None)

    def evict(self, client_id: int) -> None:
        self.service.evict(client_id)
        self.forget(client_id)

    # -- admission ------------------------------------------------------------

    def predicted_admission_denial(self, deadline_ms: Optional[float] = None
                                   ) -> Optional[str]:
        """Why the next admit must be refused on PREDICTED cost (None =
        admissible). Checked before any state mutation. Two gates:

          * the newcomer's own cold sync — a full Ns-slab resweep — is
            predicted over its deadline (no schedule can serve it);
          * aggregate utilization: Σ predict(ewma_pairs)/deadline over the
            fleet (newcomer included, cold) would exceed 1 — the fleet's
            steady-state demand outruns one sync lane."""
        d = (self.default_deadline_ms if deadline_ms is None
             else float(deadline_ms))
        if d <= 0:
            return f"deadline {d}ms is not positive"
        cold = self.cost.predict(self._ns)
        if cold > d:
            return (f"cold first sync predicted {cold:.2f}ms > deadline "
                    f"{d:.2f}ms")
        util = self.cost.predict(self._ns) / d
        for c in self._clients.values():
            util += self.cost.predict(c.ewma_pairs) / c.deadline_ms
        if util > 1.0:
            return (f"predicted fleet utilization {util:.2f} > 1 with the "
                    f"new client")
        return None

    def admit(self, cam=None, tau: Optional[float] = None,
              deadline_ms: Optional[float] = None, bandwidth=None,
              required: bool = True) -> Optional[int]:
        """`LodService.admit` behind the predicted-cost gate: a client whose
        deadline the cost model says cannot be held is DENIED
        (`AdmissionDenied`, or None with `required=False`) and the service
        is left untouched."""
        denial = self.predicted_admission_denial(deadline_ms)
        if denial is not None:
            if required:
                raise AdmissionDenied(denial)
            return None
        cid = self.service.admit(cam=cam, tau=tau, required=required,
                                 bandwidth=bandwidth)
        if cid is not None:
            self._register(cid, deadline_ms)
            # a new client's first pose is unserved motion: schedule it
            c = self._clients[cid]
            now = self._clock()
            c.pending_cam = c.last_cam.copy()
            c.oldest_motion_at = c.last_motion_at = now
        return cid

    # -- motion ingest --------------------------------------------------------

    def observe_motion(self, client_id: int, cam, t: Optional[float] = None
                       ) -> None:
        """Queue a new head pose for `client_id`. The pose is NOT pushed to
        the service here — it ships with the sync that serves it, so a
        never-selected client's service-side camera stays exactly what its
        last sync used. Velocity is an EWMA of |Δcam|/Δt over observed
        poses."""
        c = self._clients[int(client_id)]
        now = self._clock() if t is None else float(t)
        cam = np.asarray(cam, np.float32)
        if c.last_motion_at is not None and now > c.last_motion_at:
            inst = float(np.linalg.norm(cam - c.last_cam)
                         / (now - c.last_motion_at))
            s = self.VELOCITY_SMOOTHING
            c.velocity = (1 - s) * c.velocity + s * inst
        if c.oldest_motion_at is None:
            c.oldest_motion_at = now
        c.last_motion_at = now
        c.last_cam = cam
        c.pending_cam = cam

    # -- the tick -------------------------------------------------------------

    def _predicted_pairs(self) -> Dict[int, int]:
        """Read-only staleness preview: how many slab subtrees each LIVE
        client would resweep if synced right now, priced per candidate
        against its PENDING pose (`lod_search.predicted_stale_counts` — no
        state is touched). One device round-trip per tick."""
        svc = self.service
        cams = np.array(svc._slot_cams, np.float32)
        for cid, c in self._clients.items():
            if c.pending_cam is not None:
                cams[svc._slot_of(cid)] = c.pending_cam
        taus = (svc.taus if svc.taus is not None
                else np.full(svc.capacity, svc.cfg.tau, np.float32))
        counts = np.asarray(jax.device_get(ls.predicted_stale_counts(
            svc.tree, svc.state.temporal, cams, svc.focal, taus,
            svc.state.fleet.active)))
        return {cid: int(counts[svc._slot_of(cid)])
                for cid in self._clients}

    def select(self, now: Optional[float] = None) -> List[int]:
        """The tick's selection, without running it: EDF over clients with
        unserved motion, greedily budgeted by predicted cost."""
        now = self._clock() if now is None else float(now)
        cands = [cid for cid, c in self._clients.items()
                 if c.pending_cam is not None]
        if not cands:
            return []
        pairs = self._predicted_pairs()

        def urgency(cid):
            c = self._clients[cid]
            staleness_ms = (0.0 if c.last_sync_at is None
                            else (now - c.last_sync_at) * 1e3)
            priority = staleness_ms * (1.0 + c.velocity)
            age_ms = (now - c.oldest_motion_at) * 1e3
            slack = c.deadline_ms - age_ms - self.cost.predict(pairs[cid])
            return (slack, -priority)

        cands.sort(key=urgency)
        if self.tick_budget_ms is None:
            return cands
        selected, spent = [], self.cost.alpha
        for cid in cands:
            marginal = self.cost.beta * pairs[cid]
            if selected and spent + marginal > self.tick_budget_ms:
                continue
            selected.append(cid)
            spent += marginal
        return selected

    def tick(self, now: Optional[float] = None) -> Optional[ServiceStats]:
        """Run one scheduler tick: select, partial-sync, time, refit the
        cost model, stamp MTP columns. Returns the stamped per-slot stats,
        or None when no client had unserved motion (nothing to do — an
        idle fleet costs nothing)."""
        svc = self.service
        selected = self.select(now)
        if not selected:
            return None
        cams = {cid: self._clients[cid].pending_cam for cid in selected}
        t0 = self._clock()
        stats = svc.sync(cams, participate=selected)
        jax.block_until_ready(stats.sync_bytes)
        t_done = self._clock()
        resweeps = np.asarray(jax.device_get(stats.resweeps))
        self.cost.observe(float(resweeps.sum()), (t_done - t0) * 1e3)
        mtp_col = np.zeros(svc.capacity, np.float32)
        miss_col = np.zeros(svc.capacity, bool)
        for cid in selected:
            c = self._clients[cid]
            slot = svc._slot_of(cid)
            s = self.PAIRS_SMOOTHING
            c.ewma_pairs = ((1 - s) * c.ewma_pairs
                            + s * float(resweeps[slot]))
            mtp = (t_done - c.oldest_motion_at) * 1e3
            missed = mtp > c.deadline_ms
            mtp_col[slot] = mtp
            miss_col[slot] = missed
            self._mtp_samples.append((mtp, missed))
            c.last_sync_at = t_done
            c.oldest_motion_at = None
            c.pending_cam = None
        return dataclasses.replace(
            stats, mtp_ms=jax.numpy.asarray(mtp_col),
            deadline_miss=jax.numpy.asarray(miss_col))

    # -- accounting -----------------------------------------------------------

    def stats_summary(self) -> Dict[str, float]:
        """Reduce the rolling MTP window: p50/p99 motion-to-photon ms and
        the deadline-miss rate (fraction of served motion samples that
        overran their client's deadline)."""
        if not self._mtp_samples:
            return {"n": 0, "mtp_p50_ms": 0.0, "mtp_p99_ms": 0.0,
                    "deadline_miss_rate": 0.0}
        mtp = np.array([s[0] for s in self._mtp_samples], np.float64)
        miss = np.array([s[1] for s in self._mtp_samples], bool)
        return {"n": int(mtp.size),
                "mtp_p50_ms": float(np.percentile(mtp, 50)),
                "mtp_p99_ms": float(np.percentile(mtp, 99)),
                "deadline_miss_rate": float(miss.mean())}

    # -- persistence ----------------------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """JSON-able scheduler state for snapshot extras
        (`recovery.snapshot_service(scheduler_state=...)`). Wall-clock
        anchors (last_sync_at / oldest_motion_at) are process-relative and
        deliberately NOT saved — a recovered scheduler restarts its clock;
        deadlines, velocities, the fitted cost model, and per-client pair
        EWMAs survive."""
        return {
            "default_deadline_ms": self.default_deadline_ms,
            "tick_budget_ms": self.tick_budget_ms,
            "cost": self.cost.state_dict(),
            "clients": {
                str(cid): {
                    "deadline_ms": c.deadline_ms,
                    "velocity": c.velocity,
                    "ewma_pairs": c.ewma_pairs,
                    "last_cam": [float(x) for x in c.last_cam],
                } for cid, c in self._clients.items()},
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore `state_dict()` output onto a scheduler built around the
        RECOVERED service (ids must match the service's live fleet)."""
        self.default_deadline_ms = float(state["default_deadline_ms"])
        self.tick_budget_ms = (None if state["tick_budget_ms"] is None
                               else float(state["tick_budget_ms"]))
        self.cost.load_state_dict(state["cost"])
        for cid_s, cs in state.get("clients", {}).items():
            cid = int(cid_s)
            if cid not in self._clients:
                self._register(cid, cs["deadline_ms"])
            c = self._clients[cid]
            c.deadline_ms = float(cs["deadline_ms"])
            c.velocity = float(cs["velocity"])
            c.ewma_pairs = float(cs["ewma_pairs"])
            c.last_cam = np.asarray(cs["last_cam"], np.float32)


# ---------------------------------------------------------------------------
# workload generators (benchmarks + tests)
# ---------------------------------------------------------------------------


def poisson_arrivals(rng: np.random.Generator, rate: float, n_ticks: int
                     ) -> np.ndarray:
    """(n_ticks,) int — client arrivals per tick, Poisson(rate)."""
    return rng.poisson(float(rate), int(n_ticks)).astype(np.int64)


def bursty_motion_path(rng: np.random.Generator, n_steps: int, *,
                       speed: float = 0.5, burst_prob: float = 0.1,
                       burst_scale: float = 10.0,
                       start=None) -> np.ndarray:
    """(n_steps, 3) head trajectory: a random walk of per-step `speed`,
    with probability `burst_prob` per step of a `burst_scale`× saccade —
    the bursty-head-motion regime where motion-derived priority matters."""
    pos = (np.zeros(3, np.float32) if start is None
           else np.asarray(start, np.float32))
    out = np.empty((int(n_steps), 3), np.float32)
    for t in range(int(n_steps)):
        step = rng.normal(size=3).astype(np.float32)
        norm = float(np.linalg.norm(step)) or 1.0
        scale = speed * (burst_scale if rng.random() < burst_prob else 1.0)
        pos = pos + step * (scale / norm)
        out[t] = pos
    return out


def straggler_path(rng: np.random.Generator, n_steps: int, *,
                   teleport_every: int = 8, extent: float = 30.0,
                   start=None) -> np.ndarray:
    """(n_steps, 3) straggler trajectory: mostly stationary, but every
    ~`teleport_every` steps it TELEPORTS somewhere uniform in ±extent —
    each teleport forces a near-full slab resweep, the expensive client
    that makes lockstep p99 collapse."""
    pos = (rng.uniform(-extent, extent, 3).astype(np.float32)
           if start is None else np.asarray(start, np.float32))
    out = np.empty((int(n_steps), 3), np.float32)
    for t in range(int(n_steps)):
        if rng.random() < 1.0 / max(int(teleport_every), 1):
            pos = rng.uniform(-extent, extent, 3).astype(np.float32)
        out[t] = pos
    return out
