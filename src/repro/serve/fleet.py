"""Fleet lifecycle: runtime client admission/eviction for the LoD service.

The cloud fleet of `repro.serve.lod_service` is no longer fixed at
construction: clients join, idle, and drop mid-session (the "ragged fleets"
open item of the ROADMAP — the dynamic-viewer regime that serving-side
delivery systems like L3GS and Voyager assume). The lifecycle layer keeps
that churn **cheap and provable**:

  * the fleet lives in a SLOT ARRAY of static capacity — every batched
    service leaf keeps a leading (C, ...) axis and `FleetState` records
    which slots are live (`active`), who occupies them (`client_ids`), and
    how many times each slot has been recycled (`generation`);
  * capacity follows the ONE shared bounded-recompilation policy
    (`repro.core.lod_search.pow2_bucket`, the same bucketing used by the
    stale-slab pool, the Δ-union encode width, and the pooled tile
    rasterizer): admits and evicts *within* a capacity bucket are jitted
    slot scatters with the slot index as a traced argument — ZERO
    recompiles — and an admit that outgrows the bucket pads every leaf to
    the next power of two, retracing each jitted path exactly once;
  * an admitted slot starts from the fresh per-client state (`TemporalState`
    fully unswept ⇒ its first sync is a cold full sweep / cold Δcut) and an
    evicted slot is reset immediately, so a recycled slot is bit-for-bit
    indistinguishable from a fresh one;
  * inactive slots are FROZEN: the sync paths mask them out of the
    staleness pool, the Δ-union encode, the wire accounting, and the pooled
    tile rasterizer, and `freeze_inactive` keeps their per-slot state
    bitwise at its reset value — so a surviving client's whole trajectory is
    bitwise identical to a fixed-size service that only ever contained the
    surviving clients (tests/test_fleet_churn.py).

This module owns the generic machinery (the `FleetState` pytree + slot
reset / pad / freeze helpers over batched pytrees); the service-specific
state surgery lives in `repro.serve.lod_service` (`service_admit_slot`,
`service_evict_slot`, `service_grow`).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lod_search as ls

# generous host-side cap for capacity growth — pow2_bucket clamps to it
MAX_CAPACITY = 1 << 20


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FleetState:
    """Slot-array bookkeeping for a capacity-C client fleet.

    active:     (C,) bool — slot currently holds a live client
    generation: (C,) int32 — admits into this slot so far (a recycled slot
                bumps it, so (slot, generation) uniquely names a tenancy)
    client_ids: (C,) int32 — the stable client id in each slot, -1 when free
    next_id:    () int32 — next client id to hand out (monotone; ids are
                never reused even when slots are)
    """

    active: jax.Array
    generation: jax.Array
    client_ids: jax.Array
    next_id: jax.Array

    @property
    def capacity(self) -> int:
        return self.active.shape[0]


def fleet_init(capacity: int, n_active: int = 0) -> FleetState:
    """A fleet of `capacity` slots with the first `n_active` occupied by
    clients 0..n_active-1 (a fully-active fleet is exactly the legacy
    fixed-size service)."""
    if not 0 <= n_active <= capacity:
        raise ValueError(f"n_active={n_active} outside [0, {capacity}]")
    idx = jnp.arange(capacity, dtype=jnp.int32)
    occupied = idx < n_active
    return FleetState(
        active=occupied,
        generation=occupied.astype(jnp.int32),
        client_ids=jnp.where(occupied, idx, -1),
        next_id=jnp.int32(n_active),
    )


def fleet_capacity(n: int) -> int:
    """The pow2 capacity bucket holding n clients — the shared
    `lod_search.pow2_bucket` policy applied to fleet size."""
    return ls.pow2_bucket(n, MAX_CAPACITY)


def fleet_admit_slot(fleet: FleetState, slot, client_id) -> FleetState:
    """Mark `slot` occupied by `client_id` (traced indices — pure, callable
    inside jit; one trace per capacity, never per slot)."""
    slot = jnp.asarray(slot, jnp.int32)
    return FleetState(
        active=fleet.active.at[slot].set(True),
        generation=fleet.generation.at[slot].add(1),
        client_ids=fleet.client_ids.at[slot].set(
            jnp.asarray(client_id, jnp.int32)),
        next_id=jnp.maximum(fleet.next_id,
                            jnp.asarray(client_id, jnp.int32) + 1),
    )


def fleet_evict_slot(fleet: FleetState, slot) -> FleetState:
    """Free `slot` (generation is kept — it counts admits, and marks the
    tenancy that just ended as dead)."""
    slot = jnp.asarray(slot, jnp.int32)
    return FleetState(
        active=fleet.active.at[slot].set(False),
        generation=fleet.generation,
        client_ids=fleet.client_ids.at[slot].set(-1),
        next_id=fleet.next_id,
    )


def fleet_grow(fleet: FleetState, new_capacity: int) -> FleetState:
    """Pad the slot array to `new_capacity` (new slots free). Host-side —
    growth is the one lifecycle event allowed to change compiled shapes."""
    c = fleet.capacity
    if new_capacity < c:
        raise ValueError(f"cannot shrink fleet {c} -> {new_capacity}")
    pad = new_capacity - c
    return FleetState(
        active=jnp.concatenate([fleet.active,
                                jnp.zeros((pad,), bool)]),
        generation=jnp.concatenate([fleet.generation,
                                    jnp.zeros((pad,), jnp.int32)]),
        client_ids=jnp.concatenate([fleet.client_ids,
                                    jnp.full((pad,), -1, jnp.int32)]),
        next_id=fleet.next_id,
    )


def slots_mask(capacity: int, slots) -> np.ndarray:
    """(capacity,) bool participation mask selecting the given slot indices
    — the host-side constructor for the per-tick partial-sync mask of
    `LodService.sync(participate=...)` (the deadline scheduler builds one
    every tick from its selected subset). Out-of-range slots raise."""
    mask = np.zeros((int(capacity),), bool)
    idx = np.asarray(list(slots), np.int64)
    if idx.size and (idx.min() < 0 or idx.max() >= capacity):
        raise ValueError(f"slot indices outside [0, {capacity})")
    mask[idx] = True
    return mask


def fleet_mirror(fleet: FleetState):
    """Host-numpy copy of the fleet bookkeeping: (active (C,) bool,
    client_ids (C,) int64, next_id int) — the control-plane mirror
    `LodService` keeps beside the device state. Snapshot restore rebuilds
    the mirror from the restored device `FleetState` through this and
    cross-checks it against the snapshotted host copy, so a snapshot whose
    two halves disagree is a typed error, never a silently divergent
    control plane (repro.serve.recovery)."""
    return (np.array(jax.device_get(fleet.active), dtype=bool),
            np.array(jax.device_get(fleet.client_ids), dtype=np.int64),
            int(jax.device_get(fleet.next_id)))


# ---------------------------------------------------------------------------
# generic slot surgery over batched pytrees (leaves lead with the slot axis)
# ---------------------------------------------------------------------------


def reset_slot(batched, fresh, slot):
    """Write the unbatched `fresh` pytree into slot `slot` of `batched`
    (leaves of `batched` are `fresh` leaves with a leading capacity axis).
    Pure — compose inside a jitted admit/evict step so the slot index stays
    traced and slot churn never retraces."""
    slot = jnp.asarray(slot, jnp.int32)
    return jax.tree_util.tree_map(lambda b, f: b.at[slot].set(f),
                                  batched, fresh)


def pad_slots(batched, fresh, new_capacity: int):
    """Grow the leading capacity axis of `batched` to `new_capacity`,
    filling the new slots with the unbatched `fresh` values (so a grown
    slot is indistinguishable from a reset one)."""

    def pad(b, f):
        extra = new_capacity - b.shape[0]
        if extra < 0:
            raise ValueError(f"cannot shrink axis {b.shape[0]} -> "
                             f"{new_capacity}")
        fill = jnp.broadcast_to(f[None], (extra,) + f.shape)
        return jnp.concatenate([b, fill], axis=0)

    return jax.tree_util.tree_map(pad, batched, fresh)


def take_slots(batched, perm):
    """Gather slots `perm` (any length) from every leaf's leading capacity
    axis — the shrink-side dual of `pad_slots`. With `perm` = [live slots in
    slot order, enough FREE slots to fill the target capacity], the result
    is a compacted fleet whose free slots are bitwise fresh — because the
    frozen-inactive invariant already keeps every inactive slot at its
    reset value, gathering one IS a reset (no `reset_slot` pass needed)."""
    perm = jnp.asarray(perm, jnp.int32)
    return jax.tree_util.tree_map(lambda b: b[perm], batched)


def fleet_shrink(fleet: FleetState, perm) -> FleetState:
    """Compact the fleet bookkeeping to the slots in `perm` (live first —
    relative slot order of the survivors is preserved, so slot-order
    dependent accounting like the encode-once first-requester split replays
    bitwise). `next_id` is kept: client ids stay monotone across a shrink.
    Host-side — like `fleet_grow`, a shrink is a lifecycle event that
    changes compiled shapes (each jitted path retraces exactly once)."""
    perm = jnp.asarray(perm, jnp.int32)
    return FleetState(
        active=fleet.active[perm],
        generation=fleet.generation[perm],
        client_ids=fleet.client_ids[perm],
        next_id=fleet.next_id,
    )


def freeze_inactive(new, old, active: jax.Array):
    """Select `new` for active slots and `old` for inactive ones, leafwise
    (active broadcasts over every trailing axis). This is what makes an
    inactive slot PROVABLY free: its state stays bitwise at the reset value
    no matter how many fleet syncs run past it."""

    def sel(n, o):
        a = active.reshape(active.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree_util.tree_map(sel, new, old)
