"""Elastic fault-tolerant serving: snapshot/restore, mesh resize on load,
and sync-journal crash recovery for the fleet LoD service.

A killed `LodService` process used to lose every client's temporal/manager
state and force a cold full-tree resync — exactly the bandwidth cliff the
paper's streaming reduction exists to avoid. This module wires the dormant
`repro.checkpoint.manager` (atomic rename, async-safe layout,
reshard-on-load) into the serving stack:

  * `snapshot_service` / `restore_service` — the full service round-trip:
    the `ServiceState` pytree (fleet slots, temporal/manager state, paging
    debt, sync counters), the host control-plane mirrors (slot occupancy,
    client ids, cameras, foveation taus, Δ-payload tenancy), the
    closed-loop bitrate-controller state (targets, allowances, tau scales,
    and the PREVIOUS sync's measured wire bytes — the one-sync-delayed
    feedback the controller replays from), and the static session config in
    the manifest extras. Survivors of save→kill→restore replay **bitwise**
    against an uninterrupted service (tests/test_fleet_recovery.py, with
    the churn-conformance harness as the oracle) across the vmapped,
    pooled-XLA, and pooled-Pallas sweep implementations.
  * restore onto a DIFFERENT `clients`×`slabs` mesh — bigger, smaller, or
    none: `restore_service(..., mesh=...)` builds the target's
    `sharding.fleet.fleet_shardings` and the checkpoint layer device_puts
    every leaf under it (reshard-on-load). This generalizes `maybe_shrink`
    from capacity to devices without dropping a client.
  * `SyncJournal` + `replay` + `RecoveryManager` — an append-only,
    CRC-framed journal of per-sync INPUTS (camera updates, admits/evicts,
    bandwidth re-tiers, NACK retransmit debt) with a snapshot-every-K
    policy: a crash between checkpoints recovers by restoring the newest
    intact snapshot and deterministically re-executing the journal tail.
    `recover` walks snapshots newest-first, so a torn/corrupt newest step
    falls back to the previous one instead of diverging.

Failure semantics (the fault-injection contract): every injected fault — a
save killed mid-write (`step_*.tmp` leftovers), a truncated leaf file, a
corrupt manifest, a torn or corrupted journal — ends in either a clean
restore from an earlier consistent point or a typed `RecoveryError`. Silent
divergence is never an outcome: restored snapshots cross-check the device
`FleetState` against the snapshotted host mirrors and the shared tree
against its saved fingerprint, and journal replay verifies record
contiguity and the determinism of re-executed admissions.

Journal-file semantics worth knowing: a record is one JSON line carrying
its own `seq` and a CRC32 over the canonical encoding of the rest. A bad
line with nothing but bad/empty lines after it is a TORN TAIL (the append
the crash interrupted) — truncated away, recovery proceeds from the valid
prefix. A bad line FOLLOWED by valid records is mid-file corruption — a
`RecoveryError`, because replaying around a hole would silently diverge.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.core.lod_tree import LodTree
from repro.core.pipeline import SessionConfig
from repro.serve import fleet as flt
from repro.serve.lod_service import (AdmissionDenied, LodService,
                                     ServiceStats)
from repro.sharding import fleet as shd

SNAPSHOT_FORMAT = "nebula-fleet-snapshot/1"
JOURNAL_NAME = "journal.jsonl"
SNAPSHOT_DIRNAME = "snapshots"


class RecoveryError(RuntimeError):
    """A snapshot or journal cannot be used for a faithful restore: torn or
    truncated files, corrupt manifests, fingerprint/config mismatches,
    journal holes, or non-deterministic replay. The typed alternative to
    silently serving diverged state."""


# ---------------------------------------------------------------------------
# snapshot / restore
# ---------------------------------------------------------------------------


def tree_fingerprint(tree: LodTree) -> Dict[str, Any]:
    """Cheap identity of the shared city tree a snapshot was taken against:
    structural sizes plus a float64 sum over the Gaussian means. Restoring
    fleet state against a DIFFERENT tree would be silently catastrophic
    (every gid reindexed) — the fingerprint turns it into a typed error."""
    m = tree.meta
    mu = np.asarray(jax.device_get(tree.gaussians.mu))
    return {
        "n_pad": int(tree.n_pad), "T": int(m.T), "Ns": int(m.Ns),
        "S": int(m.S), "n_real": int(m.n_real),
        "mu_sum": float(mu.sum(dtype=np.float64)),
    }


def _host_mirrors(service: LodService) -> Dict[str, np.ndarray]:
    """The service's host control-plane state as a flat dict of arrays (the
    `host` half of the snapshot tree). `taus` is stored dense (cfg.tau fill
    when unset — the `has_taus` extras flag restores the None); the
    previous sync's measured wire bytes ride along so the bitrate
    controller's one-sync-delayed feedback loop replays bitwise."""
    cap = service.capacity
    taus = (np.asarray(service.taus, np.float32)
            if service.taus is not None
            else np.full((cap,), service.cfg.tau, np.float32))
    if service._last_stats is not None:
        last_bytes = np.asarray(
            jax.device_get(service._last_stats.sync_bytes), np.float32)
    else:
        last_bytes = np.zeros((cap,), np.float32)
    return {
        "active": np.asarray(service._active, bool),
        "allowance": np.asarray(service._allowance, np.int64),
        "bw_target": np.asarray(service._bw_target, np.float64),
        "client_ids": np.asarray(service._client_ids, np.int64),
        "delta_ids": np.asarray(service._delta_ids, np.int64),
        "last_sync_bytes": last_bytes,
        "slot_cams": np.asarray(service._slot_cams, np.float32),
        "stats_fresh": np.asarray(service._stats_fresh, bool),
        "tau_scale": np.asarray(service._tau_scale, np.float32),
        "taus": taus,
    }


def _host_like(capacity: int) -> Dict[str, np.ndarray]:
    """Shape/dtype skeleton of `_host_mirrors` for `ckpt.restore`."""
    return {
        "active": np.zeros((capacity,), bool),
        "allowance": np.zeros((capacity,), np.int64),
        "bw_target": np.zeros((capacity,), np.float64),
        "client_ids": np.zeros((capacity,), np.int64),
        "delta_ids": np.zeros((capacity,), np.int64),
        "last_sync_bytes": np.zeros((capacity,), np.float32),
        "slot_cams": np.zeros((capacity, 3), np.float32),
        "stats_fresh": np.zeros((capacity,), bool),
        "tau_scale": np.zeros((capacity,), np.float32),
        "taus": np.zeros((capacity,), np.float32),
    }


def snapshot_service(service: LodService, directory: str, step: int = 0, *,
                     journal_seq: int = 0,
                     scheduler_state: Optional[Dict[str, Any]] = None) -> str:
    """Atomically serialize `service` as checkpoint `step_<step>` under
    `directory` (`checkpoint.manager.save`: tmp dir + fsync + rename — a
    kill mid-write leaves a `.tmp` leftover, never a half checkpoint).

    The saved tree is {"state": ServiceState, "host": mirrors}; everything
    static — session config, scheduler mode, budgets, capacity, the shared
    tree's fingerprint, the mesh signature it was saved under, and
    `journal_seq` (how many journal records precede this snapshot) — rides
    in the manifest extras. The Δ payload itself is NOT serialized (it is a
    per-sync artifact with per-sync shapes); its tenancy vector is, so a
    restored service refuses stale decode requests instead of inventing
    rows.

    `scheduler_state` (a JSON-able dict — `DeadlineScheduler.state_dict()`)
    rides in the extras too, so a recovered service can rebuild its
    deadline scheduler with the fitted cost model and per-client deadlines
    it crashed with (repro.serve.scheduler)."""
    extras = {
        "format": SNAPSHOT_FORMAT,
        "capacity": int(service.capacity),
        "next_id": int(service._next_id),
        "has_taus": service.taus is not None,
        "has_last_stats": service._last_stats is not None,
        "journal_seq": int(journal_seq),
        "cfg": dataclasses.asdict(service.cfg),
        "service": {
            "focal": float(service.focal),
            "mode": service.mode,
            "sweep_impl": service.sweep_impl,
            "interpret": bool(service.interpret),
            "dedup": bool(service.dedup),
            "page_size": int(service.page_size),
            "delta_budget_arg": (None if service._delta_budget_arg is None
                                 else int(service._delta_budget_arg)),
            "max_clients": service.max_clients,
            "max_state_bytes": service.max_state_bytes,
        },
        "tree": tree_fingerprint(service.tree),
        "mesh": shd.mesh_signature(service.mesh),
    }
    if scheduler_state is not None:
        extras["scheduler"] = scheduler_state
    tree = {"state": service.state, "host": _host_mirrors(service)}
    return ckpt.save(directory, int(step), tree, extras)


def _zero_stats(capacity: int, sync_bytes: np.ndarray) -> ServiceStats:
    """A `ServiceStats` carrying only the restored per-slot wire bytes —
    the single column the rate controller's feedback loop reads."""
    zi = jnp.zeros((capacity,), jnp.int32)
    zf = jnp.zeros((capacity,), jnp.float32)
    zb = jnp.zeros((capacity,), bool)
    return ServiceStats(
        cut_size=zi, delta_size=zi, unique_delta=zi,
        sync_bytes=jnp.asarray(sync_bytes, jnp.float32),
        dedup_bytes_saved=zf, nodes_touched=zi, resweeps=zi,
        client_resident=zi, overflow=zb, delta_overflow=zb,
        delta_shipped=zi, delta_deferred=zi, pages=zi,
        mtp_ms=zf, deadline_miss=zb)


def _read_extras(directory: str, step: int) -> Dict[str, Any]:
    try:
        extras = ckpt.read_extras(directory, step)
    except (OSError, ValueError, KeyError) as e:
        raise RecoveryError(
            f"snapshot step {step} manifest unreadable: {e}") from e
    if extras.get("format") != SNAPSHOT_FORMAT:
        raise RecoveryError(
            f"snapshot step {step} has format {extras.get('format')!r}, "
            f"expected {SNAPSHOT_FORMAT!r}")
    return extras


def restore_service(tree: LodTree, directory: str,
                    step: Optional[int] = None, mesh=None) -> LodService:
    """Rebuild a `LodService` from a snapshot, onto any target mesh.

    `tree` must be the SAME shared city tree the snapshot was taken against
    (fingerprint-checked). `mesh` is the TARGET layout — it need not match
    the saved one: every leaf is loaded full and device_put under the new
    mesh's `fleet_shardings` (reshard-on-load), so a fleet saved on a
    2×4 mesh restores onto 4×2, 1×1, or no mesh at all, clients intact.
    `step=None` restores the newest complete snapshot.

    Raises `RecoveryError` for anything that cannot restore faithfully:
    missing/torn snapshots, truncated leaf files, corrupt manifests, a
    mismatched tree, or snapshot halves that disagree."""
    svc, _ = _restore_with_extras(tree, directory, step, mesh)
    return svc


def _restore_with_extras(tree: LodTree, directory: str,
                         step: Optional[int], mesh
                         ) -> Tuple[LodService, Dict[str, Any]]:
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise RecoveryError(f"no complete snapshot in {directory}")
    extras = _read_extras(directory, int(step))
    saved_fp = extras.get("tree", {})
    fp = tree_fingerprint(tree)
    if saved_fp != fp:
        raise RecoveryError(
            f"snapshot step {step} was taken against a different tree: "
            f"saved {saved_fp}, have {fp}")
    try:
        cfg = SessionConfig(**extras["cfg"])
        srv = extras["service"]
        capacity = int(extras["capacity"])
        svc = LodService(
            tree, cfg, n_clients=0, focal=srv["focal"], mode=srv["mode"],
            dedup=srv["dedup"], sweep_impl=srv["sweep_impl"],
            interpret=srv["interpret"],
            delta_budget=srv["delta_budget_arg"], capacity=capacity,
            mesh=mesh, max_clients=srv["max_clients"],
            max_state_bytes=srv["max_state_bytes"],
            page_size=srv["page_size"])
    except (KeyError, TypeError, ValueError) as e:
        raise RecoveryError(
            f"snapshot step {step} has an unusable config: {e}") from e
    # NOTE: LodService(mesh=None) falls back to the ambient use_fleet_mesh
    # mesh; a restore is explicit about its target, so pin exactly `mesh`
    # (resize_mesh also re-places the slab tables under it)
    if svc.mesh is not mesh:
        svc.resize_mesh(mesh)
    like = {"state": svc.state, "host": _host_like(capacity)}
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        shardings = {
            "state": shd.fleet_shardings(mesh, svc.state),
            "host": jax.tree_util.tree_map(
                lambda a: NamedSharding(mesh, PartitionSpec()),
                _host_like(capacity)),
        }
    else:
        shardings = None
    try:
        restored = ckpt.restore(directory, int(step), like, shardings)
    except (OSError, ValueError, KeyError, EOFError) as e:
        raise RecoveryError(
            f"snapshot step {step} unrestorable: {e}") from e
    svc.state = restored["state"]
    host = jax.tree_util.tree_map(
        lambda a: np.array(jax.device_get(a)), restored["host"])
    # cross-check: the device FleetState and the host mirror were saved
    # from one consistent service — restored, they must still agree
    dev_active, dev_ids, _ = flt.fleet_mirror(svc.state.fleet)
    if (not np.array_equal(dev_active, host["active"])
            or not np.array_equal(dev_ids.astype(np.int64),
                                  host["client_ids"].astype(np.int64))):
        raise RecoveryError(
            f"snapshot step {step}: device FleetState disagrees with the "
            f"snapshotted host mirror (active/client_ids)")
    svc._active = host["active"].copy()
    svc._client_ids = host["client_ids"].copy()
    svc._slot_cams = host["slot_cams"].copy()
    svc._delta_ids = host["delta_ids"].copy()
    svc._bw_target = host["bw_target"].copy()
    svc._allowance = host["allowance"].copy()
    svc._tau_scale = host["tau_scale"].copy()
    svc._stats_fresh = host["stats_fresh"].copy()
    svc._next_id = int(extras["next_id"])
    svc.taus = host["taus"].copy() if extras["has_taus"] else None
    svc._last_stats = (_zero_stats(capacity, host["last_sync_bytes"])
                       if extras["has_last_stats"] else None)
    svc.last_delta = None  # per-sync artifact; tenancy refuses stale reads
    return svc, extras


# ---------------------------------------------------------------------------
# sync journal
# ---------------------------------------------------------------------------


def _record_crc(rec: Dict[str, Any]) -> int:
    body = {k: v for k, v in rec.items() if k != "crc"}
    canon = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return zlib.crc32(canon.encode("utf-8")) & 0xFFFFFFFF


class SyncJournal:
    """Append-only CRC-framed JSONL journal of service inputs.

    One record per line: `{"seq": i, "kind": ..., ..., "crc": c}` with
    `seq` dense from 0 and `crc` a CRC32 over the canonical encoding of the
    other fields. Appends flush + fsync before returning, so a record the
    caller saw appended survives the process."""

    def __init__(self, path: str, seq: int = 0):
        self.path = path
        self.seq = int(seq)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def append(self, rec: Dict[str, Any]) -> int:
        rec = dict(rec, seq=self.seq)
        rec["crc"] = _record_crc(rec)
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self.seq += 1
        return self.seq - 1

    @staticmethod
    def read(path: str, repair: bool = True) -> List[Dict[str, Any]]:
        """Validate and load every record. A bad line at the strict TAIL
        (the append a crash interrupted — possibly followed by more
        garbage, but never by a valid record) is truncated away when
        `repair`; a bad line FOLLOWED by a valid record, or a seq hole, is
        mid-file corruption → `RecoveryError`."""
        if not os.path.exists(path):
            return []
        with open(path, "rb") as f:
            raw = f.read()
        records: List[Dict[str, Any]] = []
        good_bytes = 0
        offset = 0
        bad_at: Optional[int] = None
        lines = raw.split(b"\n")
        for i, line in enumerate(lines):
            # the final split chunk has no trailing newline: an empty one is
            # the normal file end; a non-empty one is a torn partial append
            end = offset + len(line) + (1 if i < len(lines) - 1 else 0)
            if line.strip():
                rec = None
                try:
                    parsed = json.loads(line.decode("utf-8"))
                    if (isinstance(parsed, dict)
                            and parsed.get("crc") == _record_crc(parsed)):
                        rec = parsed
                except (ValueError, UnicodeDecodeError):
                    rec = None
                if rec is None:
                    if bad_at is None:
                        bad_at = len(records)
                elif bad_at is not None:
                    raise RecoveryError(
                        f"journal {path} corrupt at record {bad_at} with "
                        f"valid records after it — a hole, not a torn tail")
                elif rec.get("seq") != len(records):
                    raise RecoveryError(
                        f"journal {path} record {len(records)} carries "
                        f"seq {rec.get('seq')} — records are missing")
                else:
                    records.append(rec)
                    good_bytes = end
            offset = end
        if bad_at is not None and repair and good_bytes < len(raw):
            with open(path, "r+b") as f:
                f.truncate(good_bytes)
        return records


def _jsonable_cam(cam) -> Optional[List[float]]:
    if cam is None:
        return None
    # float32 → float64 → float32 is exact, so the journal round-trips the
    # service's camera dtype bitwise
    return [float(x) for x in np.asarray(cam, np.float32)]


def replay(service: LodService, records) -> int:
    """Re-execute journal `records` (in order) against `service`. Returns
    the number applied. The journal holds INPUTS only — every output
    (assigned client ids, shrink results) is recomputed and, where the
    journal recorded it, verified: a mismatch means the replay is not the
    trajectory the journal describes → `RecoveryError`."""
    n = 0
    for rec in records:
        kind = rec.get("kind")
        if kind == "sync":
            cams = rec.get("cams")
            part = rec.get("participate")
            service.sync(
                None if cams is None else {
                    int(c): np.asarray(v, np.float32)
                    for c, v in cams.items()},
                participate=None if part is None
                else [int(c) for c in part])
        elif kind == "admit":
            cid = service.admit(cam=rec.get("cam"), tau=rec.get("tau"),
                                bandwidth=rec.get("bandwidth"))
            if cid != rec["id"]:
                raise RecoveryError(
                    f"replay diverged: journal admit assigned id "
                    f"{rec['id']}, replay assigned {cid}")
        elif kind == "evict":
            service.evict(rec["id"])
        elif kind == "nack":
            service.nack_rows(rec["id"], rec.get("gids", []))
        elif kind == "bandwidth":
            service.set_bandwidth(rec["id"], rec.get("target"))
        elif kind == "shrink":
            service.maybe_shrink()
        else:
            raise RecoveryError(f"unknown journal record kind {kind!r} "
                                f"(seq {rec.get('seq')})")
        n += 1
    return n


# ---------------------------------------------------------------------------
# snapshot-every-K orchestration
# ---------------------------------------------------------------------------


class RecoveryManager:
    """Crash-recoverable wrapper around a live `LodService`: every mutating
    call is write-ahead journaled, and every `every` syncs the full service
    is snapshotted (keep-last-`keep` GC bounds disk; the journal bounds
    replay work to at most `every` syncs). Drive the service THROUGH this
    wrapper — a mutation that bypasses it is invisible to recovery.

    Layout under `directory`:
        snapshots/step_<seq>/   — snapshot taken after journal record seq-1
        journal.jsonl           — the full input history (seq 0 onward)

    `recover(tree, directory)` rebuilds the newest restorable snapshot and
    replays the journal tail — the service comes back bitwise at the exact
    sync the journal last recorded."""

    def __init__(self, service: LodService, directory: str, every: int = 8,
                 keep: int = 3, *, scheduler=None,
                 _resume_seq: Optional[int] = None):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.service = service
        # optional DeadlineScheduler whose state_dict() rides in every
        # snapshot's extras (restored via `recover(...).scheduler_state`)
        self.scheduler = scheduler
        self.directory = directory
        self.snapshot_dir = os.path.join(directory, SNAPSHOT_DIRNAME)
        self.every = int(every)
        self.keep = int(keep)
        os.makedirs(self.snapshot_dir, exist_ok=True)
        self.journal = SyncJournal(os.path.join(directory, JOURNAL_NAME),
                                   seq=0 if _resume_seq is None
                                   else _resume_seq)
        self._since_snapshot = 0
        if _resume_seq is None:
            # base snapshot: recovery always has a restore point even if
            # the process dies before the first snapshot interval elapses
            self._snapshot()

    # -- persistence ----------------------------------------------------------

    def _snapshot(self) -> None:
        snapshot_service(self.service, self.snapshot_dir,
                         step=self.journal.seq,
                         journal_seq=self.journal.seq,
                         scheduler_state=None if self.scheduler is None
                         else self.scheduler.state_dict())
        self._since_snapshot = 0
        self._gc()

    def _gc(self) -> None:
        for s in ckpt.valid_steps(self.snapshot_dir)[self.keep:]:
            shutil.rmtree(
                os.path.join(self.snapshot_dir, f"step_{s:08d}"),
                ignore_errors=True)

    def snapshot_now(self) -> None:
        """Force a snapshot at the current journal position (e.g. before a
        planned shutdown, so recovery replays nothing)."""
        self._snapshot()

    # -- journaled service API -------------------------------------------------

    def sync(self, cam_positions=None, participate=None) -> ServiceStats:
        if isinstance(cam_positions, dict):
            cams = {str(int(c)): _jsonable_cam(v)
                    for c, v in cam_positions.items()}
        elif cam_positions is not None:
            arr = np.asarray(cam_positions, np.float32)
            cams = {str(int(c)): _jsonable_cam(row)
                    for c, row in zip(self.service.active_ids, arr)}
        else:
            cams = None
        if participate is not None:
            # journal STABLE CLIENT IDS, not slot indices: replay may land
            # on a restored service whose slot layout shifted (shrink), but
            # ids name the same clients
            mask = self.service._participation_mask(participate)
            ids = sorted(int(c) for c in np.asarray(
                self.service._client_ids)[mask & self.service._active])
            part = ids
        else:
            part = None
        self.journal.append({"kind": "sync", "cams": cams,
                             "participate": part})
        stats = self.service.sync(
            None if cams is None else
            {int(c): np.asarray(v, np.float32) for c, v in cams.items()},
            participate=part)
        self._since_snapshot += 1
        if self._since_snapshot >= self.every:
            self._snapshot()
        return stats

    def admit(self, cam=None, tau=None, required: bool = True,
              bandwidth=None) -> Optional[int]:
        # pre-check admission so a DENIED admit never enters the journal
        # (replay would re-raise mid-recovery otherwise)
        denial = self.service._admission_denial()
        if denial is not None:
            if required:
                raise AdmissionDenied(denial)
            return None
        cid = int(self.service._next_id)
        self.journal.append({
            "kind": "admit", "id": cid, "cam": _jsonable_cam(cam),
            "tau": None if tau is None else float(tau),
            "bandwidth": (bandwidth if bandwidth is None
                          or isinstance(bandwidth, str)
                          else float(bandwidth))})
        got = self.service.admit(cam=cam, tau=tau, bandwidth=bandwidth)
        if got != cid:
            raise RecoveryError(
                f"admit assigned id {got}, journal predicted {cid}")
        return got

    def evict(self, client_id: int) -> None:
        self.service._slot_of(client_id)  # validate BEFORE journaling
        self.journal.append({"kind": "evict", "id": int(client_id)})
        self.service.evict(client_id)

    def nack(self, client_id: int, lost_pages) -> int:
        # journal the RESOLVED gids, not the page numbers: replay must not
        # depend on a payload that died with the crashed process
        gids = self.service.resolve_nack(client_id, lost_pages)
        self.journal.append({"kind": "nack", "id": int(client_id),
                             "gids": [int(g) for g in gids]})
        return self.service.nack_rows(client_id, gids)

    def set_bandwidth(self, client_id: int, bandwidth=None) -> None:
        self.service._slot_of(client_id)  # validate BEFORE journaling
        self.journal.append({
            "kind": "bandwidth", "id": int(client_id),
            "target": (bandwidth if bandwidth is None
                       or isinstance(bandwidth, str) else float(bandwidth))})
        self.service.set_bandwidth(client_id, bandwidth)

    def maybe_shrink(self) -> Optional[int]:
        self.journal.append({"kind": "shrink"})
        return self.service.maybe_shrink()


def recover(tree: LodTree, directory: str, mesh=None, every: int = 8,
            keep: int = 3) -> Tuple[RecoveryManager, int]:
    """Crash recovery: restore the newest intact snapshot under
    `directory` and deterministically re-execute the journal tail.

    Walks complete snapshots NEWEST-FIRST — a snapshot that turns out torn,
    truncated, or corrupt falls back to the one before it (its journal tail
    is longer, so nothing is lost but replay time). Leftover `step_*.tmp`
    dirs from killed saves are swept away. A torn journal tail (the append
    the crash interrupted) is truncated; a journal hole raises.

    `mesh` is the TARGET serving mesh (restore-onto-new-mesh works across
    a crash too). Returns `(manager, replayed)` — a `RecoveryManager`
    resumed at the journal head, and how many records were re-executed.
    Raises `RecoveryError` when no snapshot can be restored."""
    snap_dir = os.path.join(directory, SNAPSHOT_DIRNAME)
    if os.path.isdir(snap_dir):
        for name in os.listdir(snap_dir):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(snap_dir, name),
                              ignore_errors=True)
    records = SyncJournal.read(os.path.join(directory, JOURNAL_NAME),
                               repair=True)
    failures: List[str] = []
    for step in ckpt.valid_steps(snap_dir):
        try:
            svc, extras = _restore_with_extras(tree, snap_dir, step, mesh)
        except RecoveryError as e:
            failures.append(str(e))
            continue
        base = int(extras.get("journal_seq", 0))
        if base > len(records):
            failures.append(
                f"snapshot step {step} is ahead of the journal "
                f"({base} > {len(records)} records)")
            continue
        replayed = replay(svc, records[base:])
        manager = RecoveryManager(svc, directory, every=every, keep=keep,
                                  _resume_seq=len(records))
        # the snapshotted scheduler state (if any) — the caller rebuilds a
        # DeadlineScheduler around the recovered service and
        # load_state_dict()s this (the journal replays partial ticks, but
        # the fitted cost model / deadlines live scheduler-side)
        manager.scheduler_state = extras.get("scheduler")
        return manager, replayed
    detail = "; ".join(failures) if failures else "no complete snapshot"
    raise RecoveryError(f"cannot recover from {directory}: {detail}")
