"""Batched multi-client LoD service — the cloud half of paper Fig. 9/10 at
serving scale.

In the paper's collaborative split, the cloud runs the temporal-aware LoD
search and the Gaussian-management table per headset, and ships compressed
Δcuts downstream; the client only renders (Fig. 10 keeps the
motion-to-photon path entirely client-side). This module scales the cloud
half from one headset to B concurrent headsets against ONE shared city tree:

  * one `LodTree` + one scene codec are shared by every client (the codec is
    scene-level, so the client-side "codebook buffer" of §5 is identical for
    all users);
  * per-client state — `TemporalState` (LoD-search reuse), `ManagerState`
    (management table), sync counters — is stacked on a leading batch axis
    (`ServiceState`), exactly the functional-core layout of
    repro.core.pipeline scaled to B;
  * `service_sync_vmapped` runs the per-frame temporal LoD search vmapped
    across clients: one fused device program, bit-identical per client to the
    sequential single-client search;
  * `service_sync_pooled` is the production scheduler: the cheap exact
    top-tree sweep + staleness predicate runs vmapped for all clients, then
    the *stale (client, slab) pairs of every client are pooled into one
    power-of-two bucket* and swept by a single dispatch (each pair carries
    its own camera and τ). Pooling, compaction, and the pair gather all run
    ON DEVICE — the only host transfers on the steady-state path are two
    scalars, the stale-pool size and the Δ-union size, each picking a
    static pow2 bucket (bounded recompilation); the staleness and Δ masks
    themselves never leave the device. Wall-clock cost scales with TOTAL
    staleness in the fleet, not with client count;
  * the sync tail is **encode-once** (`repro.serve.delta_path`): the
    fleet-union Δcut is quantized/packed by ONE batched codec call and
    fanned out as (union-offset, mask) references, so downlink bytes and
    cloud encode FLOPs grow with the fleet's *unique* Gaussians, not with B
    — co-located viewers are nearly free.

Scheduling is double-buffered by construction: every sync is dispatched
asynchronously and only the bucket-size scalars are awaited, so while the
host schedules the pooled slab sweep of sync t the device is still executing
the management-table update + encode of sync t−1 (see
`service_sync_pooled`).

The fleet is RAGGED at runtime (repro.serve.fleet): clients are admitted
and evicted mid-session via `LodService.admit` / `LodService.evict`. State
lives in a slot array whose capacity grows on the shared
`lod_search.pow2_bucket` policy — admits/evicts *within* a capacity bucket
are jitted slot scatters (zero recompiles; the slot index is a traced
argument) and a bucket growth pads every leaf and retraces each jitted
path exactly once. Inactive slots are provably free: they contribute no
staleness to the pooled bucket, no rows to the Δ-union encode, no bytes to
the wire accounting (not even a header), and no tiles to the pooled fleet
rasterizer — and their per-slot state stays bitwise frozen at the reset
value, so a surviving client's trajectory is bitwise identical to a
fixed-size service of just the survivors (tests/test_fleet_churn.py).

The service runs MESH-SHARDED when given a `clients`×`slabs` serving mesh
(`LodService(mesh=...)` or the ambient
`repro.sharding.fleet.use_fleet_mesh`): per-slot state shards on its
leading slot axis over `clients` (each host owns a contiguous block of
slots — its staleness pool, tables, and wire accounting live with its
clients), the shared slab attribute tables and the union codec rows shard
over `slabs`, the pooled staleness compaction becomes per-client-shard
pow2 buckets (one per-shard count vector awaited instead of one scalar),
and the Δ-union payload replicates across client shards (the multicast
stream is broadcast to everyone anyway). With no mesh — or any indivisible
layout — every constraint falls back to replicate and the service is
bitwise the single-device one (tests/test_sharding_fleet.py).

Per-sync, per-client byte and work accounting (`ServiceStats`, now including
`unique_delta` / `dedup_bytes_saved`) feeds benchmarks/bench_multiclient.py,
benchmarks/bench_fleet_sync.py, benchmarks/bench_fleet_churn.py and
benchmarks/bench_fleet_shard.py (the multi-user analogs of the paper's
bandwidth figures); `repro.sharding.fleet.fleet_totals` psums the per-slot
columns to fleet scalars across client shards.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.gaussians import Gaussians
from repro.core.lod_tree import LodTree
from repro.core.pipeline import SessionConfig, session_wire_format
from repro.kernels import lod_cut as lc
from repro.serve import delta_path as dp
from repro.serve import fleet as flt
from repro.sharding import fleet as shd
from repro import render as rnd


class AdmissionDenied(RuntimeError):
    """`LodService.admit` refused: the configured fleet budget (client count
    or state-byte budget) is exhausted — backpressure instead of unbounded
    capacity growth."""


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceState:
    """All per-client cloud state, batched on a leading (C, ...) SLOT axis.

    The leading axis is the fleet's slot CAPACITY, not its live client
    count: `fleet` (repro.serve.fleet.FleetState) records which slots hold a
    client. A fully-active fleet is exactly the legacy fixed-size service."""

    mgr: mgr.ManagerState       # leaves (C, N)
    temporal: ls.TemporalState  # leaves (C, Ns, ...)
    cut_gids: jax.Array         # (C, cut_budget) int32, -1 padded
    sync_index: jax.Array       # (C,) int32 — per-slot syncs WHILE ACTIVE
    pending: jax.Array          # (C, N) bool — Δ rows owed to the slot from
    #                             earlier paged syncs (deferred by the
    #                             stream budget / row allowance); folded
    #                             into the next sync's union as forced-stale
    #                             membership until they ship. All-False for
    #                             inactive slots (an evicted slot drops its
    #                             debt; an admitted slot starts clean).
    fleet: flt.FleetState       # slot occupancy / client ids / generations

    @property
    def capacity(self) -> int:
        return self.sync_index.shape[0]

    @property
    def n_clients(self) -> int:
        """Slot capacity (kept for API compatibility — the legacy fixed
        service had n_clients == capacity; live count is `fleet.active`)."""
        return self.sync_index.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Per-client accounting for one service sync (all leaves (C,), the
    slot capacity; inactive slots report all-zero rows — not even a sync
    header is charged to an empty slot)."""

    cut_size: jax.Array        # int32 — render-queue size
    delta_size: jax.Array      # int32 — Δcut Gaussians shipped to the client
    unique_delta: jax.Array    # int32 — Δ rows this client contributed to the
    #                            fleet union (first requester); sums to the
    #                            union size across clients
    sync_bytes: jax.Array      # float32 — downlink bytes (payload + ids)
    dedup_bytes_saved: jax.Array  # float32 — unicast-path bytes minus
    #                            encode-once bytes (0 when dedup is off;
    #                            slightly NEGATIVE for a sole requester —
    #                            the shared stream carries explicit union
    #                            ids the unicast format left implicit)
    nodes_touched: jax.Array   # int32 — LoD-search work attributed to client
    resweeps: jax.Array        # int32 — stale subtrees swept
    client_resident: jax.Array  # int32 — client store occupancy after sync
    overflow: jax.Array        # bool — cut exceeded cut_budget (queue truncated)
    delta_overflow: jax.Array  # bool — PER CLIENT: ≥1 of this client's Δ
    #                            rows was deferred to a later page this sync
    #                            (stream budget or row allowance; the rows
    #                            are carried over, never lost — always False
    #                            with dedup off or the default budget)
    delta_shipped: jax.Array   # int32 — union rows the client actually
    #                            ingested this sync (== delta_size unless
    #                            rows were deferred, by this sync or earlier)
    delta_deferred: jax.Array  # int32 — rows owed to the client AFTER this
    #                            sync (its carry-over into the next union;
    #                            0 once the paged stream has converged)
    pages: jax.Array           # int32 — priority pages the client pulled
    #                            rows from this sync (page-header framing)
    mtp_ms: jax.Array          # float32 — motion-to-photon latency this sync
    #                            closed for the client: ms from its oldest
    #                            unserved motion sample to this sync's
    #                            completion. Wall-clock is a HOST concept, so
    #                            the sync paths emit 0.0 and the deadline
    #                            scheduler (repro.serve.scheduler) stamps the
    #                            column on the stats it returns; 0.0 for
    #                            slots with no motion served this sync.
    deadline_miss: jax.Array   # bool — the served motion overran the
    #                            client's frame deadline (stamped by the
    #                            scheduler alongside mtp_ms; always False on
    #                            the raw lockstep sync paths)


def service_init(tree: LodTree, cfg: SessionConfig, n_clients: int,
                 capacity: Optional[int] = None) -> ServiceState:
    """Service state for `n_clients` live clients in a `capacity`-slot
    array (default: capacity == n_clients, the legacy fixed-size layout —
    pre-provision a pow2 capacity to admit clients without an early
    growth recompile)."""
    m = tree.meta
    cap = max(n_clients, 1) if capacity is None else int(capacity)
    if cap < max(n_clients, 1):
        raise ValueError(f"capacity {cap} < n_clients {n_clients}")
    return ServiceState(
        mgr=jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cap,) + a.shape),
            mgr.ManagerState.initial(tree.n_pad)),
        temporal=ls.TemporalState.initial_batched(m.Ns, m.S, cap),
        cut_gids=jnp.full((cap, cfg.cut_budget), -1, jnp.int32),
        sync_index=jnp.zeros((cap,), jnp.int32),
        pending=jnp.zeros((cap, tree.n_pad), bool),
        fleet=flt.fleet_init(cap, n_clients),
    )


# ---------------------------------------------------------------------------
# fleet lifecycle: slot admission / eviction / capacity growth
# ---------------------------------------------------------------------------


def _fresh_slot_leaves(state: ServiceState):
    """(fresh ManagerState, fresh TemporalState, fresh cut row, fresh sync
    counter, fresh pending row) for one slot — shapes from the traced
    state, so usable in jit."""
    n = state.mgr.client_has.shape[1]
    ns, s = state.temporal.slab_cut0.shape[1:]
    return (mgr.ManagerState.initial(n), ls.TemporalState.initial(ns, s),
            jnp.full((state.cut_gids.shape[1],), -1, jnp.int32), jnp.int32(0),
            jnp.zeros((n,), bool))


def _reset_slot(state: ServiceState, slot) -> ServiceState:
    f_mgr, f_tmp, f_cut, f_idx, f_pend = _fresh_slot_leaves(state)
    return ServiceState(
        mgr=flt.reset_slot(state.mgr, f_mgr, slot),
        temporal=flt.reset_slot(state.temporal, f_tmp, slot),
        cut_gids=state.cut_gids.at[jnp.asarray(slot, jnp.int32)].set(f_cut),
        sync_index=state.sync_index.at[jnp.asarray(slot, jnp.int32)].set(f_idx),
        pending=state.pending.at[jnp.asarray(slot, jnp.int32)].set(f_pend),
        fleet=state.fleet,
    )


@jax.jit
def service_admit_slot(state: ServiceState, slot, client_id) -> ServiceState:
    """Admit `client_id` into `slot`: reset every per-slot leaf to its fresh
    value (temporal fully unswept ⇒ the first sync is a cold sweep + cold
    Δcut) and mark the slot live. `slot`/`client_id` are TRACED — one trace
    per capacity bucket, zero recompiles per admit."""
    state = _reset_slot(state, slot)
    return dataclasses.replace(
        state, fleet=flt.fleet_admit_slot(state.fleet, slot, client_id))


@jax.jit
def service_nack_rows(state: ServiceState, slot, lost_rows) -> ServiceState:
    """Re-queue one slot's lost Δ rows as pending debt (the page-loss NACK
    path): the rows fold into the next sync's union exactly like
    budget-deferred pages, so the retransmit rides the normal priority
    stream — no special wire format, and convergence-to-oracle holds under
    loss for the same reason it holds under paging. `slot`/`lost_rows` are
    TRACED (one trace per capacity bucket). Inactive slots are a no-op (a
    NACK racing an eviction must not resurrect the slot's debt)."""
    slot = jnp.asarray(slot, jnp.int32)
    row = state.pending[slot] | (lost_rows & state.fleet.active[slot])
    return dataclasses.replace(state, pending=state.pending.at[slot].set(row))


@jax.jit
def service_evict_slot(state: ServiceState, slot) -> ServiceState:
    """Evict the client in `slot`: free the slot AND reset its leaves
    immediately, so a recycled slot is bit-for-bit indistinguishable from a
    fresh one (and an inactive slot's state is exactly the fresh value —
    the invariant tests/test_fleet_churn.py pins)."""
    state = _reset_slot(state, slot)
    return dataclasses.replace(
        state, fleet=flt.fleet_evict_slot(state.fleet, slot))


def service_grow(tree: LodTree, cfg: SessionConfig, state: ServiceState,
                 new_capacity: int) -> ServiceState:
    """Pad every slot-axis leaf to `new_capacity` (new slots free + fresh).
    Host-side: growth (and its dual, `service_shrink`) are the lifecycle
    events that change compiled shapes, so each jitted sync path retraces
    exactly once afterwards."""
    f_mgr, f_tmp, f_cut, f_idx, f_pend = _fresh_slot_leaves(state)
    return ServiceState(
        mgr=flt.pad_slots(state.mgr, f_mgr, new_capacity),
        temporal=flt.pad_slots(state.temporal, f_tmp, new_capacity),
        cut_gids=flt.pad_slots(state.cut_gids, f_cut, new_capacity),
        sync_index=flt.pad_slots(state.sync_index, f_idx, new_capacity),
        pending=flt.pad_slots(state.pending, f_pend, new_capacity),
        fleet=flt.fleet_grow(state.fleet, new_capacity),
    )


@jax.jit
def service_shrink(state: ServiceState, perm) -> ServiceState:
    """Compact the fleet into the `len(perm)` slots named by `perm` (live
    slots first, in slot order, then free slots to fill the target
    capacity) — capacity SHRINK, the dual of `service_grow`.

    One gather per leaf (`fleet.take_slots`): survivors keep their exact
    per-slot state (their replay is bitwise — every sync computation is
    slot-parallel and the survivors' relative order is preserved), and the
    gathered free slots are bitwise fresh by the frozen-inactive invariant.
    The shape change retraces each jitted sync path exactly once — same
    contract as growth, downward."""
    return ServiceState(
        mgr=flt.take_slots(state.mgr, perm),
        temporal=flt.take_slots(state.temporal, perm),
        cut_gids=flt.take_slots(state.cut_gids, perm),
        sync_index=flt.take_slots(state.sync_index, perm),
        pending=flt.take_slots(state.pending, perm),
        fleet=flt.fleet_shrink(state.fleet, perm),
    )


@functools.partial(jax.jit, static_argnames=("budget", "mesh"))
def _batched_cut_gids(masks: jax.Array, budget: int, mesh=None):
    def one(m):
        (g,) = jnp.nonzero(m, size=budget, fill_value=-1)
        return g.astype(jnp.int32), m.sum().astype(jnp.int32)
    gids, counts = jax.vmap(one)(masks)
    gids = shd.constrain_fleet(gids, ("clients", None), mesh)
    counts = shd.constrain_fleet(counts, ("clients",), mesh)
    return gids, counts


def _finish_sync(tree: LodTree, cfg: SessionConfig, state: ServiceState,
                 temporal: ls.TemporalState, masks: jax.Array,
                 nodes_touched: jax.Array, resweeps: jax.Array,
                 bytes_per_g: float, codec: Optional[comp.Codec] = None,
                 dedup: bool = False, delta_budget: Optional[int] = None,
                 priority=None, allowance=None,
                 page_size: Optional[int] = None,
                 participate=None,
                 mesh=None) -> Tuple[ServiceState, ServiceStats,
                                     Optional[dp.DeltaBatch]]:
    """Shared tail of both sync paths: batched management-table update,
    per-client render queues, the encode-once Δcut payload, and accounting.

    With `dedup`, the wire format is the shared multicast stream of
    repro.serve.delta_path (one codec call on the fleet union; `sync_bytes`
    uses the shared-payload split, charging only the rows that actually
    shipped plus the page-header framing) and the built `DeltaBatch` is
    returned; otherwise the legacy per-client unicast accounting applies and
    the third element is None.

    The union folds in `state.pending` — rows deferred by earlier paged
    syncs — and the new state's `pending` is this sync's deferred set MINUS
    rows the shared reuse rule evicted meanwhile (`plan.evicted`): a row the
    unbudgeted oracle's client would have dropped by now is debt nobody
    should pay, so dropping it keeps the paged stream bitwise convergent to
    the oracle. `priority` is the (N,) coarse-first rank key (default: the
    tree's `node_levels()`, computed here when not supplied — long-lived
    services pass their cached copy); `allowance` the optional (B,) int32
    per-client row cap (the bitrate controller's knob); `page_size` the
    priority-page granularity (default: one page per stream).

    Ragged fleets: inactive slots (per `state.fleet.active`) are masked out
    of EVERYTHING here — cut masks (⇒ no Δ rows, no cut ids, fresh -1 cut
    queues), the management-table update (their table stays bitwise frozen),
    the wire accounting (0.0 bytes, header included), the Δ-union encode,
    and the per-slot sync counter (it only ticks while active, so a slot's
    counter always reads "syncs since this client was admitted").

    Partial-fleet syncs (`participate`, a (C,) bool slot mask): an ACTIVE
    slot left out of the tick is handled by the exact same frozen-slot
    machinery as an inactive one — no table update, no cut recompute, no
    union rows, 0.0 bytes, no sync-counter tick — EXCEPT that, unlike an
    inactive slot, it keeps what it already had: its render queue
    (`cut_gids`), its pending page debt, and its temporal state survive the
    tick bitwise (the frozen-inactive invariant only proves freshness
    because inactive state IS the reset value; here the preserved value is
    the slot's own). `participate=None` is the lockstep tick and compiles
    the exact pre-scheduler program.

    Sharded fleets (`mesh`): everything per-client here stays on its client
    shard (the table update, cut compaction, wire accounting — and the
    participation mask — are slot-parallel); the one cross-shard step is
    the Δ-union reduction, whose payload replicates
    (repro.serve.delta_path)."""
    active = state.fleet.active
    if participate is None:
        eff = active
    else:
        eff = active & shd.constrain_fleet(
            jnp.asarray(participate, bool), ("clients",), mesh)
    masks = masks & eff[:, None]
    new_mgr, plan = mgr.batched_cloud_sync(state.mgr, masks, state.sync_index,
                                           jnp.int32(cfg.w_star))
    new_mgr = flt.freeze_inactive(new_mgr, state.mgr, eff)
    gids, counts = _batched_cut_gids(masks, cfg.cut_budget, mesh=mesh)
    if participate is not None:
        # a non-participating slot KEEPS its render queue (an inactive one's
        # stored queue is already the fresh -1 row, so this is a no-op for
        # it — and bitwise the lockstep value when everyone is selected)
        gids = jnp.where(eff[:, None], gids, state.cut_gids)
    unicast = mgr.batched_wire_bytes(plan, bytes_per_g, active=eff)
    batch = None
    zero = jnp.int32(0)
    zeros_i = jnp.zeros(counts.shape, jnp.int32)
    if dedup:
        if codec is None or delta_budget is None:
            raise ValueError("dedup sync needs a codec and a delta_budget")
        if priority is None:
            priority = tree.node_levels()
        batch = dp.build_delta_batch(tree.gaussians, codec, plan.delta_data,
                                     delta_budget, active=eff, mesh=mesh,
                                     pending=state.pending, priority=priority,
                                     allowance=allowance, page_size=page_size)
        sync_bytes = mgr.batched_wire_bytes(plan, bytes_per_g,
                                            shared_payload=True,
                                            active=eff,
                                            delivered=batch.delivered,
                                            client_pages=batch.client_pages)
        saved = unicast - sync_bytes
        delta_overflow = batch.client_overflow
        delta_shipped = batch.delivered.sum(axis=1).astype(jnp.int32)
        # carry-over debt: deferred rows survive until they ship — unless
        # the shared reuse rule evicted them meanwhile (the oracle's client
        # would have dropped them too)
        pending = batch.deferred & ~plan.evicted & eff[:, None]
        if participate is not None:
            # a slot that sat the tick out keeps its debt untouched (its
            # rows were masked out of this union, so `deferred` is blank
            # for it — wiping would silently lose its owed pages)
            pending = jnp.where(eff[:, None], pending, state.pending)
        delta_deferred = pending.sum(axis=1).astype(jnp.int32)
        pages = batch.client_pages
    else:
        sync_bytes = unicast
        saved = jnp.zeros_like(unicast)
        delta_overflow = jnp.zeros(counts.shape, bool)
        delta_shipped = jnp.where(eff, plan.n_delta, zero)
        delta_deferred = zeros_i
        pages = zeros_i
        pending = state.pending
    new_state = ServiceState(
        mgr=new_mgr, temporal=temporal, cut_gids=gids,
        sync_index=state.sync_index + eff.astype(jnp.int32),
        pending=pending, fleet=state.fleet)
    stats = ServiceStats(
        cut_size=counts,
        delta_size=plan.n_delta,
        unique_delta=dp.first_owner_counts(plan.delta_data),
        sync_bytes=sync_bytes,
        dedup_bytes_saved=saved,
        nodes_touched=jnp.where(eff, nodes_touched.astype(jnp.int32), zero),
        resweeps=jnp.where(eff, resweeps.astype(jnp.int32), zero),
        client_resident=plan.n_resident,
        overflow=counts > cfg.cut_budget,
        delta_overflow=delta_overflow & eff,
        delta_shipped=delta_shipped,
        delta_deferred=delta_deferred,
        pages=jnp.where(eff, pages, zero),
        mtp_ms=jnp.zeros(counts.shape, jnp.float32),
        deadline_miss=jnp.zeros(counts.shape, bool))
    # pin the declared fleet layout on the outputs (no-op when meshless):
    # every ServiceState/ServiceStats leaf leads with the slot axis and
    # carries the client-shard NamedSharding the acceptance contract names
    new_state = shd.shard_service_state(mesh, new_state)
    stats = shd.shard_service_state(mesh, stats)
    return new_state, stats, batch


# ---------------------------------------------------------------------------
# closed-loop per-client bitrate control (heterogeneous bandwidth tiers)
# ---------------------------------------------------------------------------


BANDWIDTH_TIERS = {
    # per-SYNC downlink budgets (bytes) for heterogeneous clients — the
    # Voyager-style device classes: a phone on cellular, a standalone
    # headset on home Wi-Fi, a tethered headset on a link that is
    # effectively never the bottleneck
    "phone": 2.5e5,
    "headset": 1.5e6,
    "tethered": 1.6e7,
}


def rate_control_step(target_bytes, measured_bytes, allowance, tau_scale, *,
                      page_size: int, max_rows: int,
                      tau_step: float = 1.25, tau_scale_max: float = 8.0):
    """One update of the per-client closed-loop bitrate controller.

    Pure host-side numpy (it runs between syncs, on the previous sync's
    MEASURED per-client wire bytes — a one-sync-delayed feedback loop, the
    price of never forcing the in-flight sync). Two nested knobs per client:

      * `allowance` — rows the client may ingest per sync (its page
        allowance in the priority-ordered union stream). Multiplicative
        feedback: scaled by target/measured, clipped to [x0.5, x2.0] per
        sync so one noisy measurement cannot slam the loop, floored at one
        page (`page_size` — a client always makes progress) and capped at
        `max_rows` (the stream budget).
      * `tau_scale` — the fallback when the allowance alone cannot meet the
        target: a client pinned at the one-page floor and still over budget
        has its foveation threshold scaled up by `tau_step` per sync (coarser
        cut ⇒ fewer Δ rows at the source), up to `tau_scale_max`; once
        comfortably under target (measured < target/tau_step) the scale
        decays back toward 1.0 — the closed loop breathes both ways.

    `measured == 0` under a finite target is MAXIMAL headroom, not "no
    signal": an idle client (nothing shipped last sync) gets the full ×2.0
    allowance step and, if escalated, a τ relax — so one bursty sync can
    never pin a client coarse forever once it goes quiet.

    The allowance floor is `min(page_size, max_rows)`: a page wider than the
    stream budget (degenerate but allowed at the `build_delta_batch` layer,
    which clamps pages to the union width) must not invert the clip bounds —
    `np.clip` with min > max silently returns max everywhere, freezing the
    loop at a value the stream can never serve.

    Clients with a non-finite target (or a negative `allowance` sentinel)
    are uncontrolled and pass through untouched. Returns (allowance,
    tau_scale) as new arrays."""
    target = np.asarray(target_bytes, np.float64)
    measured = np.asarray(measured_bytes, np.float64)
    allowance = np.asarray(allowance, np.int64)
    tau_scale = np.asarray(tau_scale, np.float32)
    controlled = np.isfinite(target) & (allowance >= 0)
    ratio = np.where(controlled,
                     np.where(measured > 0.0,
                              target / np.maximum(measured, 1.0), np.inf),
                     1.0)
    step = np.clip(ratio, 0.5, 2.0)
    lo = min(int(page_size), int(max_rows))
    new_allow = np.where(
        controlled,
        np.clip(np.floor(allowance * step), lo, max_rows),
        allowance).astype(np.int64)
    at_floor = controlled & (new_allow <= lo) & (ratio < 1.0)
    new_tau = np.where(at_floor,
                       np.minimum(tau_scale * tau_step, tau_scale_max),
                       tau_scale)
    relaxed = controlled & ~at_floor & (ratio > tau_step) & (tau_scale > 1.0)
    new_tau = np.where(relaxed, np.maximum(new_tau / tau_step, 1.0), new_tau)
    return new_allow, new_tau.astype(np.float32)


def _bandwidth_bytes(bw) -> float:
    """One client's per-sync byte target: a `BANDWIDTH_TIERS` name, a
    number (bytes/sync), or None/inf for uncontrolled."""
    if bw is None:
        return float("inf")
    if isinstance(bw, str):
        try:
            return float(BANDWIDTH_TIERS[bw])
        except KeyError:
            raise ValueError(f"unknown bandwidth tier {bw!r} (have "
                             f"{sorted(BANDWIDTH_TIERS)})") from None
    return float(bw)


def _fleet_taus(cfg: SessionConfig, n_clients: int, taus) -> jnp.ndarray:
    """(B,) per-client LoD thresholds: cfg.tau everywhere unless a foveated
    per-client vector is given (ROADMAP "Quality": τ as a (B,) vector)."""
    if taus is None:
        return jnp.full((n_clients,), cfg.tau, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    if taus.shape != (n_clients,):
        raise ValueError(f"expected ({n_clients},) taus, got {taus.shape}")
    return taus


def service_sync_vmapped(tree: LodTree, cfg: SessionConfig,
                         state: ServiceState, cam_positions, focal,
                         bytes_per_g: float, taus=None,
                         codec: Optional[comp.Codec] = None,
                         dedup: bool = False,
                         delta_budget: Optional[int] = None,
                         priority=None, allowance=None,
                         page_size: Optional[int] = None,
                         participate=None,
                         mesh=None) -> Tuple[ServiceState, ServiceStats,
                                             Optional[dp.DeltaBatch]]:
    """One LoD sync for every client, fully on-device (vmapped search).

    Exactness reference for the pooled scheduler; also the right path when
    nearly everything is stale (e.g. the fleet's first frame). `taus` is an
    optional (B,) per-client foveated threshold vector; `dedup` switches the
    sync tail to the encode-once fleet wire format (see `_finish_sync`).

    Ragged fleets: the fixed-shape vmapped sweep runs over every SLOT (that
    is the price of this path), but inactive slots' temporal state is
    frozen back to its reset value afterwards, so the resulting state is
    bitwise identical to the pooled scheduler's — which never touches them
    at all. `participate` (a (C,) bool slot mask; the deadline scheduler's
    per-tick selection) freezes non-selected ACTIVE slots the same way —
    except back to their own previous state, not the reset value (see
    `_finish_sync`).

    Sharded fleets: `mesh` (explicit, or the ambient
    `repro.sharding.fleet.use_fleet_mesh`) shards the whole search on the
    clients axis — the vmapped sweep is slot-parallel, so each client shard
    sweeps its own slots; results are bitwise the unsharded service's."""
    mesh = shd.resolve_mesh(mesh)
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    eff = state.fleet.active
    if participate is not None:
        eff = eff & shd.constrain_fleet(
            jnp.asarray(participate, bool), ("clients",), mesh)
    cut, temporal = ls.batched_temporal_search(
        tree, state.temporal, cams, jnp.float32(focal), tau_b)
    temporal = flt.freeze_inactive(temporal, state.temporal, eff)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks,
                        cut.nodes_touched, cut.resweep.sum(axis=1),
                        bytes_per_g, codec=codec, dedup=dedup,
                        delta_budget=delta_budget, priority=priority,
                        allowance=allowance, page_size=page_size,
                        participate=participate, mesh=mesh)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("guard", "mesh"))
def _apply_pooled_updates(slab_cut, root_expand, rho, cam0, sel_b, sel_s,
                          f_cut, f_rexp, f_rho, cam_sel, valid=None, *,
                          guard: bool = False, mesh=None):
    """Scatter pooled sweep results back into the batched temporal state.
    Repeat-padded (client, slab) pairs write identical values — harmless.

    `guard` (static; only the sharded per-shard compaction sets it): a
    client shard with ZERO stale pairs pads its bucket lanes with a
    non-stale (slot 0, slab 0) pair — those lanes re-write the pair's
    CURRENT values (gather-then-scatter in the same program), so an empty
    shard's bucket is provably a no-op. The meshless global pool never pads
    with non-stale pairs (count > 0 is guaranteed), so the unguarded program
    is byte-identical to the pre-mesh service."""
    if guard:
        f_cut = jnp.where(valid[:, None], f_cut, slab_cut[sel_b, sel_s])
        f_rexp = jnp.where(valid, f_rexp, root_expand[sel_b, sel_s])
        f_rho = jnp.where(valid, f_rho, rho[sel_b, sel_s])
        cam_sel = jnp.where(valid[:, None], cam_sel, cam0[sel_b, sel_s])
    out = (slab_cut.at[sel_b, sel_s].set(f_cut),
           root_expand.at[sel_b, sel_s].set(f_rexp),
           rho.at[sel_b, sel_s].set(f_rho),
           cam0.at[sel_b, sel_s].set(cam_sel))
    if mesh is not None:
        out = tuple(shd.constrain_fleet(
            x, ("clients",) + (None,) * (x.ndim - 1), mesh) for x in out)
    return out


@functools.partial(jax.jit, static_argnames=("n_shards", "mesh"))
def _shard_stale_counts(stale: jax.Array, n_shards: int, mesh=None):
    """(n_shards,) stale-pair counts, one per client shard — the ONE host
    transfer of a sharded pooled sync (each shard's count picks the shared
    per-shard pow2 bucket; their sum is the fleet pool size)."""
    counts = stale.reshape(n_shards, -1).sum(axis=1).astype(jnp.int32)
    return shd.constrain_fleet(counts, ("clients",), mesh)


@functools.partial(jax.jit, static_argnames=("bucket", "n_shards", "mesh"))
def _compact_stale_pairs(stale: jax.Array, bucket: int, n_shards: int = 1,
                         mesh=None):
    """On-device compaction of the (B, Ns) staleness mask into per-client-
    shard power-of-two buckets of (client, slab) indices.

    Replaces the old host `np.nonzero(stale)` round-trip: the cumsum-based
    `jnp.nonzero(..., size=bucket)` runs inside the program, and each
    shard's bucket is repeat-padded with its earlier stale pairs
    (idx[i mod count], exactly the old `np.resize` cycle) so padded lanes
    rewrite identical values. Only the static `bucket` size — chosen from
    the per-shard count scalars — crosses to the host.

    With `n_shards` > 1 (a mesh whose `clients` axis divides the capacity)
    every shard compacts its OWN (C/k, Ns) block into its own bucket — the
    compaction is embarrassingly shard-parallel and no staleness mask ever
    crosses shards (the cross-host staleness pool). A shard with zero stale
    pairs marks its lanes invalid (`valid` false) so the scatter can skip
    them; `n_shards=1` reduces exactly to the old single global bucket.

    Returns (sel_b, sel_s, valid), each (n_shards * bucket,) with global
    slot indices."""
    b, ns = stale.shape
    flat = stale.reshape(n_shards, -1)           # (k, (B/k)*Ns)
    flat = shd.constrain_fleet(flat, ("clients", None), mesh)

    def one(f):
        count = f.sum()
        (idx,) = jnp.nonzero(f, size=bucket, fill_value=0)
        sel = idx[jnp.arange(bucket) % jnp.maximum(count, 1)]
        return sel, jnp.broadcast_to(count > 0, (bucket,))

    sel, valid = jax.vmap(one)(flat)             # (k, bucket) shard-local
    base = (jnp.arange(n_shards, dtype=sel.dtype)
            * (b // n_shards))[:, None]          # shard → first global slot
    sel_b = (base + sel // ns).reshape(-1)
    sel_s = (sel % ns).reshape(-1)
    valid = valid.reshape(-1)
    if mesh is not None:
        sel_b = shd.constrain_fleet(sel_b, ("clients",), mesh)
        sel_s = shd.constrain_fleet(sel_s, ("clients",), mesh)
        valid = shd.constrain_fleet(valid, ("clients",), mesh)
    return sel_b, sel_s, valid


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "impl", "interpret", "mesh"))
def _pooled_pair_sweep(tables: ls.SlabTables, rpe, cams, taus, sel_b, sel_s,
                       focal, *, max_depth: int, impl: str, interpret: bool,
                       mesh=None):
    """Gather the pooled pairs' slab attributes from the device-resident
    tables and sweep them — ONE fused program (the gathers never detour
    through the host). `impl` picks the vmapped XLA sweep or the Pallas
    lod-cut kernel (`repro.kernels.lod_cut.lod_pair_sweep_pallas`).

    Sharded fleets: the pair axis is constrained onto the `clients` axis
    (each shard's bucket lanes sweep on that shard); the slab-table gathers
    cross the `slabs` axis, where the partitioner inserts the collectives —
    the XLA sweep partitions cleanly. The Pallas kernel is a single opaque
    dispatch the partitioner cannot split, so under a mesh its pair inputs
    are explicitly REPLICATED first (correct but not scaled — prefer
    impl='xla' on a mesh)."""
    gathered = (tables.mu[sel_s], tables.size[sel_s], tables.parent[sel_s],
                tables.level[sel_s], tables.is_leaf[sel_s],
                tables.valid[sel_s], rpe[sel_b, sel_s], cams[sel_b])
    tau_sel = taus[sel_b]
    if impl == "pallas":
        gathered, tau_sel = shd.replicate_fleet(mesh, (gathered, tau_sel))
        return lc.lod_pair_sweep_pallas(*gathered, focal, tau_sel,
                                        max_depth=max_depth,
                                        interpret=interpret)
    if mesh is not None:
        gathered = tuple(shd.constrain_fleet(
            g, ("clients",) + (None,) * (g.ndim - 1), mesh) for g in gathered)
        tau_sel = shd.constrain_fleet(tau_sel, ("clients",), mesh)
    return ls.sweep_slab_camera_pairs(*gathered, focal, tau_sel, max_depth)


def service_sync_pooled(tree: LodTree, cfg: SessionConfig,
                        state: ServiceState, cam_positions, focal,
                        bytes_per_g: float, taus=None,
                        codec: Optional[comp.Codec] = None,
                        dedup: bool = False,
                        delta_budget: Optional[int] = None,
                        priority=None, allowance=None,
                        page_size: Optional[int] = None,
                        participate=None,
                        tables: Optional[ls.SlabTables] = None,
                        sweep_impl: str = "xla", interpret: bool = True,
                        mesh=None) -> Tuple[ServiceState, ServiceStats,
                                            Optional[dp.DeltaBatch]]:
    """One LoD sync for every client with cross-client slab pooling.

    The batched analog of `temporal_search_hybrid`, now device-scheduled:
    the vmapped top sweep marks every client's stale slabs, the (client,
    slab) pool is compacted ON DEVICE into a power-of-two bucket (bounded
    recompilation), and one dispatch sweeps the bucket — each pair with its
    own camera and τ — before scattering back. Bit-identical results to
    `service_sync_vmapped`.

    Host involvement per sync is scalar reads only (the pool size here —
    plus, with dedup, the Δ-union size in the sync tail — each selecting a
    static bucket); the staleness mask stays on device. Because
    everything else is dispatched asynchronously, the sweep of sync t is
    being scheduled while the device still executes the management-table
    update / encode tail of sync t−1 — the double-buffered pipeline the
    ROADMAP asked for.

    `tables` are the device-resident slab attribute tables
    (`ls.SlabTables.from_tree`); pass them from a long-lived service so the
    per-sync program starts at the pair gather instead of re-deriving the
    slab views. `sweep_impl` = "xla" | "pallas" picks the bucket sweep
    implementation (bit-parity tested).

    Partial-fleet ticks (`participate`, a (C,) bool slot mask): non-selected
    slots are masked out of the staleness pool itself, so the pooled sweep —
    and the pool-size scalars the host awaits — track only the SELECTED
    subset (this is the scheduler's actual work saving, not just an output
    mask); their temporal state, render queue, pending debt, and sync
    counter survive the tick bitwise (see `_finish_sync`).

    Sharded fleets (`mesh`, explicit or ambient): the staleness pool is
    PER CLIENT SHARD — each shard compacts its own slots' stale pairs into
    its own pow2 bucket (`_compact_stale_pairs(n_shards=k)`), the host
    awaits one (k,) per-shard count vector instead of one scalar (their max
    picks the shared bucket size, their sum is the fleet pool), and the
    bucketed sweep runs shard-parallel on the clients axis while its slab
    gathers cross the `slabs` axis. The participation mask is placed on the
    `clients` axis too (`shard_participation`), so partial-tick masking
    stays shard-local. Results are bitwise the unsharded service's:
    repeat-padding differs per shard but padded lanes rewrite identical
    values, and an empty shard's lanes are guarded no-ops.

    NOTE: like `temporal_search_hybrid`, the scatter donates the incoming
    `state.temporal` buffers (no (B, Ns, S) re-copy per sync). On backends
    that honor donation the input state is CONSUMED — keep using the
    returned state, never the argument."""
    m = tree.meta
    mesh = shd.resolve_mesh(mesh)
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    active = state.fleet.active
    eff = active
    if participate is not None:
        eff = active & shd.shard_participation(
            mesh, jnp.asarray(participate, bool))
    if tables is None:
        tables = ls.SlabTables.from_tree(tree, mesh=mesh)
    # inactive slots report zero staleness, so they never enter the pool:
    # sweep work (and the pool-size scalars below) tracks the ACTIVE fleet
    # — and, on a partial tick, only its SELECTED subset
    top_cut, rpe, stale = ls.batched_top_and_staleness(
        tree, state.temporal, cams, jnp.float32(focal), tau_b, eff,
        mesh=mesh)
    k = shd.client_shards(mesh, stale.shape[0])
    # the ONE host synchronization of the sync: pool-size scalars — global
    # for the meshless service, one per client shard under a mesh
    if k > 1:
        shard_counts = np.asarray(
            jax.device_get(_shard_stale_counts(stale, k, mesh=mesh)))
        n_stale = int(shard_counts.sum())
    else:
        n_stale = int(jax.device_get(stale.sum()))
    n_pairs = stale.shape[0] * stale.shape[1]

    tp = state.temporal
    slab_cut, root_expand, rho, cam0 = (tp.slab_cut0, tp.root_expand0,
                                        tp.rho, tp.cam0)
    if n_stale > 0:
        if k > 1:
            bucket = ls.pow2_bucket(int(shard_counts.max()), n_pairs // k)
        else:
            bucket = ls.pow2_bucket(n_stale, n_pairs)
        sel_b, sel_s, valid = _compact_stale_pairs(stale, bucket,
                                                   n_shards=k, mesh=mesh)
        f_cut, f_rexp, f_rho = _pooled_pair_sweep(
            tables, rpe, cams, tau_b, sel_b, sel_s, jnp.float32(focal),
            max_depth=m.slab_max_depth, impl=sweep_impl, interpret=interpret,
            mesh=mesh)
        slab_cut, root_expand, rho, cam0 = _apply_pooled_updates(
            slab_cut, root_expand, rho, cam0, sel_b, sel_s,
            f_cut, f_rexp, f_rho, cams[sel_b], valid, guard=k > 1,
            mesh=mesh)

    # the eff-masked scatter never touches a non-participating slot's
    # donated buffers; freeze the two non-donated leaves the same way so
    # inactive slots stay bitwise at their reset value (swept=False ⇒ still
    # cold) and sat-out slots keep their own previous temporal state
    temporal = ls.TemporalState(
        cam0=cam0, rho=rho,
        parent_expand0=jnp.where(eff[:, None], rpe, tp.parent_expand0),
        slab_cut0=slab_cut, root_expand0=root_expand,
        swept=jnp.where(eff[:, None], True, tp.swept))
    nodes_touched = m.T + stale.sum(axis=1).astype(jnp.int32) * m.S
    cut = ls.CutResult(top_cut=top_cut, slab_cut=slab_cut,
                       root_expand=root_expand, resweep=stale,
                       nodes_touched=nodes_touched)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks, nodes_touched,
                        stale.sum(axis=1), bytes_per_g, codec=codec,
                        dedup=dedup, delta_budget=delta_budget,
                        priority=priority, allowance=allowance,
                        page_size=page_size, participate=participate,
                        mesh=mesh)


# ---------------------------------------------------------------------------
# fleet render step (cloud-rendered fallback clients)
# ---------------------------------------------------------------------------


def _masked_queue(gaussians: Gaussians, gids: jax.Array) -> Gaussians:
    """One client's render queue from its cut ids (-1 padding → α=0 rows)."""
    queue = gaussians.slice_rows(jnp.clip(gids, 0))
    return dataclasses.replace(
        queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))


def service_render_step(tree: LodTree, state: ServiceState, rigs,
                        rcfg: "rnd.RenderConfig", *, path: str = "vmap",
                        interpret: bool = True, mesh=None):
    """Render EVERY client's current cut queue cloud-side in one batched
    stereo dispatch (the fallback tier of Fig. 10: headsets too weak to run
    the client rasterizer receive pixels, not Gaussians).

    Queues are gathered from the cloud's raw tree attributes (the cloud never
    holds the lossy client decode). `rigs` carries a leading client axis (see
    `repro.render.stack_rigs`); `path` picks the vmapped XLA renderer or the
    fleet-pooled Pallas bucket path. Returns (img_l (B,H,W,3), img_r,
    per-client `repro.render.StereoFrameStats`) — the frame-side accounting
    that sits alongside the sync-side `ServiceStats`.

    Ragged fleets: inactive slots' queues are empty (-1 cut everywhere) and
    their slots are masked out of the pooled occupied-tile bucket, so fleet
    rasterization work tracks live clients — inactive slots just return
    black frames.

    Sharded fleets: `mesh` (explicit or ambient) shards the queues and the
    returned fallback frames on the `clients` axis — each client shard
    rasterizes (and holds the pixels of) its own slots."""
    mesh = shd.resolve_mesh(mesh)
    queues = jax.vmap(lambda g: _masked_queue(tree.gaussians, g)
                      )(state.cut_gids)
    queues = shd.shard_service_state(mesh, queues)
    return rnd.batched_render_stereo(queues, rigs, rcfg, path=path,
                                     interpret=interpret,
                                     active=state.fleet.active, mesh=mesh)


class LodService:
    """Thin stateful wrapper: one shared tree/codec, a ragged client fleet.

    `sync(cam_positions)` advances every live client by one LoD sync and
    returns per-SLOT `ServiceStats` (inactive slot rows are all-zero); the
    encode-once fleet payload of the latest sync is kept on `last_delta`
    (`client_delta(cid)` decodes one client's slice). `mode` picks the
    scheduler: "pooled" (cross-client bucketed hybrid, device-compacted —
    the production path) or "vmapped" (always-sweep exactness reference).
    `sweep_impl` selects the pooled bucket sweep: "xla" (vmapped) or
    "pallas" (`repro.kernels.lod_cut.lod_pair_sweep_pallas`;
    `interpret=True` is the CPU default — set False on real TPUs). `dedup`
    toggles the encode-once wire format (on by default; `dedup=False`
    restores per-client unicast accounting and skips the codec). `taus`
    optionally gives every client its own foveated LoD threshold
    (n_clients,). `render_fallback(rigs)` rasterizes every live client's
    current queue cloud-side in one batched dispatch, with the static
    `RenderConfig` and stacked-rig pytree cached per (rig, fleet) signature.

    Fleet lifecycle: `admit(cam, tau)` returns a stable client id;
    `evict(client_id)` frees the slot. Clients live in a `capacity`-slot
    array (default: capacity == n_clients; pass `capacity=` to pre-provision
    a pow2 bucket). Admits/evicts within the capacity bucket are jitted
    slot scatters — zero recompiles; an admit that outgrows the bucket pads
    to `lod_search.pow2_bucket(capacity + 1)` and retraces each jitted path
    exactly once; `maybe_shrink()` is the downward dual (compact a sparse
    fleet into the smaller pow2 bucket — one retrace, survivors replay
    bitwise). `max_clients` / `max_state_bytes` switch growth to
    backpressure: a budget-exceeding `admit` raises `AdmissionDenied` (or
    returns None with `required=False`) and leaves the service untouched.
    Clients are addressed by their stable id everywhere
    (`sync` dicts, `client_cut`, `client_delta`, `client_tau`); for a
    never-churned service ids coincide with 0..B-1, so the legacy positional
    API keeps working unchanged.

    The Δ stream is PAGED (repro.serve.delta_path): a sync whose fleet
    Δ-union exceeds `delta_budget` ships the coarsest `page_size`-row
    priority pages now and carries the rest as per-slot debt
    (`ServiceState.pending`) — every Gaussian arrives within ⌈U/width⌉
    syncs, nothing is silently lost. `bandwidth` turns on the closed-loop
    per-client bitrate controller: pass a `BANDWIDTH_TIERS` name ("phone" /
    "headset" / "tethered"), a bytes-per-sync number, or a per-client
    sequence of either; each sync, the PREVIOUS sync's measured per-client
    `sync_bytes` multiplicatively adjusts that client's row allowance
    (floored at one page, so it always makes progress) and — when the floor
    alone still overshoots — its foveation τ (`rate_control_step`).
    `admit(bandwidth=...)` assigns a tier at admission; an evicted slot
    drops its deferred pages and its controller state.

    `mesh` installs the clients×slabs serving mesh (see the module
    docstring; `launch.make_fleet_mesh`) — sync, lifecycle, and fallback
    render all run sharded, bitwise-identical to the meshless service."""

    def __init__(self, tree: LodTree, cfg: SessionConfig, n_clients: int,
                 focal: float, mode: str = "pooled", taus=None,
                 dedup: bool = True, sweep_impl: str = "xla",
                 interpret: bool = True,
                 delta_budget: Optional[int] = None,
                 capacity: Optional[int] = None,
                 mesh=None, max_clients: Optional[int] = None,
                 max_state_bytes: Optional[float] = None,
                 bandwidth=None, page_size: Optional[int] = None):
        if mode not in ("pooled", "vmapped"):
            raise ValueError(f"unknown scheduler mode: {mode!r}")
        if sweep_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown sweep_impl: {sweep_impl!r}")
        if sweep_impl == "pallas" and mode != "pooled":
            raise ValueError("sweep_impl='pallas' drives the pooled bucket "
                             "sweep; use mode='pooled'")
        self.tree = tree
        self.cfg = cfg
        # the serving mesh (explicit, else the ambient use_fleet_mesh one):
        # clients axis shards per-slot state, slabs axis shards the shared
        # slab tables + union codec rows; None = the single-device service
        self.mesh = shd.resolve_mesh(mesh)
        # admission control (backpressure): deny instead of growing past a
        # live-client count or a total state-byte budget
        self.max_clients = None if max_clients is None else int(max_clients)
        self.max_state_bytes = (None if max_state_bytes is None
                                else float(max_state_bytes))
        self.capacity = (max(int(n_clients), 1) if capacity is None
                         else int(capacity))
        if self.capacity < max(n_clients, 1):
            raise ValueError(f"capacity {self.capacity} < n_clients "
                             f"{n_clients}")
        self.focal = float(focal)
        self.mode = mode
        self.sweep_impl = sweep_impl
        self.interpret = bool(interpret)
        self.dedup = bool(dedup)
        # host-side control-plane mirror of state.fleet (slot lookup and
        # validation without device round-trips; the device FleetState is
        # kept consistent by the jitted admit/evict steps)
        self._active = np.zeros(self.capacity, bool)
        self._active[:n_clients] = True
        self._client_ids = np.full(self.capacity, -1, np.int64)
        self._client_ids[:n_clients] = np.arange(n_clients)
        self._next_id = int(n_clients)
        self._slot_cams = np.zeros((self.capacity, 3), np.float32)
        # per-SLOT foveated thresholds; constructor taus address the initial
        # clients, admitted clients get theirs via admit(tau=...)
        if taus is None:
            self.taus = None
        else:
            per_client = np.asarray(_fleet_taus(cfg, n_clients, taus),
                                    np.float32)
            self.taus = np.full(self.capacity, cfg.tau, np.float32)
            self.taus[:n_clients] = per_client
        self.codec, self.bytes_per_g = session_wire_format(tree, cfg)
        # static union capacity of the encode-once stream: every client's
        # Δcut is bounded by its cut budget, so the fleet union is bounded
        # by min(capacity * cut_budget, N); recomputed on capacity growth
        # unless pinned by the caller
        self._delta_budget_arg = delta_budget
        self.delta_budget = (int(delta_budget) if delta_budget is not None
                             else min(tree.n_pad,
                                      cfg.cut_budget * self.capacity))
        # page_size=None → one 256-row page, clamped to the stream budget.
        # An EXPLICIT page wider than the budget is a config error: the
        # stream could never ship a full page per sync, and the rate
        # controller's allowance floor would sit above its own ceiling
        # (the np.clip(min > max) degenerate the PR 6 controller hit).
        if page_size is None:
            self.page_size = max(1, min(256, self.delta_budget))
        else:
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if page_size > self.delta_budget:
                raise ValueError(
                    f"page_size {page_size} > delta_budget "
                    f"{self.delta_budget}: a page must fit the Δ-stream "
                    f"budget (pass a smaller page_size or raise "
                    f"delta_budget)")
            self.page_size = int(page_size)
        # coarse-first priority key of the paged union stream, derived once
        self._priority = tree.node_levels()
        # closed-loop bitrate controller state (host-side, like `taus`):
        # per-slot byte target (inf = uncontrolled), row allowance
        # (-1 sentinel = uncontrolled) and foveation fallback scale
        self._bw_target = np.full(self.capacity, np.inf, np.float64)
        self._allowance = np.full(self.capacity, -1, np.int64)
        self._tau_scale = np.ones(self.capacity, np.float32)
        self._last_stats: Optional[ServiceStats] = None
        # which rows of _last_stats are FRESH measurements (produced by the
        # immediately-previous sync): on a partial tick (`participate`) a
        # sat-out slot's stats row is its older measurement, and feeding it
        # to the multiplicative controller again would compound one
        # observation — the controller only commits where this mask is True
        self._stats_fresh = np.zeros(self.capacity, bool)
        if bandwidth is not None:
            if isinstance(bandwidth, (list, tuple, np.ndarray)):
                if len(bandwidth) != n_clients:
                    raise ValueError(f"expected {n_clients} bandwidth "
                                     f"entries, got {len(bandwidth)}")
                targets = [_bandwidth_bytes(bw) for bw in bandwidth]
            else:
                targets = [_bandwidth_bytes(bandwidth)] * n_clients
            for slot, target in enumerate(targets):
                self._set_bandwidth_slot(slot, target)
        # device-resident slab tables: gathered once, reused by every pooled
        # sweep (the per-sync program starts at the pair gather); the
        # vmapped reference path never reads them, so don't hold the copy.
        # Under a mesh the tables shard on the slabs axis at placement.
        self.tables = (ls.SlabTables.from_tree(tree, mesh=self.mesh)
                       if mode == "pooled" else None)
        self.state = shd.shard_service_state(
            self.mesh, service_init(tree, cfg, n_clients,
                                    capacity=self.capacity))
        self.last_delta: Optional[dp.DeltaBatch] = None
        self._delta_ids = np.full(self.capacity, -1, np.int64)
        self._rcfg_cache = {}
        self._stack_cache = {}

    # -- fleet lifecycle ------------------------------------------------------

    @property
    def n_clients(self) -> int:
        """Number of LIVE clients (== capacity for a never-churned fleet)."""
        return int(self._active.sum())

    @property
    def active_ids(self):
        """Stable client ids of the live fleet, in slot order (the order
        `sync` expects array-form camera positions in)."""
        return [int(c) for c in self._client_ids[self._active]]

    def _slot_of(self, client_id: int) -> int:
        slots = np.flatnonzero(self._active
                               & (self._client_ids == int(client_id)))
        if slots.size == 0:
            raise KeyError(f"no live client with id {client_id}")
        return int(slots[0])

    def client_tau(self, client_id: int) -> float:
        """One live client's foveated LoD threshold (cfg.tau unless set at
        construction or admission; the bitrate controller's `tau_scale`
        multiplies on top of this base during sync)."""
        slot = self._slot_of(client_id)
        return float(self.cfg.tau if self.taus is None else self.taus[slot])

    def _set_bandwidth_slot(self, slot: int, target: float) -> None:
        """Seed one slot's controller state: its byte target and an initial
        row allowance of target/bytes-per-row (the loop refines it from
        measurements; uncontrolled slots carry the -1 sentinel)."""
        self._bw_target[slot] = target
        self._tau_scale[slot] = 1.0
        if np.isfinite(target):
            rows = int(target // max(self.bytes_per_g, 1.0))
            self._allowance[slot] = int(np.clip(rows, self.page_size,
                                                self.delta_budget))
        else:
            self._allowance[slot] = -1

    def set_bandwidth(self, client_id: int, bandwidth=None) -> None:
        """Re-tier a live client's downlink mid-session (a `BANDWIDTH_TIERS`
        name, bytes/sync, or None to turn control off): reseed its
        closed-loop controller exactly like `admit(bandwidth=...)` would —
        the loop re-converges from the seed allowance over the next syncs."""
        slot = self._slot_of(client_id)
        self._set_bandwidth_slot(slot, _bandwidth_bytes(bandwidth))

    def client_bandwidth(self, client_id: int):
        """One live client's (target_bytes, row_allowance, tau_scale)
        controller triple (target inf / allowance None when uncontrolled)."""
        slot = self._slot_of(client_id)
        allow = int(self._allowance[slot])
        return (float(self._bw_target[slot]),
                None if allow < 0 else allow, float(self._tau_scale[slot]))

    def _slot_state_bytes(self) -> float:
        """Per-slot device bytes of the service state (all slot-axis leaves
        of `ServiceState`, capacity-normalized) — the unit the admission
        byte budget is charged in."""
        total = sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                    for a in jax.tree_util.tree_leaves(self.state)
                    if getattr(a, "ndim", 0) >= 1)
        return float(total) / self.capacity

    def _admission_denial(self) -> Optional[str]:
        """Why the next admit must be refused (None = admissible). Checked
        BEFORE any state mutation, so a denied admit is side-effect free."""
        if self.max_clients is not None \
                and self.n_clients + 1 > self.max_clients:
            return (f"live clients {self.n_clients} at the configured "
                    f"max_clients={self.max_clients}")
        if self.max_state_bytes is not None and not (~self._active).any():
            # a full fleet must GROW to admit — deny if the grown slot
            # array would blow the byte budget (in-bucket admits are free)
            grown = flt.fleet_capacity(self.capacity + 1)
            need = self._slot_state_bytes() * grown
            if need > self.max_state_bytes:
                return (f"growing {self.capacity}->{grown} slots needs "
                        f"{need:.0f} state bytes > max_state_bytes="
                        f"{self.max_state_bytes:.0f}")
        return None

    def admit(self, cam=None, tau: Optional[float] = None,
              required: bool = True, bandwidth=None) -> Optional[int]:
        """Admit one client; returns its stable id. The new slot starts
        fully stale, so the client's first sync is a cold full sweep and a
        cold Δcut. Within the current capacity bucket this is a jitted slot
        scatter (zero recompiles); on a full fleet the capacity grows to the
        next pow2 bucket first (one retrace of each jitted path). `cam`
        seeds the slot's camera (used until the next `sync` provides one);
        `tau` its foveated threshold (default cfg.tau).

        Admission control: with `max_clients` / `max_state_bytes`
        configured, an admit past the budget is DENIED instead of growing
        unboundedly — raising `AdmissionDenied` (`required=True`, the
        default) or returning None (`required=False`, for callers that
        queue and retry). A denied admit leaves the service untouched.

        `bandwidth` assigns the client's downlink tier (a `BANDWIDTH_TIERS`
        name or bytes/sync; default uncontrolled) — its closed-loop bitrate
        controller starts clean, like its pending-page debt."""
        denial = self._admission_denial()
        if denial is not None:
            if required:
                raise AdmissionDenied(denial)
            return None
        free = np.flatnonzero(~self._active)
        if free.size == 0:
            if self.capacity >= flt.MAX_CAPACITY:
                raise ValueError(f"fleet at MAX_CAPACITY ({flt.MAX_CAPACITY})")
            self._grow(flt.fleet_capacity(self.capacity + 1))
            free = np.flatnonzero(~self._active)
        slot = int(free[0])
        client_id = self._next_id
        self._next_id += 1
        self.state = shd.shard_service_state(
            self.mesh, service_admit_slot(self.state, slot, client_id))
        self._active[slot] = True
        self._client_ids[slot] = client_id
        self._slot_cams[slot] = (np.zeros(3, np.float32) if cam is None
                                 else np.asarray(cam, np.float32))
        if tau is not None and self.taus is None:
            self.taus = np.full(self.capacity, self.cfg.tau, np.float32)
        if self.taus is not None:
            self.taus[slot] = float(self.cfg.tau if tau is None else tau)
        self._set_bandwidth_slot(slot, _bandwidth_bytes(bandwidth))
        return client_id

    def evict(self, client_id: int) -> None:
        """Evict a live client. Its slot is freed AND reset in the same
        jitted step, so the next tenant of the slot is bit-for-bit
        indistinguishable from one landing on a never-used slot. No wire
        traffic results: both sides run the shared reuse rule, and the
        vacated slot contributes nothing to any later sync."""
        slot = self._slot_of(client_id)
        self.state = shd.shard_service_state(
            self.mesh, service_evict_slot(self.state, slot))
        self._active[slot] = False
        self._client_ids[slot] = -1
        self._slot_cams[slot] = 0.0
        if self.taus is not None:
            self.taus[slot] = self.cfg.tau
        # the slot's deferred pages died with its ServiceState.pending row
        # (service_evict_slot resets it); drop the controller state too
        self._bw_target[slot] = np.inf
        self._allowance[slot] = -1
        self._tau_scale[slot] = 1.0
        self._stats_fresh[slot] = False

    def _grow(self, new_capacity: int) -> None:
        """Pad every slot-axis array to `new_capacity` (host mirrors
        included). The stacked-rig / RenderConfig caches are dropped: their
        signatures include the capacity bucket, and the pinned pytrees have
        the old leading axis."""
        self.state = shd.shard_service_state(
            self.mesh, service_grow(self.tree, self.cfg, self.state,
                                    new_capacity))
        pad = new_capacity - self.capacity
        self._active = np.concatenate([self._active, np.zeros(pad, bool)])
        self._client_ids = np.concatenate(
            [self._client_ids, np.full(pad, -1, np.int64)])
        self._slot_cams = np.concatenate(
            [self._slot_cams, np.zeros((pad, 3), np.float32)])
        if self.taus is not None:
            self.taus = np.concatenate(
                [self.taus, np.full(pad, self.cfg.tau, np.float32)])
        # new slots have no slice in the latest payload (tenancy -1); the
        # pinned last_delta.ref_mask keeps its pre-growth leading dim — the
        # shrink remap and client_delta both handle the short payload
        self._delta_ids = np.concatenate(
            [self._delta_ids, np.full(pad, -1, np.int64)])
        self._bw_target = np.concatenate(
            [self._bw_target, np.full(pad, np.inf, np.float64)])
        self._allowance = np.concatenate(
            [self._allowance, np.full(pad, -1, np.int64)])
        self._tau_scale = np.concatenate(
            [self._tau_scale, np.ones(pad, np.float32)])
        self._stats_fresh = np.concatenate(
            [self._stats_fresh, np.zeros(pad, bool)])
        if self._last_stats is not None:
            # the feedback source keeps its pre-growth leading dim — pad
            # with zero rows (new slots are uncontrolled until admitted, and
            # a zero measurement is the no-op of the multiplicative loop)
            self._last_stats = jax.tree_util.tree_map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((new_capacity - a.shape[0],)
                                  + a.shape[1:], a.dtype)]),
                self._last_stats)
        self.capacity = new_capacity
        if self._delta_budget_arg is None:
            self.delta_budget = min(self.tree.n_pad,
                                    self.cfg.cut_budget * self.capacity)
        self._rcfg_cache.clear()
        self._stack_cache.clear()

    def maybe_shrink(self) -> Optional[int]:
        """Capacity SHRINK: if the live fleet fits a smaller pow2 bucket,
        compact the live slots to the front (slot order preserved) and
        truncate every slot-axis array to that bucket. Returns the new
        capacity, or None when already right-sized.

        One retrace: the shape change costs each jitted sync path exactly
        one new trace (the growth contract, downward). Survivors replay
        bitwise — every per-sync computation is slot-parallel and the
        survivors keep their relative order, so the pooled sweep, Δ-union
        stream, and first-requester byte split are unchanged. The latest
        encode-once payload's ref-mask rows are remapped through the same
        permutation, so `client_delta` keeps working across the shrink."""
        target = flt.fleet_capacity(max(self.n_clients, 1))
        if target >= self.capacity:
            return None
        live = np.flatnonzero(self._active)
        free = np.flatnonzero(~self._active)
        perm = np.concatenate([live, free])[:target].astype(np.int32)
        self.state = shd.shard_service_state(
            self.mesh, service_shrink(self.state, jnp.asarray(perm)))
        self._active = self._active[perm]
        self._client_ids = self._client_ids[perm]
        self._slot_cams = self._slot_cams[perm]
        if self.taus is not None:
            self.taus = self.taus[perm]
        self.capacity = target
        if self._delta_budget_arg is None:
            self.delta_budget = min(self.tree.n_pad,
                                    self.cfg.cut_budget * self.capacity)
        # client-leading device pytrees that may predate a capacity growth
        # (their leading dim = the capacity at their sync): slots beyond
        # them have no row — give those an all-zero one
        def _remap_rows(a):
            safe = np.minimum(perm, a.shape[0] - 1)
            keep = (perm < a.shape[0]).reshape((-1,) + (1,) *
                                               (a.ndim - 1))
            return jnp.where(keep, a[safe], jnp.zeros((), a.dtype))
        if self._last_stats is not None:
            # the rate controller's feedback source follows the slot
            # permutation like every other per-slot mirror
            self._last_stats = jax.tree_util.tree_map(_remap_rows,
                                                      self._last_stats)
        if self.last_delta is not None:
            # slots with no slice in the payload get an all-zero row (their
            # _delta_ids entry is -1, so client_delta already refuses them);
            # every client-leading leaf remaps through the same permutation
            self.last_delta = dataclasses.replace(
                self.last_delta,
                ref_mask=_remap_rows(self.last_delta.ref_mask),
                delivered=_remap_rows(self.last_delta.delivered),
                deferred=_remap_rows(self.last_delta.deferred),
                client_overflow=_remap_rows(self.last_delta.client_overflow),
                client_pages=_remap_rows(self.last_delta.client_pages))
        self._delta_ids = self._delta_ids[perm]
        self._bw_target = self._bw_target[perm]
        self._allowance = self._allowance[perm]
        self._tau_scale = self._tau_scale[perm]
        self._stats_fresh = self._stats_fresh[perm]
        self._rcfg_cache.clear()
        self._stack_cache.clear()
        return target

    # -- elasticity: live mesh resize + snapshot/restore ----------------------

    def resize_mesh(self, mesh) -> None:
        """Move the LIVE service onto a different `clients`×`slabs` serving
        mesh (bigger, smaller, or `None` for the single-device layout)
        without dropping a client: every `ServiceState` leaf (and the
        device-resident slab tables) is re-placed under the new mesh's fleet
        shardings — the in-memory analog of restore-onto-a-new-mesh. The
        traced signatures of the jitted sync paths include the static mesh,
        so the first sync after a resize retraces once (the same contract as
        a capacity change); results stay bitwise (the divisibility fallback
        replicates anything the new mesh cannot split)."""
        self.mesh = mesh
        if mesh is None:
            dev = jax.devices()[0]
            self.state = jax.device_put(self.state, dev)
            if self.tables is not None:
                self.tables = jax.device_put(self.tables, dev)
            if self.last_delta is not None:
                self.last_delta = jax.device_put(self.last_delta, dev)
        else:
            self.state = shd.shard_service_state(mesh, self.state)
            if self.tables is not None:
                self.tables = shd.shard_slab_tables(mesh, self.tables)
            if self.last_delta is not None:
                # mixed logical axes (union rows vs client slots): replicate
                # — always a correct placement for a broadcast stream
                from jax.sharding import NamedSharding, PartitionSpec
                self.last_delta = jax.device_put(
                    self.last_delta, NamedSharding(mesh, PartitionSpec()))
        self._rcfg_cache.clear()
        self._stack_cache.clear()

    def snapshot(self, directory: str, step: int = 0, *,
                 journal_seq: int = 0) -> str:
        """Atomically serialize the full service — `ServiceState` pytree,
        host control-plane mirrors, bitrate-controller state, and static
        config — as checkpoint `step_<step>` under `directory`
        (repro.serve.recovery.snapshot_service). Returns the final path."""
        from repro.serve import recovery
        return recovery.snapshot_service(self, directory, step=step,
                                         journal_seq=journal_seq)

    @classmethod
    def restore(cls, tree: LodTree, directory: str, step: Optional[int] = None,
                mesh=None) -> "LodService":
        """Rebuild a service from a `snapshot` directory against the SAME
        shared city tree (fingerprint-checked), optionally onto a different
        serving mesh (reshard-on-load; `mesh=None` restores single-device).
        Survivors replay bitwise vs the uninterrupted service
        (tests/test_fleet_recovery.py). Raises
        `repro.serve.recovery.RecoveryError` on any torn/corrupt/mismatched
        snapshot — never a silently divergent service."""
        from repro.serve import recovery
        return recovery.restore_service(tree, directory, step=step, mesh=mesh)

    # -- sync -----------------------------------------------------------------

    def _participation_mask(self, participate) -> Optional[np.ndarray]:
        """Normalize `sync`'s `participate` argument to a (capacity,) bool
        slot mask (None = lockstep): a bool array of capacity length passes
        through; anything else is an iterable of stable CLIENT IDS, each
        resolved to its live slot (unknown ids raise, before any state is
        touched)."""
        if participate is None:
            return None
        arr = np.asarray(participate)
        if arr.dtype == bool:
            if arr.shape != (self.capacity,):
                raise ValueError(f"participation mask shape {arr.shape} != "
                                 f"({self.capacity},)")
            return arr.copy()
        slots = [self._slot_of(int(c)) for c in np.atleast_1d(arr)]
        return flt.slots_mask(self.capacity, slots)

    def sync(self, cam_positions=None, participate=None) -> ServiceStats:
        """One fleet sync. Returns device-resident per-SLOT stats — they
        are NOT forced here, so back-to-back `sync` calls pipeline: the host
        dispatches sync t while the device finishes the table update and
        encode tail of sync t−1 (the only awaits per sync are the pooled
        scheduler's and the encoder's bucket-size scalars).

        `cam_positions` is either an (n_clients, 3) array addressing the
        live clients in slot order (`active_ids` order — the legacy form), a
        {client_id: position} dict updating a subset (others keep their last
        known position), or None (everyone keeps their last position). A
        dict with an unknown client id raises KeyError BEFORE any position
        is stored — a bad id never partially updates `_slot_cams`.

        `participate` makes this a PARTIAL-FLEET tick (the deadline
        scheduler's primitive, repro.serve.scheduler): a (capacity,) bool
        slot mask or an iterable of client ids — only those slots sync;
        everyone else's state (temporal, render queue, pending debt, sync
        counter, controller) survives the tick bitwise untouched, and
        returned stats rows for sat-out slots are zero. A mask selecting
        every live slot replays bitwise against the lockstep
        `participate=None` call (tests/test_scheduler.py).

        With bandwidth-controlled clients the PREVIOUS sync's stats are
        read back here to close the bitrate loop (one forced await per sync
        — only then; an uncontrolled fleet keeps the fully-async pipeline).
        Under partial ticks the controller only commits a slot's update
        when that slot's measurement is fresh (it participated in the
        previous sync) — a stale measurement is never fed through the
        multiplicative loop twice."""
        part_mask = self._participation_mask(participate)
        if isinstance(cam_positions, dict):
            updates = {self._slot_of(cid): np.asarray(pos, np.float32)
                       for cid, pos in cam_positions.items()}
            for slot, pos in updates.items():
                self._slot_cams[slot] = pos
        elif cam_positions is not None:
            cams = np.asarray(cam_positions, np.float32)
            if cams.shape != (self.n_clients, 3):
                raise ValueError(f"expected ({self.n_clients}, 3) camera "
                                 f"positions, got {cams.shape}")
            self._slot_cams[self._active] = cams
        allowance, taus_eff = None, self.taus
        if self.dedup and np.isfinite(self._bw_target).any():
            if self._last_stats is not None:
                measured = np.asarray(self._last_stats.sync_bytes,
                                      np.float64)
                new_allow, new_tau = rate_control_step(
                    self._bw_target, measured, self._allowance,
                    self._tau_scale, page_size=self.page_size,
                    max_rows=self.delta_budget)
                commit = self._stats_fresh
                self._allowance = np.where(commit, new_allow,
                                           self._allowance)
                self._tau_scale = np.where(commit, new_tau,
                                           self._tau_scale
                                           ).astype(np.float32)
            allowance = np.where(self._allowance >= 0, self._allowance,
                                 self.delta_budget).astype(np.int32)
            base = (self.taus if self.taus is not None
                    else np.full(self.capacity, self.cfg.tau, np.float32))
            taus_eff = (base * self._tau_scale).astype(np.float32)
        kw = dict(taus=taus_eff, codec=self.codec, dedup=self.dedup,
                  delta_budget=self.delta_budget, priority=self._priority,
                  allowance=allowance, page_size=self.page_size,
                  participate=part_mask, mesh=self.mesh)
        if self.mode == "pooled":
            self.state, stats, batch = service_sync_pooled(
                self.tree, self.cfg, self.state, self._slot_cams, self.focal,
                self.bytes_per_g, tables=self.tables,
                sweep_impl=self.sweep_impl, interpret=self.interpret, **kw)
        else:
            self.state, stats, batch = service_sync_vmapped(
                self.tree, self.cfg, self.state, self._slot_cams, self.focal,
                self.bytes_per_g, **kw)
        if batch is not None:
            self.last_delta = batch
            # tenancy snapshot: which client each slot's ref_mask row is FOR
            # (guards client_delta against churn between sync and decode)
            self._delta_ids = self._client_ids.copy()
        # feedback source for the NEXT sync's rate-control step (device-
        # resident; only read back when a client is bandwidth-controlled).
        # A partial tick merges: each slot keeps its latest OBSERVED
        # measurement, and _stats_fresh marks which rows this tick renewed.
        if part_mask is None or self._last_stats is None:
            self._last_stats = stats
        else:
            pm = jnp.asarray(part_mask)
            self._last_stats = jax.tree_util.tree_map(
                lambda n, o: jnp.where(
                    pm.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
                stats, self._last_stats)
        self._stats_fresh = (self._active.copy() if part_mask is None
                             else (self._active & part_mask))
        return stats

    def client_cut(self, client_id: int) -> jax.Array:
        """(cut_budget,) int32 render-queue ids of one live client (-1
        padded). Addressed by stable client id (== slot index for a
        never-churned fleet)."""
        return self.state.cut_gids[self._slot_of(client_id)]

    def client_delta(self, client_id: int):
        """Decode one client's Δcut slice of the latest encode-once payload:
        (ids (U,) int32 — -1 where the union row is not this client's — and
        the decoded union rows). Bitwise what the encode-per-client path
        would have delivered (tests/test_delta_path.py).

        The payload is a per-sync artifact: a client admitted (or a slot
        recycled) after the latest sync has no slice in it — that is an
        error, never a silent read of the previous tenant's row."""
        if self.last_delta is None:
            raise ValueError("no sync performed yet (or dedup=False)")
        slot = self._slot_of(client_id)
        if (slot >= len(self._delta_ids)
                or self._delta_ids[slot] != client_id):
            raise ValueError(f"latest payload predates client {client_id}'s "
                             f"admission — sync first")
        return dp.decode_client(self.codec, self.last_delta,
                                self.tree.gaussians.sh.shape[1], slot)

    def delta_checksums(self) -> np.ndarray:
        """(pages,) uint32 per-page checksums of the latest sync's shared
        stream — the values the wire serializer writes into each page header
        (`manager.PAGE_HEADER_BYTES` budgets the slot). A client re-derives
        each page's checksum from the rows it parsed and NACKs mismatches."""
        if self.last_delta is None:
            raise ValueError("no sync performed yet (or dedup=False)")
        return dp.page_checksums(self.last_delta)

    def resolve_nack(self, client_id: int, lost_pages) -> np.ndarray:
        """READ-ONLY half of the page-loss NACK: the ascending gids client
        `client_id` ingested from the named priority pages of the LATEST
        sync's stream — the rows a checksum-failed page costs it, resolved
        against the current payload. `nack` applies them; a journaling layer
        (repro.serve.recovery) records the resolved gids instead of the page
        numbers, so crash replay never depends on a payload that died with
        the process.

        Like `client_delta`, the NACK is a per-sync artifact: it must name
        pages of the latest payload, and a client admitted (or recycled)
        after that sync has no rows in it — that is an error, never a silent
        requeue of the previous tenant's rows."""
        if self.last_delta is None:
            raise ValueError("no sync performed yet (or dedup=False)")
        slot = self._slot_of(client_id)
        if (slot >= len(self._delta_ids)
                or self._delta_ids[slot] != client_id):
            raise ValueError(f"latest payload predates client {client_id}'s "
                             f"admission — nothing to NACK")
        n_pages = int(np.asarray(self.last_delta.pages))
        pages = sorted(set(int(p) for p in lost_pages))
        bad = [p for p in pages if not 0 <= p < n_pages]
        if bad:
            raise ValueError(f"NACK names pages {bad} outside the latest "
                             f"stream's {n_pages} pages")
        return np.flatnonzero(dp.lost_row_mask(self.last_delta, slot, pages))

    def nack_rows(self, client_id: int, gids) -> int:
        """Re-queue specific Gaussians as one live client's pending debt —
        the APPLY half of the NACK (and the form the sync journal replays):
        the rows fold into the next sync's union like budget-deferred pages
        and retransmit through the normal priority stream. Returns the
        number of rows queued."""
        slot = self._slot_of(client_id)
        g = np.asarray(list(gids), np.int64)
        if g.size and (g.min() < 0 or g.max() >= self.tree.n_pad):
            raise ValueError(f"NACK gids outside [0, {self.tree.n_pad})")
        mask = np.zeros((self.tree.n_pad,), bool)
        mask[g] = True
        self.state = shd.shard_service_state(
            self.mesh, service_nack_rows(self.state, slot,
                                         jnp.asarray(mask)))
        return int(mask.sum())

    def nack(self, client_id: int, lost_pages) -> int:
        """Client-reported page loss on the LATEST sync's stream: re-queue
        the rows `client_id` ingested from the named priority pages as
        `ServiceState.pending` debt (`resolve_nack` + `nack_rows`). Returns
        the number of rows re-queued."""
        return self.nack_rows(client_id,
                              self.resolve_nack(client_id, lost_pages))

    # -- fallback rendering ---------------------------------------------------

    def _fleet_key(self):
        """The fleet signature every render cache key must carry: the
        capacity bucket AND the live slot layout. Without it an evict (or a
        slot recycle) would serve a stacked-rig pytree whose slot alignment
        belongs to the previous fleet."""
        return (self.capacity, tuple(np.flatnonzero(self._active)))

    def _slot_aligned_rigs(self, rigs):
        """Expand an n_clients rig list (slot order) to a capacity-length
        slot list; free slots borrow the first rig purely as a shape/static
        placeholder — their queues are empty and the pooled path masks their
        tiles out entirely."""
        rigs = list(rigs)
        if self.n_clients == 0:
            raise ValueError("no live clients to render (fleet is empty)")
        if len(rigs) == self.capacity and self.n_clients == self.capacity:
            return rigs
        if len(rigs) != self.n_clients:
            raise ValueError(f"expected {self.n_clients} rigs (one per live "
                             f"client, slot order) or a slot-aligned stacked "
                             f"pytree, got {len(rigs)}")
        slot_rigs = [rigs[0]] * self.capacity
        for slot, rig in zip(np.flatnonzero(self._active), rigs):
            slot_rigs[int(slot)] = rig
        return slot_rigs

    def _fleet_render_config(self, rigs, tile, list_len, max_pairs):
        """Per-signature cache of the static RenderConfig + stacked rigs.

        Rebuilding the (frozen, hashable) RenderConfig each call re-traces
        nothing by itself, but `for_fleet` + `stack_rigs` walk every rig on
        the host per frame; repeated fleet renders (the steady state of the
        fallback tier) hit the caches instead. Both keys include the fleet
        signature (capacity bucket + live slots), so churn invalidates
        exactly the stale entries; the stack cache additionally keys on rig
        identity and pins the rig objects, so a hit can only mean the exact
        same rig pytrees in the exact same fleet."""
        fleet_key = self._fleet_key()
        static_sig = (tuple((r.left.width, r.left.height, float(r.left.focal),
                             r.left.near, r.left.far, r.baseline)
                            for r in rigs), tile, list_len, max_pairs,
                      fleet_key)
        rcfg = self._rcfg_cache.get(static_sig)
        if rcfg is None:
            rcfg = rnd.RenderConfig.for_fleet(rigs, tile=tile,
                                              list_len=list_len,
                                              max_pairs=max_pairs)
            self._rcfg_cache[static_sig] = rcfg
        stack_key = (tuple(id(r) for r in rigs), fleet_key)
        hit = self._stack_cache.get(stack_key)
        if hit is None:
            if len(self._stack_cache) >= 8:   # bound the pinned rigs
                self._stack_cache.clear()
            hit = (list(rigs), rnd.stack_rigs(rigs))
            self._stack_cache[stack_key] = hit
        return rcfg, hit[1]

    def render_fallback(self, rigs, *, tile: int = 16, list_len: int = 256,
                        max_pairs: int = 1 << 16, path: str = "vmap",
                        interpret: bool = True):
        """Fleet render of every live client's queue → (img_l, img_r, stats)
        with a leading SLOT axis (inactive slots render black).

        `rigs` is a list of n_clients StereoRigs (shared resolution/
        baseline; slot order, like `sync`) or an already slot-aligned
        stacked rig pytree. The derived static `RenderConfig` (and, for rig
        lists, the stacked pytree) is cached per (rig, fleet) signature so
        repeated fleet renders skip the per-call host rebuild — and churn
        can never serve a stale stacked-rig pytree."""
        if isinstance(rigs, (list, tuple)):
            rcfg, rigs = self._fleet_render_config(
                self._slot_aligned_rigs(rigs), tile, list_len, max_pairs)
        else:
            from repro.core.stereo import n_categories
            focal = float(np.max(np.asarray(rigs.left.focal)))
            static_sig = (rigs.left.width, rigs.left.height, focal,
                          rigs.left.near, rigs.baseline, tile, list_len,
                          max_pairs, self._fleet_key())
            rcfg = self._rcfg_cache.get(static_sig)
            if rcfg is None:
                max_disp = focal * rigs.baseline / rigs.left.near
                rcfg = rnd.RenderConfig(
                    width=rigs.left.width, height=rigs.left.height, tile=tile,
                    list_len=list_len, max_pairs=max_pairs,
                    n_cat=n_categories(max_disp, tile))
                self._rcfg_cache[static_sig] = rcfg
        return service_render_step(self.tree, self.state, rigs, rcfg,
                                   path=path, interpret=interpret,
                                   mesh=self.mesh)
