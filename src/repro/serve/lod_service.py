"""Batched multi-client LoD service — the cloud half of paper Fig. 9/10 at
serving scale.

In the paper's collaborative split, the cloud runs the temporal-aware LoD
search and the Gaussian-management table per headset, and ships compressed
Δcuts downstream; the client only renders (Fig. 10 keeps the
motion-to-photon path entirely client-side). This module scales the cloud
half from one headset to B concurrent headsets against ONE shared city tree:

  * one `LodTree` + one scene codec are shared by every client (the codec is
    scene-level, so the client-side "codebook buffer" of §5 is identical for
    all users);
  * per-client state — `TemporalState` (LoD-search reuse), `ManagerState`
    (management table), sync counters — is stacked on a leading batch axis
    (`ServiceState`), exactly the functional-core layout of
    repro.core.pipeline scaled to B;
  * `service_sync_vmapped` runs the per-frame temporal LoD search vmapped
    across clients: one fused device program, bit-identical per client to the
    sequential single-client search;
  * `service_sync_pooled` is the host-driven scheduler: the cheap exact
    top-tree sweep + staleness predicate runs vmapped for all clients, then
    the *stale (client, slab) pairs of every client are pooled into one
    power-of-two bucket* and swept by a single
    `lod_search.sweep_slab_camera_pairs` dispatch (each pair carries its own
    camera). This extends `temporal_search_hybrid` across clients: wall-clock
    cost scales with TOTAL staleness in the fleet, not with client count — a
    fleet of mostly-still headsets costs almost nothing beyond the top
    sweeps.

Per-sync, per-client byte and work accounting (`ServiceStats`) feeds
benchmarks/bench_multiclient.py (the multi-user analog of the paper's
bandwidth figures). Follow-ons tracked in ROADMAP.md: cross-client Δcut
payload dedup (overlapping viewers request the same Gaussians) and
client-side Pallas stereo batching.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.gaussians import Gaussians
from repro.core.lod_tree import LodTree
from repro.core.pipeline import SessionConfig, session_wire_format
from repro import render as rnd


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceState:
    """All per-client cloud state, batched on a leading (B, ...) axis."""

    mgr: mgr.ManagerState       # leaves (B, N)
    temporal: ls.TemporalState  # leaves (B, Ns, ...)
    cut_gids: jax.Array         # (B, cut_budget) int32, -1 padded
    sync_index: jax.Array       # (B,) int32

    @property
    def n_clients(self) -> int:
        return self.sync_index.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Per-client accounting for one service sync (all leaves (B,))."""

    cut_size: jax.Array        # int32 — render-queue size
    delta_size: jax.Array      # int32 — Δcut Gaussians shipped
    sync_bytes: jax.Array      # float32 — downlink bytes (payload + ids)
    nodes_touched: jax.Array   # int32 — LoD-search work attributed to client
    resweeps: jax.Array        # int32 — stale subtrees swept
    client_resident: jax.Array  # int32 — client store occupancy after sync
    overflow: jax.Array        # bool — cut exceeded cut_budget (queue truncated)


def service_init(tree: LodTree, cfg: SessionConfig, n_clients: int
                 ) -> ServiceState:
    m = tree.meta
    return ServiceState(
        mgr=jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape),
            mgr.ManagerState.initial(tree.n_pad)),
        temporal=ls.TemporalState.initial_batched(m.Ns, m.S, n_clients),
        cut_gids=jnp.full((n_clients, cfg.cut_budget), -1, jnp.int32),
        sync_index=jnp.zeros((n_clients,), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("budget",))
def _batched_cut_gids(masks: jax.Array, budget: int):
    def one(m):
        (g,) = jnp.nonzero(m, size=budget, fill_value=-1)
        return g.astype(jnp.int32), m.sum().astype(jnp.int32)
    return jax.vmap(one)(masks)


def _finish_sync(tree: LodTree, cfg: SessionConfig, state: ServiceState,
                 temporal: ls.TemporalState, masks: jax.Array,
                 nodes_touched: jax.Array, resweeps: jax.Array,
                 bytes_per_g: float) -> Tuple[ServiceState, ServiceStats]:
    """Shared tail of both sync paths: batched management-table update,
    per-client render queues, and accounting."""
    new_mgr, plan = mgr.batched_cloud_sync(state.mgr, masks, state.sync_index,
                                           jnp.int32(cfg.w_star))
    gids, counts = _batched_cut_gids(masks, cfg.cut_budget)
    new_state = ServiceState(
        mgr=new_mgr, temporal=temporal, cut_gids=gids,
        sync_index=state.sync_index + 1)
    stats = ServiceStats(
        cut_size=counts,
        delta_size=plan.n_delta,
        sync_bytes=mgr.batched_wire_bytes(plan, bytes_per_g),
        nodes_touched=nodes_touched.astype(jnp.int32),
        resweeps=resweeps.astype(jnp.int32),
        client_resident=plan.n_resident,
        overflow=counts > cfg.cut_budget)
    return new_state, stats


def _fleet_taus(cfg: SessionConfig, n_clients: int, taus) -> jnp.ndarray:
    """(B,) per-client LoD thresholds: cfg.tau everywhere unless a foveated
    per-client vector is given (ROADMAP "Quality": τ as a (B,) vector)."""
    if taus is None:
        return jnp.full((n_clients,), cfg.tau, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    if taus.shape != (n_clients,):
        raise ValueError(f"expected ({n_clients},) taus, got {taus.shape}")
    return taus


def service_sync_vmapped(tree: LodTree, cfg: SessionConfig,
                         state: ServiceState, cam_positions, focal,
                         bytes_per_g: float, taus=None
                         ) -> Tuple[ServiceState, ServiceStats]:
    """One LoD sync for every client, fully on-device (vmapped search).

    Exactness reference for the pooled scheduler; also the right path when
    nearly everything is stale (e.g. the fleet's first frame). `taus` is an
    optional (B,) per-client foveated threshold vector."""
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    cut, temporal = ls.batched_temporal_search(
        tree, state.temporal, cams, jnp.float32(focal), tau_b)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks,
                        cut.nodes_touched, cut.resweep.sum(axis=1),
                        bytes_per_g)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _apply_pooled_updates(slab_cut, root_expand, rho, cam0, sel_b, sel_s,
                          f_cut, f_rexp, f_rho, cam_sel):
    """Scatter pooled sweep results back into the batched temporal state.
    Repeat-padded (client, slab) pairs write identical values — harmless."""
    return (slab_cut.at[sel_b, sel_s].set(f_cut),
            root_expand.at[sel_b, sel_s].set(f_rexp),
            rho.at[sel_b, sel_s].set(f_rho),
            cam0.at[sel_b, sel_s].set(cam_sel))


def service_sync_pooled(tree: LodTree, cfg: SessionConfig,
                        state: ServiceState, cam_positions, focal,
                        bytes_per_g: float, taus=None
                        ) -> Tuple[ServiceState, ServiceStats]:
    """One LoD sync for every client with cross-client slab pooling.

    Host-driven (the batched analog of `temporal_search_hybrid`): gather the
    stale (client, slab) pairs of ALL clients, round the pool up to a
    power-of-two bucket (bounded recompilation), sweep it in one dispatch —
    each pair with its own camera — and scatter back. Bit-identical results
    to `service_sync_vmapped`.

    NOTE: like `temporal_search_hybrid`, the scatter donates the incoming
    `state.temporal` buffers (no (B, Ns, S) re-copy per sync). On backends
    that honor donation the input state is CONSUMED — keep using the
    returned state, never the argument."""
    m = tree.meta
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    top_cut, rpe, stale = ls.batched_top_and_staleness(
        tree, state.temporal, cams, jnp.float32(focal), tau_b)
    stale_np = np.asarray(stale)
    b_idx, s_idx = np.nonzero(stale_np)
    n_stale = len(b_idx)

    tp = state.temporal
    slab_cut, root_expand, rho, cam0 = (tp.slab_cut0, tp.root_expand0,
                                        tp.rho, tp.cam0)
    if n_stale > 0:
        n_pairs = stale_np.size
        bucket = 1 << int(np.ceil(np.log2(max(n_stale, 1))))
        bucket = min(bucket, n_pairs)
        pad = np.resize(np.arange(n_stale), bucket)  # repeat-pad the pool
        sel_b = jnp.asarray(b_idx[pad])
        sel_s = jnp.asarray(s_idx[pad])
        f_cut, f_rexp, f_rho = ls.sweep_slab_camera_pairs(
            tree.slab_mu()[sel_s], tree.slab_size()[sel_s],
            tree.slab_parent[sel_s], tree.slab_level[sel_s],
            tree.slab_is_leaf[sel_s], tree.slab_valid[sel_s],
            rpe[sel_b, sel_s], cams[sel_b],
            jnp.float32(focal), tau_b[sel_b], m.slab_max_depth)
        slab_cut, root_expand, rho, cam0 = _apply_pooled_updates(
            slab_cut, root_expand, rho, cam0, sel_b, sel_s,
            f_cut, f_rexp, f_rho, cams[sel_b])

    temporal = ls.TemporalState(
        cam0=cam0, rho=rho, parent_expand0=rpe, slab_cut0=slab_cut,
        root_expand0=root_expand,
        swept=jnp.ones_like(stale))
    nodes_touched = m.T + stale.sum(axis=1).astype(jnp.int32) * m.S
    cut = ls.CutResult(top_cut=top_cut, slab_cut=slab_cut,
                       root_expand=root_expand, resweep=stale,
                       nodes_touched=nodes_touched)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks, nodes_touched,
                        stale.sum(axis=1), bytes_per_g)


# ---------------------------------------------------------------------------
# fleet render step (cloud-rendered fallback clients)
# ---------------------------------------------------------------------------


def _masked_queue(gaussians: Gaussians, gids: jax.Array) -> Gaussians:
    """One client's render queue from its cut ids (-1 padding → α=0 rows)."""
    queue = gaussians.slice_rows(jnp.clip(gids, 0))
    return dataclasses.replace(
        queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))


def service_render_step(tree: LodTree, state: ServiceState, rigs,
                        rcfg: "rnd.RenderConfig", *, path: str = "vmap",
                        interpret: bool = True):
    """Render EVERY client's current cut queue cloud-side in one batched
    stereo dispatch (the fallback tier of Fig. 10: headsets too weak to run
    the client rasterizer receive pixels, not Gaussians).

    Queues are gathered from the cloud's raw tree attributes (the cloud never
    holds the lossy client decode). `rigs` carries a leading client axis (see
    `repro.render.stack_rigs`); `path` picks the vmapped XLA renderer or the
    fleet-pooled Pallas bucket path. Returns (img_l (B,H,W,3), img_r,
    per-client `repro.render.StereoFrameStats`) — the frame-side accounting
    that sits alongside the sync-side `ServiceStats`."""
    queues = jax.vmap(lambda g: _masked_queue(tree.gaussians, g)
                      )(state.cut_gids)
    return rnd.batched_render_stereo(queues, rigs, rcfg, path=path,
                                     interpret=interpret)


class LodService:
    """Thin stateful wrapper: one shared tree/codec, B client sessions.

    `sync(cam_positions)` advances every client by one LoD sync and returns
    per-client `ServiceStats`. `mode` picks the scheduler: "pooled"
    (cross-client bucketed hybrid — the production path) or "vmapped"
    (always-sweep exactness reference). `taus` optionally gives every client
    its own foveated LoD threshold (B,). `render_fallback(rigs)` rasterizes
    every client's current queue cloud-side in one batched dispatch."""

    def __init__(self, tree: LodTree, cfg: SessionConfig, n_clients: int,
                 focal: float, mode: str = "pooled", taus=None):
        if mode not in ("pooled", "vmapped"):
            raise ValueError(f"unknown scheduler mode: {mode!r}")
        self.tree = tree
        self.cfg = cfg
        self.n_clients = n_clients
        self.focal = float(focal)
        self.mode = mode
        # validate eagerly (shared with the sync-time path)
        self.taus = (None if taus is None
                     else np.asarray(_fleet_taus(cfg, n_clients, taus)))
        self.codec, self.bytes_per_g = session_wire_format(tree, cfg)
        self.state = service_init(tree, cfg, n_clients)

    def sync(self, cam_positions) -> ServiceStats:
        cams = np.asarray(cam_positions, np.float32)
        if cams.shape != (self.n_clients, 3):
            raise ValueError(f"expected ({self.n_clients}, 3) camera "
                             f"positions, got {cams.shape}")
        step = (service_sync_pooled if self.mode == "pooled"
                else service_sync_vmapped)
        self.state, stats = step(self.tree, self.cfg, self.state, cams,
                                 self.focal, self.bytes_per_g, taus=self.taus)
        return stats

    def client_cut(self, client: int) -> jax.Array:
        """(cut_budget,) int32 render-queue ids of one client (-1 padded)."""
        return self.state.cut_gids[client]

    def render_fallback(self, rigs, *, tile: int = 16, list_len: int = 256,
                        max_pairs: int = 1 << 16, path: str = "vmap",
                        interpret: bool = True):
        """Fleet render of all B clients' queues → (img_l, img_r, stats).

        `rigs` is a list of B StereoRigs (shared resolution/baseline) or an
        already-stacked rig pytree."""
        if isinstance(rigs, (list, tuple)):
            rcfg = rnd.RenderConfig.for_fleet(rigs, tile=tile,
                                              list_len=list_len,
                                              max_pairs=max_pairs)
            rigs = rnd.stack_rigs(rigs)
        else:
            from repro.core.stereo import n_categories
            max_disp = (float(jnp.max(rigs.left.focal)) * rigs.baseline
                        / rigs.left.near)
            rcfg = rnd.RenderConfig(
                width=rigs.left.width, height=rigs.left.height, tile=tile,
                list_len=list_len, max_pairs=max_pairs,
                n_cat=n_categories(max_disp, tile))
        return service_render_step(self.tree, self.state, rigs, rcfg,
                                   path=path, interpret=interpret)
