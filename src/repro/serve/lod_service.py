"""Batched multi-client LoD service — the cloud half of paper Fig. 9/10 at
serving scale.

In the paper's collaborative split, the cloud runs the temporal-aware LoD
search and the Gaussian-management table per headset, and ships compressed
Δcuts downstream; the client only renders (Fig. 10 keeps the
motion-to-photon path entirely client-side). This module scales the cloud
half from one headset to B concurrent headsets against ONE shared city tree:

  * one `LodTree` + one scene codec are shared by every client (the codec is
    scene-level, so the client-side "codebook buffer" of §5 is identical for
    all users);
  * per-client state — `TemporalState` (LoD-search reuse), `ManagerState`
    (management table), sync counters — is stacked on a leading batch axis
    (`ServiceState`), exactly the functional-core layout of
    repro.core.pipeline scaled to B;
  * `service_sync_vmapped` runs the per-frame temporal LoD search vmapped
    across clients: one fused device program, bit-identical per client to the
    sequential single-client search;
  * `service_sync_pooled` is the production scheduler: the cheap exact
    top-tree sweep + staleness predicate runs vmapped for all clients, then
    the *stale (client, slab) pairs of every client are pooled into one
    power-of-two bucket* and swept by a single dispatch (each pair carries
    its own camera and τ). Pooling, compaction, and the pair gather all run
    ON DEVICE — the only host transfers on the steady-state path are two
    scalars, the stale-pool size and the Δ-union size, each picking a
    static pow2 bucket (bounded recompilation); the staleness and Δ masks
    themselves never leave the device. Wall-clock cost scales with TOTAL
    staleness in the fleet, not with client count;
  * the sync tail is **encode-once** (`repro.serve.delta_path`): the
    fleet-union Δcut is quantized/packed by ONE batched codec call and
    fanned out as (union-offset, mask) references, so downlink bytes and
    cloud encode FLOPs grow with the fleet's *unique* Gaussians, not with B
    — co-located viewers are nearly free.

Scheduling is double-buffered by construction: every sync is dispatched
asynchronously and only the bucket-size scalars are awaited, so while the
host schedules the pooled slab sweep of sync t the device is still executing
the management-table update + encode of sync t−1 (see
`service_sync_pooled`).

Per-sync, per-client byte and work accounting (`ServiceStats`, now including
`unique_delta` / `dedup_bytes_saved`) feeds benchmarks/bench_multiclient.py
and benchmarks/bench_fleet_sync.py (the multi-user analogs of the paper's
bandwidth figures). Remaining follow-ons tracked in ROADMAP.md: sharding
`ServiceState`/tree on the cloud mesh, runtime client admission/eviction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.gaussians import Gaussians
from repro.core.lod_tree import LodTree
from repro.core.pipeline import SessionConfig, session_wire_format
from repro.kernels import lod_cut as lc
from repro.serve import delta_path as dp
from repro import render as rnd


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceState:
    """All per-client cloud state, batched on a leading (B, ...) axis."""

    mgr: mgr.ManagerState       # leaves (B, N)
    temporal: ls.TemporalState  # leaves (B, Ns, ...)
    cut_gids: jax.Array         # (B, cut_budget) int32, -1 padded
    sync_index: jax.Array       # (B,) int32

    @property
    def n_clients(self) -> int:
        return self.sync_index.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Per-client accounting for one service sync (all leaves (B,))."""

    cut_size: jax.Array        # int32 — render-queue size
    delta_size: jax.Array      # int32 — Δcut Gaussians shipped to the client
    unique_delta: jax.Array    # int32 — Δ rows this client contributed to the
    #                            fleet union (first requester); sums to the
    #                            union size across clients
    sync_bytes: jax.Array      # float32 — downlink bytes (payload + ids)
    dedup_bytes_saved: jax.Array  # float32 — unicast-path bytes minus
    #                            encode-once bytes (0 when dedup is off;
    #                            slightly NEGATIVE for a sole requester —
    #                            the shared stream carries explicit union
    #                            ids the unicast format left implicit)
    nodes_touched: jax.Array   # int32 — LoD-search work attributed to client
    resweeps: jax.Array        # int32 — stale subtrees swept
    client_resident: jax.Array  # int32 — client store occupancy after sync
    overflow: jax.Array        # bool — cut exceeded cut_budget (queue truncated)
    delta_overflow: jax.Array  # bool — fleet Δ-union exceeded delta_budget
    #                            (encode-once payload truncated; always False
    #                            with dedup off or the default budget)


def service_init(tree: LodTree, cfg: SessionConfig, n_clients: int
                 ) -> ServiceState:
    m = tree.meta
    return ServiceState(
        mgr=jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape),
            mgr.ManagerState.initial(tree.n_pad)),
        temporal=ls.TemporalState.initial_batched(m.Ns, m.S, n_clients),
        cut_gids=jnp.full((n_clients, cfg.cut_budget), -1, jnp.int32),
        sync_index=jnp.zeros((n_clients,), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("budget",))
def _batched_cut_gids(masks: jax.Array, budget: int):
    def one(m):
        (g,) = jnp.nonzero(m, size=budget, fill_value=-1)
        return g.astype(jnp.int32), m.sum().astype(jnp.int32)
    return jax.vmap(one)(masks)


def _finish_sync(tree: LodTree, cfg: SessionConfig, state: ServiceState,
                 temporal: ls.TemporalState, masks: jax.Array,
                 nodes_touched: jax.Array, resweeps: jax.Array,
                 bytes_per_g: float, codec: Optional[comp.Codec] = None,
                 dedup: bool = False, delta_budget: Optional[int] = None
                 ) -> Tuple[ServiceState, ServiceStats,
                            Optional[dp.DeltaBatch]]:
    """Shared tail of both sync paths: batched management-table update,
    per-client render queues, the encode-once Δcut payload, and accounting.

    With `dedup`, the wire format is the shared multicast stream of
    repro.serve.delta_path (one codec call on the fleet union; `sync_bytes`
    uses the shared-payload split) and the built `DeltaBatch` is returned;
    otherwise the legacy per-client unicast accounting applies and the third
    element is None."""
    new_mgr, plan = mgr.batched_cloud_sync(state.mgr, masks, state.sync_index,
                                           jnp.int32(cfg.w_star))
    gids, counts = _batched_cut_gids(masks, cfg.cut_budget)
    unicast = mgr.batched_wire_bytes(plan, bytes_per_g)
    batch = None
    if dedup:
        if codec is None or delta_budget is None:
            raise ValueError("dedup sync needs a codec and a delta_budget")
        batch = dp.build_delta_batch(tree.gaussians, codec, plan.delta_data,
                                     delta_budget)
        sync_bytes = mgr.batched_wire_bytes(plan, bytes_per_g,
                                            shared_payload=True)
        saved = unicast - sync_bytes
        delta_overflow = jnp.broadcast_to(batch.overflow, counts.shape)
    else:
        sync_bytes = unicast
        saved = jnp.zeros_like(unicast)
        delta_overflow = jnp.zeros(counts.shape, bool)
    new_state = ServiceState(
        mgr=new_mgr, temporal=temporal, cut_gids=gids,
        sync_index=state.sync_index + 1)
    stats = ServiceStats(
        cut_size=counts,
        delta_size=plan.n_delta,
        unique_delta=dp.first_owner_counts(plan.delta_data),
        sync_bytes=sync_bytes,
        dedup_bytes_saved=saved,
        nodes_touched=nodes_touched.astype(jnp.int32),
        resweeps=resweeps.astype(jnp.int32),
        client_resident=plan.n_resident,
        overflow=counts > cfg.cut_budget,
        delta_overflow=delta_overflow)
    return new_state, stats, batch


def _fleet_taus(cfg: SessionConfig, n_clients: int, taus) -> jnp.ndarray:
    """(B,) per-client LoD thresholds: cfg.tau everywhere unless a foveated
    per-client vector is given (ROADMAP "Quality": τ as a (B,) vector)."""
    if taus is None:
        return jnp.full((n_clients,), cfg.tau, jnp.float32)
    taus = jnp.asarray(taus, jnp.float32)
    if taus.shape != (n_clients,):
        raise ValueError(f"expected ({n_clients},) taus, got {taus.shape}")
    return taus


def service_sync_vmapped(tree: LodTree, cfg: SessionConfig,
                         state: ServiceState, cam_positions, focal,
                         bytes_per_g: float, taus=None,
                         codec: Optional[comp.Codec] = None,
                         dedup: bool = False,
                         delta_budget: Optional[int] = None
                         ) -> Tuple[ServiceState, ServiceStats,
                                    Optional[dp.DeltaBatch]]:
    """One LoD sync for every client, fully on-device (vmapped search).

    Exactness reference for the pooled scheduler; also the right path when
    nearly everything is stale (e.g. the fleet's first frame). `taus` is an
    optional (B,) per-client foveated threshold vector; `dedup` switches the
    sync tail to the encode-once fleet wire format (see `_finish_sync`)."""
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    cut, temporal = ls.batched_temporal_search(
        tree, state.temporal, cams, jnp.float32(focal), tau_b)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks,
                        cut.nodes_touched, cut.resweep.sum(axis=1),
                        bytes_per_g, codec=codec, dedup=dedup,
                        delta_budget=delta_budget)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _apply_pooled_updates(slab_cut, root_expand, rho, cam0, sel_b, sel_s,
                          f_cut, f_rexp, f_rho, cam_sel):
    """Scatter pooled sweep results back into the batched temporal state.
    Repeat-padded (client, slab) pairs write identical values — harmless."""
    return (slab_cut.at[sel_b, sel_s].set(f_cut),
            root_expand.at[sel_b, sel_s].set(f_rexp),
            rho.at[sel_b, sel_s].set(f_rho),
            cam0.at[sel_b, sel_s].set(cam_sel))


@functools.partial(jax.jit, static_argnames=("bucket",))
def _compact_stale_pairs(stale: jax.Array, bucket: int):
    """On-device compaction of the (B, Ns) staleness mask into a static
    power-of-two bucket of (client, slab) indices.

    Replaces the old host `np.nonzero(stale)` round-trip: the cumsum-based
    `jnp.nonzero(..., size=bucket)` runs inside the program, and the bucket
    is repeat-padded with earlier stale pairs (idx[i mod count], exactly the
    old `np.resize` cycle) so padded lanes rewrite identical values. Only
    the static `bucket` size — chosen from the pool-size scalar — crosses to
    the host."""
    ns = stale.shape[1]
    flat = stale.reshape(-1)
    count = flat.sum()
    (idx,) = jnp.nonzero(flat, size=bucket, fill_value=0)
    sel = idx[jnp.arange(bucket) % jnp.maximum(count, 1)]
    return sel // ns, sel % ns


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "impl", "interpret"))
def _pooled_pair_sweep(tables: ls.SlabTables, rpe, cams, taus, sel_b, sel_s,
                       focal, *, max_depth: int, impl: str, interpret: bool):
    """Gather the pooled pairs' slab attributes from the device-resident
    tables and sweep them — ONE fused program (the gathers never detour
    through the host). `impl` picks the vmapped XLA sweep or the Pallas
    lod-cut kernel (`repro.kernels.lod_cut.lod_pair_sweep_pallas`)."""
    args = (tables.mu[sel_s], tables.size[sel_s], tables.parent[sel_s],
            tables.level[sel_s], tables.is_leaf[sel_s], tables.valid[sel_s],
            rpe[sel_b, sel_s], cams[sel_b])
    if impl == "pallas":
        return lc.lod_pair_sweep_pallas(*args, focal, taus[sel_b],
                                        max_depth=max_depth,
                                        interpret=interpret)
    return ls.sweep_slab_camera_pairs(*args, focal, taus[sel_b], max_depth)


def service_sync_pooled(tree: LodTree, cfg: SessionConfig,
                        state: ServiceState, cam_positions, focal,
                        bytes_per_g: float, taus=None,
                        codec: Optional[comp.Codec] = None,
                        dedup: bool = False,
                        delta_budget: Optional[int] = None,
                        tables: Optional[ls.SlabTables] = None,
                        sweep_impl: str = "xla", interpret: bool = True
                        ) -> Tuple[ServiceState, ServiceStats,
                                   Optional[dp.DeltaBatch]]:
    """One LoD sync for every client with cross-client slab pooling.

    The batched analog of `temporal_search_hybrid`, now device-scheduled:
    the vmapped top sweep marks every client's stale slabs, the (client,
    slab) pool is compacted ON DEVICE into a power-of-two bucket (bounded
    recompilation), and one dispatch sweeps the bucket — each pair with its
    own camera and τ — before scattering back. Bit-identical results to
    `service_sync_vmapped`.

    Host involvement per sync is scalar reads only (the pool size here —
    plus, with dedup, the Δ-union size in the sync tail — each selecting a
    static bucket); the staleness mask stays on device. Because
    everything else is dispatched asynchronously, the sweep of sync t is
    being scheduled while the device still executes the management-table
    update / encode tail of sync t−1 — the double-buffered pipeline the
    ROADMAP asked for.

    `tables` are the device-resident slab attribute tables
    (`ls.SlabTables.from_tree`); pass them from a long-lived service so the
    per-sync program starts at the pair gather instead of re-deriving the
    slab views. `sweep_impl` = "xla" | "pallas" picks the bucket sweep
    implementation (bit-parity tested).

    NOTE: like `temporal_search_hybrid`, the scatter donates the incoming
    `state.temporal` buffers (no (B, Ns, S) re-copy per sync). On backends
    that honor donation the input state is CONSUMED — keep using the
    returned state, never the argument."""
    m = tree.meta
    cams = jnp.asarray(cam_positions, jnp.float32)
    tau_b = _fleet_taus(cfg, cams.shape[0], taus)
    if tables is None:
        tables = ls.SlabTables.from_tree(tree)
    top_cut, rpe, stale = ls.batched_top_and_staleness(
        tree, state.temporal, cams, jnp.float32(focal), tau_b)
    # the ONE host synchronization of the sync: the pool-size scalar
    n_stale = int(jax.device_get(stale.sum()))
    n_pairs = stale.shape[0] * stale.shape[1]

    tp = state.temporal
    slab_cut, root_expand, rho, cam0 = (tp.slab_cut0, tp.root_expand0,
                                        tp.rho, tp.cam0)
    if n_stale > 0:
        bucket = ls.pow2_bucket(n_stale, n_pairs)
        sel_b, sel_s = _compact_stale_pairs(stale, bucket)
        f_cut, f_rexp, f_rho = _pooled_pair_sweep(
            tables, rpe, cams, tau_b, sel_b, sel_s, jnp.float32(focal),
            max_depth=m.slab_max_depth, impl=sweep_impl, interpret=interpret)
        slab_cut, root_expand, rho, cam0 = _apply_pooled_updates(
            slab_cut, root_expand, rho, cam0, sel_b, sel_s,
            f_cut, f_rexp, f_rho, cams[sel_b])

    temporal = ls.TemporalState(
        cam0=cam0, rho=rho, parent_expand0=rpe, slab_cut0=slab_cut,
        root_expand0=root_expand,
        swept=jnp.ones_like(stale))
    nodes_touched = m.T + stale.sum(axis=1).astype(jnp.int32) * m.S
    cut = ls.CutResult(top_cut=top_cut, slab_cut=slab_cut,
                       root_expand=root_expand, resweep=stale,
                       nodes_touched=nodes_touched)
    masks = ls.batched_cut_mask(cut, tree)
    return _finish_sync(tree, cfg, state, temporal, masks, nodes_touched,
                        stale.sum(axis=1), bytes_per_g, codec=codec,
                        dedup=dedup, delta_budget=delta_budget)


# ---------------------------------------------------------------------------
# fleet render step (cloud-rendered fallback clients)
# ---------------------------------------------------------------------------


def _masked_queue(gaussians: Gaussians, gids: jax.Array) -> Gaussians:
    """One client's render queue from its cut ids (-1 padding → α=0 rows)."""
    queue = gaussians.slice_rows(jnp.clip(gids, 0))
    return dataclasses.replace(
        queue, opacity=jnp.where(gids >= 0, queue.opacity, 0.0))


def service_render_step(tree: LodTree, state: ServiceState, rigs,
                        rcfg: "rnd.RenderConfig", *, path: str = "vmap",
                        interpret: bool = True):
    """Render EVERY client's current cut queue cloud-side in one batched
    stereo dispatch (the fallback tier of Fig. 10: headsets too weak to run
    the client rasterizer receive pixels, not Gaussians).

    Queues are gathered from the cloud's raw tree attributes (the cloud never
    holds the lossy client decode). `rigs` carries a leading client axis (see
    `repro.render.stack_rigs`); `path` picks the vmapped XLA renderer or the
    fleet-pooled Pallas bucket path. Returns (img_l (B,H,W,3), img_r,
    per-client `repro.render.StereoFrameStats`) — the frame-side accounting
    that sits alongside the sync-side `ServiceStats`."""
    queues = jax.vmap(lambda g: _masked_queue(tree.gaussians, g)
                      )(state.cut_gids)
    return rnd.batched_render_stereo(queues, rigs, rcfg, path=path,
                                     interpret=interpret)


class LodService:
    """Thin stateful wrapper: one shared tree/codec, B client sessions.

    `sync(cam_positions)` advances every client by one LoD sync and returns
    per-client `ServiceStats`; the encode-once fleet payload of the latest
    sync is kept on `last_delta` (`client_delta(i)` decodes one client's
    slice). `mode` picks the scheduler: "pooled" (cross-client bucketed
    hybrid, device-compacted — the production path) or "vmapped"
    (always-sweep exactness reference). `sweep_impl` selects the pooled
    bucket sweep: "xla" (vmapped) or "pallas"
    (`repro.kernels.lod_cut.lod_pair_sweep_pallas`; `interpret=True` is the
    CPU default — set False on real TPUs). `dedup` toggles the encode-once
    wire format (on by default; `dedup=False` restores per-client unicast
    accounting and skips the codec). `taus` optionally gives every client
    its own foveated LoD threshold (B,). `render_fallback(rigs)` rasterizes
    every client's current queue cloud-side in one batched dispatch, with
    the static `RenderConfig` and stacked-rig pytree cached per rig
    signature."""

    def __init__(self, tree: LodTree, cfg: SessionConfig, n_clients: int,
                 focal: float, mode: str = "pooled", taus=None,
                 dedup: bool = True, sweep_impl: str = "xla",
                 interpret: bool = True,
                 delta_budget: Optional[int] = None):
        if mode not in ("pooled", "vmapped"):
            raise ValueError(f"unknown scheduler mode: {mode!r}")
        if sweep_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown sweep_impl: {sweep_impl!r}")
        if sweep_impl == "pallas" and mode != "pooled":
            raise ValueError("sweep_impl='pallas' drives the pooled bucket "
                             "sweep; use mode='pooled'")
        self.tree = tree
        self.cfg = cfg
        self.n_clients = n_clients
        self.focal = float(focal)
        self.mode = mode
        self.sweep_impl = sweep_impl
        self.interpret = bool(interpret)
        self.dedup = bool(dedup)
        # validate eagerly (shared with the sync-time path)
        self.taus = (None if taus is None
                     else np.asarray(_fleet_taus(cfg, n_clients, taus)))
        self.codec, self.bytes_per_g = session_wire_format(tree, cfg)
        # static union capacity of the encode-once stream: every client's
        # Δcut is bounded by its cut budget, so the fleet union is bounded by
        # min(B * cut_budget, N)
        self.delta_budget = (int(delta_budget) if delta_budget is not None
                             else min(tree.n_pad, cfg.cut_budget * n_clients))
        # device-resident slab tables: gathered once, reused by every pooled
        # sweep (the per-sync program starts at the pair gather); the
        # vmapped reference path never reads them, so don't hold the copy
        self.tables = (ls.SlabTables.from_tree(tree) if mode == "pooled"
                       else None)
        self.state = service_init(tree, cfg, n_clients)
        self.last_delta: Optional[dp.DeltaBatch] = None
        self._rcfg_cache = {}
        self._stack_cache = {}

    def sync(self, cam_positions) -> ServiceStats:
        """One fleet sync. Returns device-resident per-client stats — they
        are NOT forced here, so back-to-back `sync` calls pipeline: the host
        dispatches sync t while the device finishes the table update and
        encode tail of sync t−1 (the only awaits per sync are the pooled
        scheduler's and the encoder's bucket-size scalars)."""
        cams = np.asarray(cam_positions, np.float32)
        if cams.shape != (self.n_clients, 3):
            raise ValueError(f"expected ({self.n_clients}, 3) camera "
                             f"positions, got {cams.shape}")
        kw = dict(taus=self.taus, codec=self.codec, dedup=self.dedup,
                  delta_budget=self.delta_budget)
        if self.mode == "pooled":
            self.state, stats, batch = service_sync_pooled(
                self.tree, self.cfg, self.state, cams, self.focal,
                self.bytes_per_g, tables=self.tables,
                sweep_impl=self.sweep_impl, interpret=self.interpret, **kw)
        else:
            self.state, stats, batch = service_sync_vmapped(
                self.tree, self.cfg, self.state, cams, self.focal,
                self.bytes_per_g, **kw)
        if batch is not None:
            self.last_delta = batch
        return stats

    def client_cut(self, client: int) -> jax.Array:
        """(cut_budget,) int32 render-queue ids of one client (-1 padded)."""
        return self.state.cut_gids[client]

    def client_delta(self, client: int):
        """Decode one client's Δcut slice of the latest encode-once payload:
        (ids (U,) int32 — -1 where the union row is not this client's — and
        the decoded union rows). Bitwise what the encode-per-client path
        would have delivered (tests/test_delta_path.py)."""
        if self.last_delta is None:
            raise ValueError("no sync performed yet (or dedup=False)")
        return dp.decode_client(self.codec, self.last_delta,
                                self.tree.gaussians.sh.shape[1], client)

    # -- fallback rendering ---------------------------------------------------

    def _fleet_render_config(self, rigs, tile, list_len, max_pairs):
        """Per-signature cache of the static RenderConfig + stacked rigs.

        Rebuilding the (frozen, hashable) RenderConfig each call re-traces
        nothing by itself, but `for_fleet` + `stack_rigs` walk every rig on
        the host per frame; repeated fleet renders (the steady state of the
        fallback tier) hit the caches instead. The stack cache keys on rig
        identity and pins the rig objects, so a hit can only mean the exact
        same rig pytrees."""
        static_sig = (tuple((r.left.width, r.left.height, float(r.left.focal),
                             r.left.near, r.left.far, r.baseline)
                            for r in rigs), tile, list_len, max_pairs)
        rcfg = self._rcfg_cache.get(static_sig)
        if rcfg is None:
            rcfg = rnd.RenderConfig.for_fleet(rigs, tile=tile,
                                              list_len=list_len,
                                              max_pairs=max_pairs)
            self._rcfg_cache[static_sig] = rcfg
        stack_key = tuple(id(r) for r in rigs)
        hit = self._stack_cache.get(stack_key)
        if hit is None:
            if len(self._stack_cache) >= 8:   # bound the pinned rigs
                self._stack_cache.clear()
            hit = (list(rigs), rnd.stack_rigs(rigs))
            self._stack_cache[stack_key] = hit
        return rcfg, hit[1]

    def render_fallback(self, rigs, *, tile: int = 16, list_len: int = 256,
                        max_pairs: int = 1 << 16, path: str = "vmap",
                        interpret: bool = True):
        """Fleet render of all B clients' queues → (img_l, img_r, stats).

        `rigs` is a list of B StereoRigs (shared resolution/baseline) or an
        already-stacked rig pytree. The derived static `RenderConfig` (and,
        for rig lists, the stacked pytree) is cached per rig signature so
        repeated fleet renders skip the per-call host rebuild."""
        if isinstance(rigs, (list, tuple)):
            rcfg, rigs = self._fleet_render_config(list(rigs), tile,
                                                  list_len, max_pairs)
        else:
            from repro.core.stereo import n_categories
            focal = float(np.max(np.asarray(rigs.left.focal)))
            static_sig = (rigs.left.width, rigs.left.height, focal,
                          rigs.left.near, rigs.baseline, tile, list_len,
                          max_pairs)
            rcfg = self._rcfg_cache.get(static_sig)
            if rcfg is None:
                max_disp = focal * rigs.baseline / rigs.left.near
                rcfg = rnd.RenderConfig(
                    width=rigs.left.width, height=rigs.left.height, tile=tile,
                    list_len=list_len, max_pairs=max_pairs,
                    n_cat=n_categories(max_disp, tile))
                self._rcfg_cache[static_sig] = rcfg
        return service_render_step(self.tree, self.state, rigs, rcfg,
                                   path=path, interpret=interpret)
