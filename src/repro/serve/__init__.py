"""Batched serving: the multi-client LoD cloud service (`lod_service`), the
ragged-fleet lifecycle (`fleet`: runtime client admission/eviction on pow2
capacity buckets), the encode-once Δcut dedup path (`delta_path`), the
deadline-driven motion-to-photon scheduler (`scheduler`), and crash
recovery (`recovery`)."""
