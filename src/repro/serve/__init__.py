"""Batched serving: the multi-client LoD cloud service (`lod_service`) and
the LM prefill/decode engine (`engine`)."""
