"""Batched serving engine: prefill tier + decode tier.

Two-tier disaggregation (DESIGN.md §4 — the framework-level transfer of the
paper's cloud/client split): prefill is throughput-bound and batched per
request group; decode is latency-bound and runs a fixed-batch step with slot
recycling. On a multi-pod mesh the two tiers live on different pods; here
both run on the same devices but through the same interfaces."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import ModelBundle


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Static-batch continuous decoding over `slots` concurrent requests."""

    def __init__(self, model: ModelBundle, slots: int, max_len: int,
                 greedy: bool = True):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.params: Optional[dict] = None
        self._decode = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}

    def load(self, seed: int = 0):
        self.params, _ = self.model.init(jax.random.PRNGKey(seed))

    def submit(self, req: Request):
        self.queue.append(req)

    def run(self) -> List[Request]:
        """Drain the queue: per-request prefill (the throughput tier would
        batch these), then lockstep batched decode with slot recycling."""
        finished: List[Request] = []
        while self.queue or self.active:
            # fill free slots
            while self.queue and len(self.active) < self.slots:
                req = self.queue.pop(0)
                self.active[req.rid] = req
            finished.extend(self._decode_round())
        return finished

    def _decode_round(self) -> List[Request]:
        reqs = list(self.active.values())
        # per-request prefill → merge caches batch-wise is engine machinery;
        # for clarity each round re-prefills the batch (batch = slot count)
        b = len(reqs)
        max_prompt = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(reqs):
            toks[i, -len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)},
            max_len=max_prompt + max(r.max_new for r in reqs))
        for _ in range(max(r.max_new for r in reqs)):
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i, r in enumerate(reqs):
                if not r.done and len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
                    if len(r.out) >= r.max_new:
                        r.done = True
            if all(r.done for r in reqs):
                break
            logits, cache = self._decode(self.params, cache, {"token": nxt})
        done = [r for r in reqs if r.done]
        for r in done:
            del self.active[r.rid]
        return done
