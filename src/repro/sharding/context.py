"""Ambient sharding context: models stay mesh-agnostic.

Model code calls `constrain(x, ("batch", None, "embed_act"))` with *logical*
axis names; under a launcher-installed context (mesh + rules) this becomes a
with_sharding_constraint, otherwise it is a no-op — so smoke tests and
single-device runs need no plumbing."""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.sharding.partitioning import _spec_entry, axes_for_dim

_RULES: contextvars.ContextVar[Optional[Dict[str, Tuple[str, ...]]]] = (
    contextvars.ContextVar("logical_axis_rules", default=None))


def activation_rules(mesh, seq_parallel: bool = True
                     ) -> Dict[str, Tuple[str, ...]]:
    """Logical → mesh axes for ACTIVATIONS (weights: partitioning.py).

    `mesh` may be a Mesh or a tuple of axis names (sizes then unknown and
    divisibility is not enforced). seq_parallel shards block-boundary
    activations' seq dim over `model` — Megatron-SP style; this is what keeps
    the saved scan carries (one residual per layer) within HBM for the big
    train cells (§Perf iteration log)."""
    if hasattr(mesh, "axis_names"):
        names = tuple(mesh.axis_names)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    else:
        names = tuple(mesh)
        sizes = {}
    has_pod = "pod" in names
    batch = ("pod", "data") if has_pod else ("data",)
    rules = {
        "batch": batch,
        "experts": ("model",),
        "expert_cap": batch,
        "expert_groups": batch,
        "heads_act": ("model",),
        "embed_act": (),          # replicated activations along features
        "ffn_act": ("model",),
        "vocab_act": ("model",),
        "seq_act": ("model",) if seq_parallel else (),
        "__sizes__": sizes,
    }
    return rules


def current_rules():
    return _RULES.get()


@contextlib.contextmanager
def use_rules(rules: Dict[str, Tuple[str, ...]]):
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def bshard(x: jax.Array) -> jax.Array:
    """Constrain block-boundary activations: batch over the data axes and —
    for (B, S, D) activations — seq over `model` (sequence parallelism: saved
    residuals shrink by the TP degree; attention/k-v re-gathers inside the
    block). Indivisible dims silently fall back to replicated."""
    if x.ndim >= 3:
        return constrain(x, ("batch", "seq_act") + (None,) * (x.ndim - 2))
    return constrain(x, ("batch",) + (None,) * (x.ndim - 1))


def constrain(x: jax.Array, logical: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply a sharding constraint if a context is active (else no-op).
    Axes that do not divide the corresponding dim are dropped — via the SAME
    `partitioning.axes_for_dim` rule the weight-sharding path uses (one
    shared helper, so a multi-axis product can't be checked one way here and
    another way there; with partially-known `__sizes__` the old local check
    multiplied only the known axes and could silently drop a divisible
    multi-axis split)."""
    rules = _RULES.get()
    if rules is None:
        return x
    sizes = rules.get("__sizes__") or None
    spec = []
    for i, name in enumerate(logical):
        axes = axes_for_dim(name, x.shape[i], rules, mesh_names=None,
                            mesh_sizes=sizes)
        spec.append(_spec_entry(axes))
    return jax.lax.with_sharding_constraint(x, P(*spec))
