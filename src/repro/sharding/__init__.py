from repro.sharding.context import (activation_rules, constrain,
                                    current_rules, use_rules)
from repro.sharding.partitioning import (logical_to_pspec, make_shardings,
                                         LOGICAL_RULES)
