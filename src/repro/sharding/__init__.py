from repro.sharding.context import (activation_rules, constrain,
                                    current_rules, use_rules)
from repro.sharding.partitioning import (axes_for_dim, logical_to_pspec,
                                         make_shardings, LOGICAL_RULES)
from repro.sharding.fleet import (FLEET_RULES, constrain_fleet,
                                  current_fleet_mesh, fleet_axis_rules,
                                  fleet_shardings, fleet_totals,
                                  replicate_fleet, shard_service_state,
                                  shard_slab_tables, slab_shardings,
                                  use_fleet_mesh)
