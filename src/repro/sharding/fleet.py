"""Fleet-service sharding: client-axis × slab-axis partitioning of the
cloud LoD sync path (ROADMAP "shard ServiceState + tree on the cloud mesh").

The serving mesh has two logical axes:

  clients — shards every per-slot leaf of the service on its leading SLOT
            axis (`ServiceState` / `FleetState` / `ServiceStats` /
            per-client cut queues / fallback frames). A host owns a
            contiguous block of slots: its staleness pool, management
            tables, Δ ref-mask rows, and wire accounting all live where its
            clients live.
  slabs   — shards the SHARED tree's slab attribute tables
            (`lod_search.SlabTables`, leading Ns axis) and the row axis of
            the encode-once union codec work, so one city's attribute
            tables need not fit a single accelerator's HBM.

Logical names are mapped to mesh axes by `fleet_axis_rules` (the default
mesh simply names its axes "clients"/"slabs" — `launch.make_fleet_mesh`),
through the SAME `partitioning.axes_for_dim` divisibility rule as the
weight/activation paths: an axis whose size does not divide the dimension
falls back to REPLICATED, never a partial split — so on a single device (or
any indivisible layout) every constraint is a no-op and the service is
bitwise the unsharded one.

The mesh is ambient (`use_fleet_mesh` / `current_fleet_mesh`):
`LodService(mesh=...)` installs it once and the functional sync paths pick
it up; plumbing-free callers can wrap any functional call themselves. The
jitted service kernels take the mesh as a STATIC argument (a `Mesh` is
hashable), so a meshed and an unmeshed service in one process can never
collide on a traced signature — the no-mesh traces stay byte-identical to
the pre-mesh code.

Cross-shard semantics worth knowing (tested in
tests/test_sharding_fleet.py):

  * the Δ-union `any` over clients is a cross-shard reduction; the union
    mask — and therefore the encode-once payload — comes back REPLICATED
    across client shards (the "replicated-union fallback": every host holds
    the full multicast stream, which is exactly the wire model — the stream
    is broadcast to everyone anyway);
  * `fleet_totals` reduces per-slot `ServiceStats` columns to fleet scalars
    with a `psum` over the clients axis (`shard_map`) when the mesh makes
    that meaningful, and a plain sum otherwise — int/bool totals are
    bit-identical either way; float columns may differ in the last ulp
    (per-shard partial sums reassociate the additions).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.partitioning import logical_to_pspec

# logical → mesh axes for the serving stack (remappable: a launcher that
# wants clients over an existing "data" axis passes its own rules)
FLEET_RULES: Dict[str, Tuple[str, ...]] = {
    "clients": ("clients",),   # leading slot axis of per-client state
    "slabs": ("slabs",),       # Ns axis of the shared slab tables
    "union": ("slabs",),       # row axis of the encode-once codec work
}


def fleet_axis_rules(mesh: Mesh,
                     rules: Optional[Dict[str, Tuple[str, ...]]] = None
                     ) -> Dict[str, Tuple[str, ...]]:
    """`FLEET_RULES` filtered to `mesh`'s axes, with `__sizes__` attached
    (the form `context.constrain`-style helpers consume)."""
    base = dict(FLEET_RULES if rules is None else rules)
    names = set(mesh.axis_names)
    out = {k: tuple(a for a in v if a in names)
           for k, v in base.items() if k != "__sizes__"}
    out["__sizes__"] = dict(zip(mesh.axis_names, mesh.devices.shape))
    return out


# -- ambient mesh -----------------------------------------------------------

_FLEET_MESH: contextvars.ContextVar[Optional[Mesh]] = (
    contextvars.ContextVar("fleet_mesh", default=None))


def current_fleet_mesh() -> Optional[Mesh]:
    return _FLEET_MESH.get()


@contextlib.contextmanager
def use_fleet_mesh(mesh: Optional[Mesh]):
    """Install `mesh` as the ambient serving mesh: functional sync calls
    (`service_sync_vmapped` / `service_sync_pooled` / `service_render_step`)
    that are not given an explicit mesh pick it up here. `LodService`
    captures it at construction, so a long-lived service needs no `with`."""
    token = _FLEET_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _FLEET_MESH.reset(token)


def resolve_mesh(mesh: Optional[Mesh]) -> Optional[Mesh]:
    """Explicit mesh if given, else the ambient one (else None)."""
    return mesh if mesh is not None else _FLEET_MESH.get()


def mesh_signature(mesh: Optional[Mesh]):
    """JSON-able identity of a serving mesh — [[axis, size], ...] in axis
    order, or None for the meshless service. Recorded in snapshot manifests
    (repro.serve.recovery) so a restore can report the layout the state was
    SAVED under; restore itself is mesh-free (reshard-on-load device_puts
    every leaf under whatever target mesh the caller brings)."""
    if mesh is None:
        return None
    return [[str(a), int(s)]
            for a, s in zip(mesh.axis_names, mesh.devices.shape)]


def client_shards(mesh: Optional[Mesh], capacity: int) -> int:
    """How many client shards the slot axis actually splits into: the mesh's
    `clients` size when it divides `capacity`, else 1 (the replicate
    fallback — same divisibility rule as every constraint here)."""
    if mesh is None or "clients" not in mesh.axis_names:
        return 1
    k = int(mesh.shape["clients"])
    return k if k > 0 and capacity % k == 0 else 1


# -- constraints & placement ------------------------------------------------


def fleet_pspec(mesh: Mesh, logical: Tuple[Optional[str], ...],
                shape: Tuple[int, ...]) -> P:
    """PartitionSpec for one leaf under the fleet rules (shape-checked —
    indivisible dims replicate; the same `logical_to_pspec` every other
    rule table goes through)."""
    return logical_to_pspec(logical, mesh, tuple(shape),
                            fleet_axis_rules(mesh))


def constrain_fleet(x: jax.Array, logical: Tuple[Optional[str], ...],
                    mesh: Optional[Mesh]) -> jax.Array:
    """`with_sharding_constraint` under the fleet rules; no-op when no mesh.
    Usable inside jit (the service kernels pass their static mesh arg)."""
    if mesh is None:
        return x
    spec = fleet_pspec(mesh, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_participation(mesh: Optional[Mesh], mask) -> jax.Array:
    """Place a per-tick (C,) participation mask (the deadline scheduler's
    selected-slot set, `LodService.sync(participate=...)`) on the `clients`
    axis, like every other per-slot leaf: each client shard holds its own
    slots' bits, so the partial-sync masking (`active & participate`) stays
    shard-local and no mask ever crosses shards. No-op without a mesh."""
    mask = jnp.asarray(mask, bool)
    if mesh is None:
        return mask
    return jax.device_put(
        mask, NamedSharding(mesh, fleet_pspec(mesh, ("clients",),
                                              mask.shape)))


def _leading_axis_shardings(mesh: Mesh, tree: Any, axis_name: str):
    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if not shape:
            return NamedSharding(mesh, P())
        logical = (axis_name,) + (None,) * (len(shape) - 1)
        return NamedSharding(mesh, logical_to_pspec(
            logical, mesh, shape, fleet_axis_rules(mesh)))
    return jax.tree_util.tree_map(one, tree)


def fleet_shardings(mesh: Mesh, state: Any):
    """Tree of NamedShardings for any per-client pytree whose array leaves
    lead with the slot axis (`ServiceState`, `FleetState`, `ServiceStats`,
    stacked rigs, ...). Scalars replicate; an indivisible slot axis
    replicates (so a CPU/single-device run is a bitwise no-op) — the
    `partitioning.logical_to_pspec` fallback, not a second rule."""
    return _leading_axis_shardings(mesh, state, "clients")


def slab_shardings(mesh: Mesh, tables: Any):
    """NamedShardings for the shared tree's slab-axis pytrees
    (`lod_search.SlabTables`: every leaf leads with Ns)."""
    return _leading_axis_shardings(mesh, tables, "slabs")


def shard_service_state(mesh: Optional[Mesh], state: Any):
    """Pin `state`'s leaves to their fleet NamedShardings (device_put; the
    sync paths call this on every returned state so
    `state.leaf.sharding.spec` is always the declared layout, independent of
    what GSPMD propagation chose for the final jit output)."""
    if mesh is None:
        return state
    return jax.device_put(state, fleet_shardings(mesh, state))


def shard_slab_tables(mesh: Optional[Mesh], tables: Any):
    """Pin the shared slab attribute tables on the `slabs` axis."""
    if mesh is None:
        return tables
    return jax.device_put(tables, slab_shardings(mesh, tables))


def replicate_fleet(mesh: Optional[Mesh], tree: Any):
    """Replicate a pytree on every device of the fleet mesh — the opaque-
    kernel fallback: a Pallas dispatch the SPMD partitioner cannot split
    (the pooled lod-cut pair sweep, the pooled tile rasterizer) gets
    explicitly replicated inputs instead of shard-local garbage. Works
    inside jit (a constraint) and eagerly (device_put semantics); no-op
    without a mesh."""
    if mesh is None:
        return tree
    rep = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda a: jax.lax.with_sharding_constraint(a, rep), tree)


# -- cross-host reductions --------------------------------------------------


def fleet_totals(stats: Any, mesh: Optional[Mesh] = None):
    """Reduce per-slot stats columns ((C,) leaves) to fleet totals.

    With a mesh whose `clients` axis divides C, the reduction runs as a
    `shard_map` whose cross-shard half is an explicit `jax.lax.psum` over
    the clients axis — each host sums its own slots locally and one
    all-reduce combines them (the cross-host staleness-pool accounting).
    Otherwise it is a plain sum. Bool columns count (int32). Int/bool
    totals are bit-identical between the two paths (integer addition is
    associative); float columns (`sync_bytes`, `dedup_bytes_saved`) may
    differ in the last ulp once totals leave float32's exact-integer range
    — per-shard partial sums reassociate the additions."""
    mesh = resolve_mesh(mesh)

    def local(s):
        return jax.tree_util.tree_map(
            lambda a: (a.astype(jnp.int32) if a.dtype == jnp.bool_
                       else a).sum(axis=0), s)

    leaves = jax.tree_util.tree_leaves(stats)
    cap = leaves[0].shape[0] if leaves else 0
    k = client_shards(mesh, int(cap))
    if k <= 1:
        return local(stats)
    from jax.experimental.shard_map import shard_map

    def shardwise(s):
        return jax.tree_util.tree_map(
            lambda a: jax.lax.psum(a, "clients"), local(s))

    in_specs = jax.tree_util.tree_map(
        lambda a: P(*(("clients",) + (None,) * (a.ndim - 1))), stats)
    out_specs = jax.tree_util.tree_map(lambda a: P(), stats)
    return shard_map(shardwise, mesh=mesh, in_specs=(in_specs,),
                     out_specs=out_specs, check_rep=False)(stats)


def shard_resident_bytes(mesh: Optional[Mesh], *trees: Any) -> int:
    """Max per-shard resident bytes of the given pytrees under their fleet
    placement (analytic: each leaf's nbytes divided by the product of its
    spec's mesh axis sizes). With no mesh: the plain total."""
    total = 0.0
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            nbytes = int(np.prod(leaf.shape, initial=1)
                         * jnp.dtype(leaf.dtype).itemsize)
            div = 1
            sharding = getattr(leaf, "sharding", None)
            if mesh is not None and sharding is not None \
                    and getattr(sharding, "spec", None) is not None:
                for entry in sharding.spec:
                    for ax in ((entry,) if isinstance(entry, str)
                               else (entry or ())):
                        div *= int(mesh.shape[ax])
            total += nbytes / div
    return int(total)
