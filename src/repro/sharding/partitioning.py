"""Weight/cache sharding rules: logical axis names → mesh PartitionSpecs.

Strategy (MaxText-class):
  * FSDP: weight `embed` dims shard over the `data` axis;
  * TP: heads / ffn / vocab / experts dims shard over the `model` axis;
  * KV caches shard batch over (`pod`,`data`) and head_dim over `model`
    (head_dim is divisible by 16 for every assigned arch; head COUNTS often
    are not — e.g. qwen2.5 has 2 kv heads);
  * the `pod` axis is pure DP for weights (gradients all-reduce across pods).

`make_shardings` checks divisibility per-dimension and falls back to
replication for any axis that does not divide — so one rule table serves all
ten architectures."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weight-side logical rules (activations: context.activation_rules)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "layer": (),
    "embed": ("data",),       # FSDP
    "heads": ("model",),      # fused H*hd dim
    "kv_heads": ("model",),   # fused Hkv*hd dim
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "inner": ("model",),      # ssm / xlstm d_inner
    "inner_fsdp": ("data",),  # input dim of square inner projections
    "embed_out": ("model",),  # output dim of square d→d projections
    "ssm_state": (),
    "mheads": ("model",),
    # cache / activation logical names that appear in cache axes trees
    "batch": ("pod", "data"),
    "kv_heads_c": (),
    "head_dim_c": ("model",),
}


def axes_for_dim(name: Optional[str], dim: Optional[int],
                 rules: Dict[str, Tuple[str, ...]],
                 mesh_names=None, mesh_sizes=None) -> Tuple[str, ...]:
    """Mesh axes for ONE logical dimension — the single divisibility /
    replicate-fallback rule shared by `logical_to_pspec` (weights) and
    `context.constrain` (activations), so the two paths cannot drift.

      * axes absent from `mesh_names` are dropped (no filter when None);
      * if `dim` is known and EVERY remaining axis has a known size, the
        full multi-axis product must divide `dim` — otherwise the whole
        dimension falls back to replicated (never a partial split);
      * if any axis size is unknown (mesh given as bare axis names),
        divisibility is unknowable and is not enforced.

    Returns the surviving mesh axes, possibly () (= replicate)."""
    axes = tuple(rules.get(name, ())) if name is not None else ()
    if mesh_names is not None:
        axes = tuple(a for a in axes if a in mesh_names)
    if not axes:
        return ()
    if dim is not None and mesh_sizes is not None \
            and all(a in mesh_sizes for a in axes):
        div = int(np.prod([mesh_sizes[a] for a in axes]))
        if div and dim % div != 0:
            return ()  # indivisible → replicate this dim
    return axes


def _spec_entry(axes: Tuple[str, ...]):
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def logical_to_pspec(logical: Tuple[Optional[str], ...], mesh: Mesh,
                     shape: Optional[Tuple[int, ...]] = None,
                     rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> P:
    rules = rules or LOGICAL_RULES
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for i, name in enumerate(logical):
        if name is None:
            spec.append(None)
            continue
        axes = axes_for_dim(name, None if shape is None else shape[i],
                            rules, mesh_names=names, mesh_sizes=sizes)
        spec.append(_spec_entry(axes))
    return P(*spec)


def make_shardings(mesh: Mesh, abstract: Any, axes_tree: Any,
                   rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Tree of NamedShardings matching `abstract` (ShapeDtypeStructs)."""

    def one(leaf, ax):
        if ax is None:
            ax = ()
        ax = tuple(ax) + (None,) * (len(leaf.shape) - len(ax))
        return NamedSharding(mesh, logical_to_pspec(ax[: len(leaf.shape)], mesh,
                                                    leaf.shape, rules))

    # abstract's treedef drives the map; axes_tree is flattened *up to* it, so
    # tuple-of-names leaves in axes_tree are passed whole.
    return jax.tree.map(one, abstract, axes_tree)
