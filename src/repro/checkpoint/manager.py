"""Mesh-agnostic checkpointing: atomic, async, elastic.

Layout: <dir>/step_<N>/
  manifest.json       — tree structure, shapes, dtypes, leaf→file map, extras
  <leaf_id>.npy       — one file per leaf (np.save; process-0 writes in this
                        single-process container; on a real fleet each host
                        writes its shards and the manifest records the grid)

Properties required at 1000-node scale and tested here:
  * atomicity: write to step_<N>.tmp, fsync, rename — a killed save never
    corrupts the latest checkpoint;
  * async: a background thread does the serialization (the train loop only
    blocks on the previous save);
  * elasticity: restore() takes target shardings built for ANY mesh — leaves
    are loaded full and device_put with the new sharding (reshard-on-load);
  * GC: keep-last-k.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items.append((key, leaf))
    return items, treedef


def save(directory: str, step: int, tree: Any,
         extras: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save. Returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    items, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extras": extras or {}}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _step_of(name: str) -> Optional[int]:
    """Parse a `step_<N>` directory name; None for anything else (torn
    `.tmp` leftovers, foreign files, non-integer suffixes). Discovery and GC
    must both survive junk in the checkpoint directory — a single stray
    `step_backup` dir must not take down `latest_step` with a ValueError."""
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        step = _step_of(name)
        if step is not None:
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(step)
    return max(steps) if steps else None


def valid_steps(directory: str):
    """All complete (manifest-bearing) step numbers in `directory`,
    descending — the fallback order elastic recovery walks when the newest
    snapshot turns out torn or corrupt."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        step = _step_of(name)
        if step is not None:
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(step)
    return sorted(steps, reverse=True)


def restore(directory: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of `like`. If `shardings` is given (a tree
    of NamedSharding built for the CURRENT mesh), leaves are device_put with
    it — elastic reshard-on-load."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    items, treedef = _flatten_with_paths(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    shard_items = (None,) * len(items) if shardings is None else (
        _flatten_with_paths(shardings)[0])

    leaves = []
    for i, (key, leaf) in enumerate(items):
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        # the manifest dtype is authoritative on BOTH paths (a drifted leaf
        # file used to restore uncast — silently wrong — under a mesh)
        arr = arr.astype(entry["dtype"], copy=False)
        if shardings is not None:
            leaves.append(jax.device_put(arr, shard_items[i][1]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def read_extras(directory: str, step: int) -> Dict[str, Any]:
    path = os.path.join(directory, f"step_{step:08d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)["extras"]


class CheckpointManager:
    """Async keep-last-k manager with crash-safe saves."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # steps a concurrent restore() is currently reading — _gc must never
        # delete the checkpoint under a reader, even with keep=1
        self._lock = threading.Lock()
        self._reading: set = set()
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any,
                   extras: Optional[Dict[str, Any]] = None):
        self.wait()
        # materialize on host BEFORE backgrounding (donated buffers may die)
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save(self.directory, step, host_tree, extras)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            s for s in (_step_of(n) for n in os.listdir(self.directory))
            if s is not None)
        with self._lock:
            protected = set(self._reading)
        for s in steps[: -self.keep]:
            if s in protected:
                continue
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        self.wait()
        step = latest_step(self.directory) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with self._lock:
            self._reading.add(step)
        try:
            return restore(self.directory, step, like, shardings)
        finally:
            with self._lock:
                self._reading.discard(step)
