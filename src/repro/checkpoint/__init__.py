from repro.checkpoint.manager import CheckpointManager, restore, save
