"""Mamba2 (SSD) block — chunked state-space dual form.

Per head (state N, head dim P):  h_t = exp(dt_t·A)·h_{t−1} + dt_t·x_t⊗B_t,
y_t = h_t·C_t + D·x_t. Training uses the chunkwise form (intra-chunk
quadratic + inter-chunk state passing — maps onto the MXU); decode carries
(conv window, ssd state) in O(1) per token. A sequential oracle validates
the chunked form (tests/test_ssm.py)."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (Params, dense_init, dtype_of, rmsnorm,
                                 split_keys)
from repro.sharding.context import bshard


def ssd_chunked(x, dt, A, B_mat, C_mat, chunk: int = 64, state=None):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; B_mat/C_mat: (B, S, N).
    Returns (y (B,S,H,P), state (B,H,P,N))."""
    b, s, nh, p = x.shape
    n = B_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_mat.astype(jnp.float32)
    Cf = C_mat.astype(jnp.float32)

    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))   # dt=0 ⇒ no decay, no input
    Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
    Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))

    def resh(z):
        return z.reshape(b, nc, chunk, *z.shape[2:]).transpose(
            1, 0, *range(2, z.ndim + 1))

    xc, dtc, Bc, Cc = map(resh, (xf, dtf, Bf, Cf))
    if state is None:
        state = jnp.zeros((b, nh, p, n), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(h_st, inp):
        xi, dti, Bi, Ci = inp                      # (B,T,H,P), (B,T,H), (B,T,N)
        ldec = dti * A                              # (B,T,H) log decay ≤ 0
        cum = jnp.cumsum(ldec, axis=1)
        # intra: w_ij = exp(cum_i − cum_j)·dt_j, j ≤ i
        lw = cum[:, :, None] - cum[:, None, :]      # (B,T_i,T_j,H)
        w = jnp.where(causal[None, :, :, None], jnp.exp(lw), 0.0) * dti[:, None]
        cb = jnp.einsum("bin,bjn->bij", Ci, Bi)     # (B,T_i,T_j)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, w, xi)
        # inter: y_i += exp(cum_i) · (h_st C_i)
        y_inter = jnp.einsum("bhpn,bin->bihp", h_st, Ci) * jnp.exp(cum)[..., None]
        # state update
        tot = cum[:, -1]                            # (B,H)
        dec_j = jnp.exp(tot[:, None] - cum) * dti   # (B,T,H)
        h_new = (h_st * jnp.exp(tot)[..., None, None]
                 + jnp.einsum("bjh,bjhp,bjn->bhpn", dec_j, xi, Bi))
        return h_new, y_intra + y_inter

    state, ys = jax.lax.scan(body, state, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, nh, p)
    return y[:, :s], state


def ssd_step(state, x, dt, A, B_vec, C_vec):
    """One-token step. x: (B,H,P); dt: (B,H); B_vec/C_vec: (B,N)."""
    xf = x.astype(jnp.float32)
    dec = jnp.exp(dt * A)                           # (B,H)
    state = (state * dec[..., None, None]
             + dt[..., None, None] * (xf[..., :, None] * B_vec[:, None, None, :]))
    y = jnp.einsum("bhpn,bn->bhp", state, C_vec)
    return state, y


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B, S, C); w: (K, C). Returns (y, new_state
    (B, K−1, C))."""
    k = w.shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(y), new_state


def block_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    nh = di // cfg.mamba_headdim
    conv_ch = di + 2 * n
    k1, k2, k3 = split_keys(key, 3)
    p = {
        "norm": jnp.ones((d,), dtype),
        "in_proj": dense_init(k1, (d, 2 * di + 2 * n + nh), dtype),
        "conv_w": (jax.random.normal(k2, (cfg.mamba_conv, conv_ch), jnp.float32)
                   * 0.2).astype(jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),     # A = −exp(A_log) ∈ (−∞,0)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(k3, (di, d), dtype),
    }
    ax = {
        "norm": ("embed",), "in_proj": ("embed", "inner"), "conv_w": (None, "inner"),
        "A_log": ("mheads",), "D": ("mheads",), "dt_bias": ("mheads",),
        "gate_norm": ("inner",), "out_proj": ("inner", "embed"),
    }
    return p, ax


def _split_proj(proj, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    nh = di // cfg.mamba_headdim
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt, di, n, nh


def apply(x, p, cfg: ModelConfig, chunk: int = 64):
    """Training/prefill form. x: (B, S, D) → (B, S, D), state."""
    b, s, d = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xbc, dt_raw, di, n, nh = _split_proj(proj, cfg)
    xbc, _ = _causal_conv(xbc, p["conv_w"])
    xs = xbc[..., :di].reshape(b, s, nh, cfg.mamba_headdim)
    B_mat = xbc[..., di:di + n]
    C_mat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(xs, dt, A, B_mat, C_mat, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return bshard(x + out), state


def make_state(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.ssm_state
    nh = di // cfg.mamba_headdim
    return {
        "ssd": jnp.zeros((batch, nh, cfg.mamba_headdim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_conv - 1, di + 2 * n), jnp.float32),
    }


def state_axes() -> Params:
    return {"ssd": ("batch", "mheads", None, None),
            "conv": ("batch", None, "inner")}


def apply_prefill(x, p, cfg: ModelConfig, chunk: int = 64):
    """Like `apply` but also returns the decode-ready state dict."""
    b, s, d = x.shape
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xbc, dt_raw, di, n, nh = _split_proj(proj, cfg)
    conv_in = xbc
    xbc, conv_state = _causal_conv(xbc, p["conv_w"])
    xs = xbc[..., :di].reshape(b, s, nh, cfg.mamba_headdim)
    B_mat = xbc[..., di:di + n]
    C_mat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssd_state = ssd_chunked(xs, dt, A, B_mat, C_mat, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return bshard(x + out), {"ssd": ssd_state, "conv": conv_state.astype(jnp.float32)}


def apply_decode(x, p, st, cfg: ModelConfig):
    """One-token step. x: (B, 1, D)."""
    b = x.shape[0]
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    z, xbc, dt_raw, di, n, nh = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state=st["conv"])
    xs = xbc[:, 0, :di].reshape(b, nh, cfg.mamba_headdim)
    B_vec = xbc[:, 0, di:di + n]
    C_vec = xbc[:, 0, di + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    ssd_state, y = ssd_step(st["ssd"], xs, dt, A, B_vec, C_vec)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + out, {"ssd": ssd_state, "conv": conv_state.astype(jnp.float32)}
