"""Zamba2 hybrid (arXiv:2411.15242): Mamba2 backbone + one SHARED
attention+MLP block applied every `attn_every` Mamba blocks.

The shared block's weights are a single param set reused at every
application site (Zamba's parameter-efficiency trick); its input is the
concat of the current hidden state with the original embedding output,
fused by a 2D→D projection. Each application site keeps its OWN KV cache
(weights shared, state not)."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import mamba2
from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.sharding.context import bshard
from repro.models.layers import (Params, apply_rope, attn_params, dense_init,
                                 dtype_of, embed_init, mlp_params, qkv, rmsnorm,
                                 split_keys, stack_params, stacked_axes)


def _n_groups(cfg: ModelConfig) -> int:
    k = cfg.attn_every or cfg.n_layers
    assert cfg.n_layers % k == 0, "zamba: n_layers must divide by attn_every"
    return cfg.n_layers // k


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    dtype = dtype_of(cfg.dtype)
    k = cfg.attn_every or cfg.n_layers
    ng = _n_groups(cfg)
    keys = split_keys(key, 6 + cfg.n_layers)
    vp = cfg.vocab_padded

    blocks, bax = [], None
    for i in range(cfg.n_layers):
        p, bax = mamba2.block_init(keys[6 + i], cfg, dtype)
        blocks.append(p)
    # stack as (ng, k) macro groups
    grouped = [dict((f"m{j}", blocks[g * k + j]) for j in range(k))
               for g in range(ng)]

    ap, aax = attn_params(keys[1], cfg, dtype)
    mp, max_ = mlp_params(keys[2], cfg.d_model, cfg.d_ff, dtype)
    params = {
        "embed": embed_init(keys[0], (vp, cfg.d_model), dtype),
        "unembed": dense_init(keys[3], (cfg.d_model, vp), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "groups": stack_params(grouped),
        "shared": {
            "fuse": dense_init(keys[4], (2 * cfg.d_model, cfg.d_model), dtype),
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": ap,
            "mlp": mp,
            "out": dense_init(keys[5], (cfg.d_model, cfg.d_model), dtype),
        },
    }
    axes = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
        "groups": {f"m{j}": stacked_axes(bax) for j in range(k)},
        "shared": {
            "fuse": ("embed", "embed_out"),
            "attn_norm": ("embed",), "mlp_norm": ("embed",),
            "attn": aax, "mlp": max_, "out": ("embed", "embed_out"),
        },
    }
    return params, axes


def _shared_apply(x, x0, sp, cfg: ModelConfig, positions, kv_chunk):
    h = jnp.concatenate([x, x0], axis=-1)
    h = jnp.einsum("bsd,de->bse", h, sp["fuse"])
    a = rmsnorm(h, sp["attn_norm"], cfg.norm_eps)
    q, kk, vv = qkv(a, sp["attn"], cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    kk = apply_rope(kk, positions, cfg.rope_theta)
    o = attention(q, kk, vv, causal=True, kv_chunk=kv_chunk)
    h = h + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                       sp["attn"]["wo"])
    m = rmsnorm(h, sp["mlp_norm"], cfg.norm_eps)
    from repro.models.layers import swiglu
    h = h + swiglu(m, **sp["mlp"])
    return bshard(x + jnp.einsum("bsd,de->bse", h, sp["out"])), (kk, vv)


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            kv_chunk: int = 1024, chunk: int = 64) -> jax.Array:
    k = cfg.attn_every or cfg.n_layers
    x = jnp.take(params["embed"], tokens, axis=0)
    x0 = x
    positions = jnp.arange(tokens.shape[1])

    def body(xc, gp):
        for j in range(k):
            xc, _ = mamba2.apply(xc, gp[f"m{j}"], cfg, chunk=chunk)
        xc, _ = _shared_apply(xc, x0, params["shared"], cfg, positions, kv_chunk)
        return xc, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["groups"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
         kv_chunk: int = 1024) -> jax.Array:
    x = forward(params, batch["tokens"], cfg, kv_chunk)
    from repro.models.layers import chunked_ce
    return chunked_ce(x, params["unembed"], batch["targets"])


# -- serving -----------------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    k = cfg.attn_every or cfg.n_layers
    ng = _n_groups(cfg)
    dtype = dtype_of(cfg.dtype)
    st = mamba2.make_state(cfg, batch)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "mamba": {f"m{j}": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (ng,) + a.shape), st)
            for j in range(k)},
        "attn_k": jnp.zeros((ng, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
        "attn_v": jnp.zeros((ng, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    k = cfg.attn_every or cfg.n_layers
    st_ax = jax.tree.map(lambda t: ("layer",) + t, mamba2.state_axes(),
                         is_leaf=lambda t: isinstance(t, tuple))
    t = ("layer", "batch", None, "kv_heads_c", "head_dim_c")
    return {"pos": (), "mamba": {f"m{j}": st_ax for j in range(k)},
            "attn_k": t, "attn_v": t}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            kv_chunk: int = 1024, max_len: int = 0, chunk: int = 64):
    k = cfg.attn_every or cfg.n_layers
    tokens = batch["tokens"]
    b, s = tokens.shape
    ml = max(max_len, s)
    x = jnp.take(params["embed"], tokens, axis=0)
    x0 = x
    positions = jnp.arange(s)

    def body(xc, gp):
        sts = {}
        for j in range(k):
            xc, st = mamba2.apply_prefill(xc, gp[f"m{j}"], cfg, chunk=chunk)
            sts[f"m{j}"] = st
        xc, (kk, vv) = _shared_apply(xc, x0, params["shared"], cfg, positions,
                                     kv_chunk)
        kk = jnp.pad(kk, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        return xc, (sts, kk, vv)

    x, (msts, ks, vs) = jax.lax.scan(body, x, params["groups"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]).astype(jnp.float32)
    cache = {"pos": jnp.asarray(s, jnp.int32), "mamba": msts,
             "attn_k": ks, "attn_v": vs}
    return logits, cache


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig, kv_chunk: int = 2048):
    k = cfg.attn_every or cfg.n_layers
    tok = batch["token"]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    x0 = x
    b = x.shape[0]
    s_cache = cache["attn_k"].shape[2]
    slot = jnp.minimum(pos, s_cache - 1)
    sp = params["shared"]

    def body(xc, scanned):
        gp, gst, ck, cv = scanned
        sts = {}
        for j in range(k):
            xc, sts[f"m{j}"] = mamba2.apply_decode(xc, gp[f"m{j}"],
                                                   gst[f"m{j}"], cfg)
        h = jnp.concatenate([xc, x0], axis=-1)
        h = jnp.einsum("bsd,de->bse", h, sp["fuse"])
        a = rmsnorm(h, sp["attn_norm"], cfg.norm_eps)
        q, kk, vv = qkv(a, sp["attn"], cfg)
        q = apply_rope(q, pos[None], cfg.rope_theta)
        kk = apply_rope(kk, pos[None], cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kk, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vv, slot, axis=1)
        o = attention(q, ck, cv, causal=False,
                      kv_valid_len=jnp.minimum(pos + 1, s_cache),
                      kv_chunk=kv_chunk)
        h = h + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1), sp["attn"]["wo"])
        m = rmsnorm(h, sp["mlp_norm"], cfg.norm_eps)
        from repro.models.layers import swiglu
        h = h + swiglu(m, **sp["mlp"])
        xc = xc + jnp.einsum("bsd,de->bse", h, sp["out"])
        return xc, (sts, ck, cv)

    x, (msts, ks, vs) = jax.lax.scan(
        body, x, (params["groups"], cache["mamba"], cache["attn_k"],
                  cache["attn_v"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"]).astype(jnp.float32)
    return logits, {"pos": pos + 1, "mamba": msts, "attn_k": ks, "attn_v": vs}
