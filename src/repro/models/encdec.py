"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech frontend is a STUB per the assignment: `input_specs()` provides
precomputed audio-frame embeddings (B, S//4, 1280); a learned linear
projector maps them to d_model. Encoder = bidirectional self-attention;
decoder = causal self-attention + cross-attention to the encoder output.
Decode serving caches both the self KV and the (computed-once) cross KV."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.sharding.context import bshard
from repro.models.layers import (Params, apply_rope, attn_params, dense_init,
                                 dtype_of, embed_init, mlp_params, qkv, rmsnorm,
                                 split_keys, stack_params, stacked_axes, swiglu)

AUDIO_FEAT = 1280


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = split_keys(key, 2)
    ap, aax = attn_params(k1, cfg, dtype)
    mp, max_ = mlp_params(k2, cfg.d_model, cfg.d_ff, dtype)
    p = {"attn_norm": jnp.ones((cfg.d_model,), dtype),
         "mlp_norm": jnp.ones((cfg.d_model,), dtype), "attn": ap, "mlp": mp}
    ax = {"attn_norm": ("embed",), "mlp_norm": ("embed",), "attn": aax,
          "mlp": max_}
    return p, ax


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = split_keys(key, 3)
    ap, aax = attn_params(k1, cfg, dtype)
    cp, cax = attn_params(k2, cfg, dtype, cross=True)
    mp, max_ = mlp_params(k3, cfg.d_model, cfg.d_ff, dtype)
    p = {"attn_norm": jnp.ones((cfg.d_model,), dtype),
         "cross_norm": jnp.ones((cfg.d_model,), dtype),
         "mlp_norm": jnp.ones((cfg.d_model,), dtype),
         "attn": ap, "cross": cp, "mlp": mp}
    ax = {"attn_norm": ("embed",), "cross_norm": ("embed",),
          "mlp_norm": ("embed",), "attn": aax, "cross": cax, "mlp": max_}
    return p, ax


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    dtype = dtype_of(cfg.dtype)
    keys = split_keys(key, 4 + cfg.n_enc_layers + cfg.n_layers)
    vp = cfg.vocab_padded
    enc, eax = [], None
    for i in range(cfg.n_enc_layers):
        p, eax = _enc_layer_init(keys[4 + i], cfg, dtype)
        enc.append(p)
    dec, dax = [], None
    for i in range(cfg.n_layers):
        p, dax = _dec_layer_init(keys[4 + cfg.n_enc_layers + i], cfg, dtype)
        dec.append(p)
    params = {
        "audio_proj": dense_init(keys[0], (AUDIO_FEAT, cfg.d_model), dtype),
        "embed": embed_init(keys[1], (vp, cfg.d_model), dtype),
        "unembed": dense_init(keys[2], (cfg.d_model, vp), dtype),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "enc_layers": stack_params(enc),
        "dec_layers": stack_params(dec),
    }
    axes = {
        "audio_proj": (None, "embed"),
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "enc_norm": ("embed",),
        "final_norm": ("embed",),
        "enc_layers": stacked_axes(eax),
        "dec_layers": stacked_axes(dax),
    }
    return params, axes


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           kv_chunk: int = 1024) -> jax.Array:
    x = jnp.einsum("bsa,ad->bsd", frames.astype(dtype_of(cfg.dtype)),
                   params["audio_proj"])
    positions = jnp.arange(x.shape[1])

    def body(xc, lp):
        h = rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = qkv(h, lp["attn"], cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, causal=False, kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                             lp["attn"]["wo"])
        h = rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        return bshard(xc + swiglu(h, **lp["mlp"])), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(x, lp, enc_out, cfg, positions, kv_chunk):
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = qkv(h, lp["attn"], cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, causal=True, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                       lp["attn"]["wo"])
    h = rmsnorm(x, lp["cross_norm"], cfg.norm_eps)
    qc = jnp.einsum("bsd,dh->bsh", h, lp["cross"]["wq"]).reshape(
        *h.shape[:2], cfg.n_heads, cfg.hd)
    kc = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wk"]).reshape(
        enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
    vc = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wv"]).reshape(
        enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
    oc = attention(qc, kc, vc, causal=False, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bsh,hd->bsd", oc.reshape(*oc.shape[:2], -1),
                       lp["cross"]["wo"])
    h = rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return bshard(x + swiglu(h, **lp["mlp"]))


def loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
         kv_chunk: int = 1024) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg, kv_chunk)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(tokens.shape[1])

    def body(xc, lp):
        return _dec_block(xc, lp, enc_out, cfg, positions, kv_chunk), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    from repro.models.layers import chunked_ce
    return chunked_ce(x, params["unembed"], batch["targets"])


# -- serving -------------------------------------------------------------------


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            kv_chunk: int = 1024, max_len: int = 0):
    """Encoder pass + decoder prefill. Caches: self KV (padded to max_len) and
    cross KV computed once from the encoder output."""
    enc_out = encode(params, batch["frames"], cfg, kv_chunk)
    tokens = batch["tokens"]
    b, s = tokens.shape
    ml = max(max_len, s)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)

    def body(xc, lp):
        h = rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = qkv(h, lp["attn"], cfg)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, causal=True, kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), lp["attn"]["wo"])
        h = rmsnorm(xc, lp["cross_norm"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dh->bsh", h, lp["cross"]["wq"]).reshape(
            b, s, cfg.n_heads, cfg.hd)
        kc = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wk"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        vc = jnp.einsum("bsd,dh->bsh", enc_out, lp["cross"]["wv"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        oc = attention(qc, kc, vc, causal=False, kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", oc.reshape(b, s, -1),
                             lp["cross"]["wo"])
        h = rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = bshard(xc + swiglu(h, **lp["mlp"]))
        k = jnp.pad(k, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        return xc, {"k": k, "v": v, "ck": kc, "cv": vc}

    x, kvs = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]).astype(jnp.float32)
    return logits, {"pos": jnp.asarray(s, jnp.int32), **kvs}


def make_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    dtype = dtype_of(cfg.dtype)
    hkv, hd, nl = cfg.n_kv_heads, cfg.hd, cfg.n_layers
    s_audio = max(seq // cfg.audio_downsample, 1)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((nl, batch, seq, hkv, hd), dtype),
        "v": jnp.zeros((nl, batch, seq, hkv, hd), dtype),
        "ck": jnp.zeros((nl, batch, s_audio, hkv, hd), dtype),
        "cv": jnp.zeros((nl, batch, s_audio, hkv, hd), dtype),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    t = ("layer", "batch", None, "kv_heads_c", "head_dim_c")
    return {"pos": (), "k": t, "v": t, "ck": t, "cv": t}


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig, kv_chunk: int = 2048):
    tok = batch["token"]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    b = x.shape[0]
    s_cache = cache["k"].shape[2]
    slot = jnp.minimum(pos, s_cache - 1)

    def body(xc, scanned):
        lp, ck, cv, xk, xv = scanned
        h = rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, k, v = qkv(h, lp["attn"], cfg)
        q = apply_rope(q, pos[None], cfg.rope_theta)
        k = apply_rope(k, pos[None], cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=1)
        o = attention(q, ck, cv, causal=False,
                      kv_valid_len=jnp.minimum(pos + 1, s_cache),
                      kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1), lp["attn"]["wo"])
        h = rmsnorm(xc, lp["cross_norm"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dh->bsh", h, lp["cross"]["wq"]).reshape(
            b, 1, cfg.n_heads, cfg.hd)
        oc = attention(qc, xk, xv, causal=False, kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", oc.reshape(b, 1, -1),
                             lp["cross"]["wo"])
        h = rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        xc = bshard(xc + swiglu(h, **lp["mlp"]))
        return xc, {"k": ck, "v": cv}

    x, kvs = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                    cache["v"], cache["ck"], cache["cv"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"]).astype(jnp.float32)
    return logits, {"pos": pos + 1, "k": kvs["k"], "v": kvs["v"],
                    "ck": cache["ck"], "cv": cache["cv"]}
