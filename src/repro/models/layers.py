"""Shared layer primitives: RMSNorm, RoPE, SwiGLU, initializers, logical axes.

Params are plain nested dicts of jnp arrays; every param tree has a parallel
"logical axes" tree (tuples of logical axis names) consumed by
repro.sharding.partitioning to build NamedShardings. Layer stacks carry a
leading "layer" axis and are scanned (compact HLO — essential for the 512-
device dry-run compile times)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# -- init helpers -------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = -2):
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# -- norms ---------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# -- rope ----------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, rotary_pct: float = 1.0):
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    inv, rot_dim = rope_freqs(hd, theta, rotary_pct)
    if rot_dim == 0:
        return x
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv  # (S, rd/2) or (B, S, rd/2)
    if ang.ndim == 2:
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape[:-1] + (rot_dim,))
    return jnp.concatenate([out.astype(x.dtype), x[..., rot_dim:]], axis=-1)


# -- mlp -----------------------------------------------------------------------


def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def mlp_params(key, d: int, f: int, dtype) -> Tuple[Params, Params]:
    k1, k2, k3 = split_keys(key, 3)
    p = {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }
    ax = {
        "w_gate": ("embed", "ffn"),
        "w_up": ("embed", "ffn"),
        "w_down": ("ffn", "embed"),
    }
    return p, ax


# -- attention projections -------------------------------------------------------


def attn_params(key, cfg, dtype, cross: bool = False) -> Tuple[Params, Params]:
    d, hd = cfg.d_model, cfg.hd
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = split_keys(key, 4)
    p = {
        "wq": dense_init(k1, (d, h * hd), dtype),
        "wk": dense_init(k2, (d, hkv * hd), dtype),
        "wv": dense_init(k3, (d, hkv * hd), dtype),
        "wo": dense_init(k4, (h * hd, d), dtype),
    }
    ax = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
        ax["bq"] = ("heads",)
        ax["bk"] = ("kv_heads",)
        ax["bv"] = ("kv_heads",)
    return p, ax


def qkv(x: jax.Array, p: Params, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    b, s = x.shape[:2]
    return (q.reshape(b, s, h, hd), k.reshape(b, s, hkv, hd),
            v.reshape(b, s, hkv, hd))


# -- loss ------------------------------------------------------------------------


def chunked_ce(x: jax.Array, unembed: jax.Array, targets: jax.Array,
               seq_chunk: int = 256) -> jax.Array:
    """Cross-entropy without materializing the full (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside a
    remat'd body (backward recomputes them) — peak activation drops from
    B·S·V to B·seq_chunk·V. §Perf lever for huge-vocab archs (gemma3 262k)."""
    b, s, d = x.shape
    seq_chunk = min(seq_chunk, s)
    if s % seq_chunk != 0:
        logits = jnp.einsum("bsd,dv->bsv", x, unembed).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0].mean()
    nc = s // seq_chunk
    xc = x.reshape(b, nc, seq_chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, nc, seq_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        xi, ti = inp
        logits = jnp.einsum("bsd,dv->bsv", xi, unembed).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        return acc + (lse - tgt).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, tc))
    return total / (b * s)


# -- stacking (scan over layers) ----------------------------------------------


def stack_params(per_layer: list) -> Params:
    """List of identical-structure param trees → single tree with leading L."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def stacked_axes(ax: Params) -> Params:
    return jax.tree.map(lambda t: ("layer",) + t, ax,
                        is_leaf=lambda t: isinstance(t, tuple))
