"""xLSTM (sLSTM + mLSTM blocks) — arXiv:2405.04517.

mLSTM is a matrix-memory linear-attention recurrence with exponential input
gating and a running stabilizer m_t:

  m_t = max(log f_t + m_{t-1}, log i_t)
  C_t = exp(log f_t + m_{t-1} − m_t)·C_{t-1} + exp(log i_t − m_t)·k_t v_tᵀ
  n_t = (same decays on n)                 h_t = (q̂_t·C_t) / max(|q̂_t·n_t|, e^{−m_t})

Training uses the *chunkwise-parallel* form (intra-chunk quadratic + carried
(C, n, m) state — the standard way these models map onto matrix units);
decode uses the O(1) recurrent step. A sequential-scan oracle validates the
chunked form (tests/test_ssm.py).

Block pattern: `slstm_every` gives one sLSTM block per group (e.g. 6 mLSTM +
1 sLSTM), mirroring the dense family's pattern-scan. sLSTM is inherently
sequential (scalar memory with recurrent weights) and runs as a time scan."""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.context import bshard, constrain
from repro.models.layers import (Params, dense_init, dtype_of, embed_init,
                                 rmsnorm, split_keys, stack_params,
                                 stacked_axes)


# -- mLSTM core ------------------------------------------------------------------


def mlstm_chunked(q, k, v, log_f, log_i, chunk: int = 64,
                  state: Tuple = None):
    """q,k,v: (B, S, H, Dh); log_f, log_i: (B, S, H). Returns (h, state).

    state = (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H))."""
    b, s, nh, dh = q.shape
    q = q.astype(jnp.float32) / (dh ** 0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    log_f = log_f.astype(jnp.float32)
    log_i = log_i.astype(jnp.float32)

    chunk = min(chunk, s)
    nc = -(-s // chunk)
    pad = nc * chunk - s

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))

    # padding: log_f = 0 (no decay), log_i = -inf (no input) keeps state exact
    qp, kp, vp = pad_t(q), pad_t(k), pad_t(v)
    lfp = pad_t(log_f)
    lip = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)

    def resh(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1))

    qc, kc, vc, lfc, lic = map(resh, (qp, kp, vp, lfp, lip))
    # shapes: (nc, B, T, H, ...)

    if state is None:
        state = (jnp.zeros((b, nh, dh, dh), jnp.float32),
                 jnp.zeros((b, nh, dh), jnp.float32),
                 jnp.full((b, nh), -1e30, jnp.float32))

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        c_st, n_st, m_st = carry
        qi, ki, vi, lf, li = inp                     # (B, T, H, ...)
        bcum = jnp.cumsum(lf, axis=1)                # b_j inclusive
        btot = bcum[:, -1]                           # (B, H)

        # m_intra_i = b_i + prefix-max_j≤i (li_j − b_j)
        g = li - bcum
        gmax = jax.lax.cummax(g, axis=1)
        m_intra = bcum + gmax
        m_i = jnp.maximum(m_st[:, None] + bcum, m_intra)   # (B, T, H)

        # intra-chunk weights: exp(b_i − b_j + li_j − m_i), j ≤ i
        lw = (bcum[:, :, None] - bcum[:, None, :] + li[:, None, :]
              - m_i[:, :, None])                     # (B, T_i, T_j, H)
        w = jnp.where(causal[None, :, :, None], jnp.exp(lw), 0.0)

        score = jnp.einsum("bihd,bjhd->bijh", qi, ki)
        num_intra = jnp.einsum("bijh,bijh,bjhd->bihd", score, w, vi)
        den_intra = jnp.einsum("bijh,bjhd,bihd->bih", w, ki, qi)

        s_inter = jnp.exp(m_st[:, None] + bcum - m_i)      # (B, T, H)
        num_inter = jnp.einsum("bihd,bhde->bihe", qi, c_st) * s_inter[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qi, n_st) * s_inter

        num = num_intra + num_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # state update (= values at i = T)
        m_new = m_i[:, -1]
        dec_j = jnp.exp(btot[:, None] - bcum + li - m_new[:, None])  # (B, T, H)
        c_new = (c_st * jnp.exp(m_st + btot - m_new)[..., None, None]
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", dec_j, ki, vi))
        n_new = (n_st * jnp.exp(m_st + btot - m_new)[..., None]
                 + jnp.einsum("bjh,bjhd->bhd", dec_j, ki))
        return (c_new, n_new, m_new), h

    state, hs = jax.lax.scan(body, state, (qc, kc, vc, lfc, lic))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, nh, dh)
    return h[:, :s], state


def mlstm_recurrent_step(state, q, k, v, log_f, log_i):
    """One-token step. q,k,v: (B, H, Dh); gates: (B, H). Oracle + decode."""
    c_st, n_st, m_st = state
    dh = q.shape[-1]
    q = q.astype(jnp.float32) / (dh ** 0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m_st, log_i)
    df = jnp.exp(log_f + m_st - m_new)
    di = jnp.exp(log_i - m_new)
    c_new = df[..., None, None] * c_st + di[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n_new = df[..., None] * n_st + di[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
                      jnp.exp(-m_new))
    return (c_new, n_new, m_new), num / den[..., None]


# -- sLSTM core (sequential, scalar memory with exponential gating) -----------------


def slstm_scan(x_gates, r_weights, state=None):
    """x_gates: (B, S, H, 4, Dh) input preactivations (i, f, z, o);
    r_weights: (H, 4, Dh, Dh) recurrent block-diagonal weights.
    Returns (h (B,S,H,Dh), state)."""
    b, s, nh, _, dh = x_gates.shape
    if state is None:
        state = (jnp.zeros((b, nh, dh), jnp.float32),  # c
                 jnp.zeros((b, nh, dh), jnp.float32),  # n
                 jnp.zeros((b, nh, dh), jnp.float32),  # h
                 jnp.zeros((b, nh, dh), jnp.float32))  # m

    def step(carry, xt):
        c, n, h, m = carry
        rec = jnp.einsum("bhd,hgde->bhge", h, r_weights)
        pre = xt.astype(jnp.float32) + rec
        i_t = pre[:, :, 0]
        f_t = pre[:, :, 1]
        z_t = jnp.tanh(pre[:, :, 2])
        o_t = jax.nn.sigmoid(pre[:, :, 3])
        m_new = jnp.maximum(f_t + m, i_t)           # log-space stabilizer
        ig = jnp.exp(i_t - m_new)
        fg = jnp.exp(f_t + m - m_new)
        c = fg * c + ig * z_t
        n = fg * n + ig
        h = o_t * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    state, hs = jax.lax.scan(step, state, x_gates.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), state


# -- blocks ---------------------------------------------------------------------


def _mlstm_block_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    nh = cfg.n_heads
    k1, k2, k3, k4, k5, k6, k7 = split_keys(key, 7)
    p = {
        "norm": jnp.ones((d,), dtype),
        "w_up": dense_init(k1, (d, di), dtype),
        "w_z": dense_init(k2, (d, di), dtype),
        "wq": dense_init(k3, (di, di), dtype),
        "wk": dense_init(k4, (di, di), dtype),
        "wv": dense_init(k5, (di, di), dtype),
        "w_gates": dense_init(k6, (d, 2 * nh), dtype),
        "head_norm": jnp.ones((di,), dtype),
        "w_down": dense_init(k7, (di, d), dtype),
    }
    ax = {
        "norm": ("embed",), "w_up": ("embed", "inner"), "w_z": ("embed", "inner"),
        "wq": ("inner_fsdp", "inner"), "wk": ("inner_fsdp", "inner"),
        "wv": ("inner_fsdp", "inner"),
        "w_gates": ("embed", None), "head_norm": ("inner",),
        "w_down": ("inner", "embed"),
    }
    return p, ax


def _slstm_block_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    k1, k2, k3 = split_keys(key, 3)
    p = {
        "norm": jnp.ones((d,), dtype),
        "w_in": dense_init(k1, (d, nh * 4 * dh), dtype),
        "r": dense_init(k2, (nh, 4, dh, dh), jnp.float32),
        "w_out": dense_init(k3, (d, d), dtype),
    }
    ax = {"norm": ("embed",), "w_in": ("embed", "inner"),
          "r": ("mheads", None, None, None), "w_out": ("embed", "embed_out")}
    return p, ax


def _mlstm_apply(x, p, cfg: ModelConfig, chunk: int, state=None):
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    nh = cfg.n_heads
    dh = di // nh
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", h, p["w_up"])
    z = jnp.einsum("bsd,de->bse", h, p["w_z"])
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(b, s, nh, dh)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(b, s, nh, dh)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(b, s, nh, dh)
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_gates"]).astype(jnp.float32)
    log_i = gates[..., :nh]
    log_f = -jax.nn.softplus(-gates[..., nh:])      # log σ(f̃)
    o, new_state = mlstm_chunked(q, k, v, log_f, log_i, chunk=chunk, state=state)
    o = o.reshape(b, s, di).astype(x.dtype)
    o = rmsnorm(o, p["head_norm"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", o * jax.nn.silu(z), p["w_down"])
    # batch-only boundary: mLSTM's chunk reshape fights seq-parallel sharding
    return constrain(x + y, ("batch", None, None)), new_state


def _slstm_apply(x, p, cfg: ModelConfig, state=None):
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_in"]).reshape(b, s, nh, 4, dh)
    o, new_state = slstm_scan(gates, p["r"], state=state)
    y = jnp.einsum("bsd,de->bse", o.reshape(b, s, d).astype(x.dtype),
                   p["w_out"])
    return constrain(x + y, ("batch", None, None)), new_state


# -- full model -------------------------------------------------------------------


def _pattern(cfg: ModelConfig):
    if cfg.slstm_every > 0:
        pat = ("m",) * (cfg.slstm_every - 1) + ("s",)
    else:
        pat = ("m",)
    n_groups = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_groups * len(pat)
    return pat, n_groups, ("m",) * rem


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    dtype = dtype_of(cfg.dtype)
    pat, n_groups, rem = _pattern(cfg)
    keys = split_keys(key, 3 + cfg.n_layers)
    vp = cfg.vocab_padded
    params = {
        "embed": embed_init(keys[0], (vp, cfg.d_model), dtype),
        "unembed": dense_init(keys[1], (cfg.d_model, vp), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
    }
    ki = iter(keys[3:])
    if n_groups:
        groups = []
        gax = {}
        for _ in range(n_groups):
            subs = {}
            for si, kind in enumerate(pat):
                fn = _mlstm_block_init if kind == "m" else _slstm_block_init
                p, ax = fn(next(ki), cfg, dtype)
                subs[f"sub{si}"] = p
                gax[f"sub{si}"] = stacked_axes(ax)
            groups.append(subs)
        params["groups"] = stack_params(groups)
        axes["groups"] = gax
    for ri in range(len(rem)):
        p, ax = _mlstm_block_init(next(ki), cfg, dtype)
        params[f"rem{ri}"] = p
        axes[f"rem{ri}"] = ax
    return params, axes


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            chunk: int = 64) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    pat, n_groups, rem = _pattern(cfg)

    if n_groups:
        def body(xc, gp):
            for si, kind in enumerate(pat):
                if kind == "m":
                    xc, _ = _mlstm_apply(xc, gp[f"sub{si}"], cfg, chunk)
                else:
                    xc, _ = _slstm_apply(xc, gp[f"sub{si}"], cfg)
            return xc, None

        if cfg.remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["groups"])
    for ri in range(len(rem)):
        x, _ = _mlstm_apply(x, params[f"rem{ri}"], cfg, chunk)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
         kv_chunk: int = 1024) -> jax.Array:
    x = forward(params, batch["tokens"], cfg)
    from repro.models.layers import chunked_ce
    return chunked_ce(x, params["unembed"], batch["targets"])


# -- serving: recurrent state cache (O(1) per token — long_500k native) -------------


def make_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    del seq  # state size is sequence-independent (the SSM advantage)
    pat, n_groups, rem = _pattern(cfg)
    d = cfg.d_model
    di = cfg.mamba_expand * d
    nh = cfg.n_heads
    dh_m = di // nh
    dh_s = d // nh

    def m_state():
        return {"c": jnp.zeros((batch, nh, dh_m, dh_m), jnp.float32),
                "n": jnp.zeros((batch, nh, dh_m), jnp.float32),
                "m": jnp.full((batch, nh), -1e30, jnp.float32)}

    def s_state():
        return {"c": jnp.zeros((batch, nh, dh_s), jnp.float32),
                "n": jnp.zeros((batch, nh, dh_s), jnp.float32),
                "h": jnp.zeros((batch, nh, dh_s), jnp.float32),
                "m": jnp.zeros((batch, nh, dh_s), jnp.float32)}

    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if n_groups:
        cache["groups"] = {
            f"sub{si}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape),
                m_state() if kind == "m" else s_state())
            for si, kind in enumerate(pat)}
    for ri in range(len(rem)):
        cache[f"rem{ri}"] = m_state()
    return cache


def cache_axes(cfg: ModelConfig) -> Params:
    pat, n_groups, rem = _pattern(cfg)
    m_ax = {"c": ("batch", "mheads", None, None), "n": ("batch", "mheads", None),
            "m": ("batch", "mheads")}
    s_ax = {"c": ("batch", "mheads", None), "n": ("batch", "mheads", None),
            "h": ("batch", "mheads", None), "m": ("batch", "mheads", None)}
    ax: Params = {"pos": ()}
    if n_groups:
        ax["groups"] = {
            f"sub{si}": jax.tree.map(lambda t: ("layer",) + t,
                                     m_ax if kind == "m" else s_ax,
                                     is_leaf=lambda t: isinstance(t, tuple))
            for si, kind in enumerate(pat)}
    for ri in range(len(rem)):
        ax[f"rem{ri}"] = m_ax
    return ax


def _mlstm_decode(x, p, st, cfg: ModelConfig):
    b = x.shape[0]
    d = cfg.d_model
    di = cfg.mamba_expand * d
    nh = cfg.n_heads
    dh = di // nh
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", h, p["w_up"])
    z = jnp.einsum("bsd,de->bse", h, p["w_z"])
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(b, nh, dh)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(b, nh, dh)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(b, nh, dh)
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_gates"]).astype(jnp.float32)[:, 0]
    log_i = gates[..., :nh]
    log_f = -jax.nn.softplus(-gates[..., nh:])
    state = (st["c"], st["n"], st["m"])
    state, o = mlstm_recurrent_step(state, q, k, v, log_f, log_i)
    o = o.reshape(b, 1, di).astype(x.dtype)
    o = rmsnorm(o, p["head_norm"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", o * jax.nn.silu(z), p["w_down"])
    return x + y, {"c": state[0], "n": state[1], "m": state[2]}


def _slstm_decode(x, p, st, cfg: ModelConfig):
    b = x.shape[0]
    nh = cfg.n_heads
    d = cfg.d_model
    dh = d // nh
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    gates = jnp.einsum("bsd,dg->bsg", h, p["w_in"]).reshape(b, 1, nh, 4, dh)
    o, state = slstm_scan(gates, p["r"],
                          state=(st["c"], st["n"], st["h"], st["m"]))
    y = jnp.einsum("bsd,de->bse", o.reshape(b, 1, d).astype(x.dtype),
                   p["w_out"])
    return x + y, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            kv_chunk: int = 1024, max_len: int = 0, chunk: int = 64):
    """Run the sequence through, carrying recurrent states into the cache."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    pat, n_groups, rem = _pattern(cfg)
    cache: Params = {"pos": jnp.asarray(s, jnp.int32)}

    if n_groups:
        def body(xc, gp):
            sts = {}
            for si, kind in enumerate(pat):
                if kind == "m":
                    xc, st = _mlstm_apply(xc, gp[f"sub{si}"], cfg, chunk)
                    sts[f"sub{si}"] = {"c": st[0], "n": st[1], "m": st[2]}
                else:
                    xc, st = _slstm_apply(xc, gp[f"sub{si}"], cfg)
                    sts[f"sub{si}"] = {"c": st[0], "n": st[1], "h": st[2],
                                       "m": st[3]}
            return xc, sts

        x, gst = jax.lax.scan(body, x, params["groups"])
        cache["groups"] = gst
    for ri in range(len(rem)):
        x, st = _mlstm_apply(x, params[f"rem{ri}"], cfg, chunk)
        cache[f"rem{ri}"] = {"c": st[0], "n": st[1], "m": st[2]}

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]).astype(jnp.float32)
    return logits, cache


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig, kv_chunk: int = 2048):
    tok = batch["token"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    pat, n_groups, rem = _pattern(cfg)
    new_cache: Params = {"pos": cache["pos"] + 1}

    if n_groups:
        def body(xc, scanned):
            gp, gst = scanned
            sts = {}
            for si, kind in enumerate(pat):
                if kind == "m":
                    xc, sts[f"sub{si}"] = _mlstm_decode(xc, gp[f"sub{si}"],
                                                        gst[f"sub{si}"], cfg)
                else:
                    xc, sts[f"sub{si}"] = _slstm_decode(xc, gp[f"sub{si}"],
                                                        gst[f"sub{si}"], cfg)
            return xc, sts

        x, gst = jax.lax.scan(body, x, (params["groups"], cache["groups"]))
        new_cache["groups"] = gst
    for ri in range(len(rem)):
        x, new_cache[f"rem{ri}"] = _mlstm_decode(x, params[f"rem{ri}"],
                                                 cache[f"rem{ri}"], cfg)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"]).astype(jnp.float32)
    return logits, new_cache
