"""Model / shape configuration system.

One `ModelConfig` per assigned architecture (src/repro/configs/<id>.py holds
the exact published numbers). `ShapeConfig` captures the assigned input-shape
cells (train_4k / prefill_32k / decode_32k / long_500k)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

VOCAB_PAD = 256  # pad vocab to a multiple (even TP sharding; logits masked)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | vlm | xlstm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    qkv_bias: bool = False
    rotary_pct: float = 1.0      # stablelm uses partial rotary
    # attention pattern
    sliding_window: int = 0      # >0: local attention window
    local_global_ratio: int = 0  # gemma3: N local layers per 1 global
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # enc-dec (seamless: encoder over stub audio frames)
    n_enc_layers: int = 0
    audio_downsample: int = 4    # S_frames = seq // downsample
    # vlm (paligemma: stub patch embeddings, prefix-LM mask)
    n_img_tokens: int = 0
    # ssm / hybrid
    ssm_state: int = 0
    mamba_expand: int = 2
    mamba_conv: int = 4
    mamba_headdim: int = 64
    attn_every: int = 0          # zamba2: shared attention every k blocks
    slstm_every: int = 0         # xlstm: sLSTM block every k blocks (0 = none)
    # numerics
    dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // VOCAB_PAD) * VOCAB_PAD

    @property
    def param_count(self) -> int:
        """Total parameters (analytic; MoE counts all experts)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_padded, self.hd
        att = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        if self.family == "xlstm":
            per = self._xlstm_params()
        elif self.family == "hybrid":
            per = self._mamba_params()
            shared = att + 3 * d * f + 2 * d * d  # one shared attn+mlp block
            return self.n_layers * per + shared + 2 * v * d
        else:
            mlp = 3 * d * f
            if self.n_experts:
                mlp = self.n_experts * 3 * d * f + d * self.n_experts
            per = att + mlp
        n = self.n_layers * per + 2 * v * d
        if self.n_enc_layers:
            n += self.n_enc_layers * (att + 3 * d * f)
        return n

    @property
    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count
        d, f = self.d_model, self.d_ff
        att = (d * self.hd * self.n_heads + 2 * d * self.hd * self.n_kv_heads
               + self.hd * self.n_heads * d)
        mlp = self.top_k * 3 * d * f + d * self.n_experts
        return self.n_layers * (att + mlp) + 2 * self.vocab_padded * d

    def _xlstm_params(self) -> int:
        d = self.d_model
        di = self.mamba_expand * d
        return 2 * d * di + di * d + 3 * di * di // 4  # rough: proj + gates

    def _mamba_params(self) -> int:
        d = self.d_model
        di = self.mamba_expand * d
        nh = di // self.mamba_headdim
        return d * (2 * di + 2 * self.ssm_state + nh) + di * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def long_context_capable(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    return cfg.family in ("xlstm", "hybrid") or cfg.local_global_ratio > 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny sizes."""
    base = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        head_dim=32,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_enc_layers=min(cfg.n_enc_layers, 2) if cfg.n_enc_layers else 0,
        n_img_tokens=min(cfg.n_img_tokens, 16) if cfg.n_img_tokens else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        mamba_headdim=32 if cfg.ssm_state else cfg.mamba_headdim,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        slstm_every=cfg.slstm_every,
        dtype="float32",
        remat=False,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
