"""Mixture-of-Experts decoder (granite-moe, qwen3-moe).

Expert parallelism is expressed as a capacity-based sort-dispatch whose
(E, C, D) buffers carry sharding constraints — experts over the `model` mesh
axis, capacity over the batch axes — so GSPMD inserts the all-to-all
exchange (EP) while the code stays single-program. Router uses softmax
top-k with renormalization (qwen3 style) + switch-style load-balance aux.

Dispatch is index-based (argsort + searchsorted), NOT one-hot einsum: at the
assigned dry-run scale (1M tokens × 128 experts) one-hot masks would be
hundreds of GB."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import dense
from repro.models.attention import attention
from repro.models.config import ModelConfig
from repro.models.layers import (Params, attn_params, dense_init, dtype_of,
                                 embed_init, rmsnorm, split_keys, stack_params,
                                 stacked_axes)
from repro.sharding.context import bshard, constrain

AUX_COEF = 0.01


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _moe_layer_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    k1, k2, k3, k4, k5 = split_keys(key, 5)
    attn_p, attn_ax = attn_params(k1, cfg, dtype)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "attn_norm": jnp.ones((d,), dtype),
        "mlp_norm": jnp.ones((d,), dtype),
        "attn": attn_p,
        "router": dense_init(k2, (d, e), jnp.float32),
        "w_gate": dense_init(k3, (e, d, f), dtype, in_axis=-2),
        "w_up": dense_init(k4, (e, d, f), dtype, in_axis=-2),
        "w_down": dense_init(k5, (e, f, d), dtype, in_axis=-2),
    }
    ax = {
        "attn_norm": ("embed",),
        "mlp_norm": ("embed",),
        "attn": attn_ax,
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }
    return p, ax


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    dtype = dtype_of(cfg.dtype)
    keys = split_keys(key, 3 + cfg.n_layers)
    vp = cfg.vocab_padded
    layers, axs = [], None
    for i in range(cfg.n_layers):
        p, axs = _moe_layer_init(keys[3 + i], cfg, dtype)
        layers.append(p)
    params = {
        "embed": embed_init(keys[0], (vp, cfg.d_model), dtype),
        "unembed": dense_init(keys[1], (cfg.d_model, vp), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": stack_params(layers),
    }
    axes = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
        "layers": stacked_axes(axs),
    }
    return params, axes


def _n_data_groups() -> int:
    """Data-parallel group count from the ambient sharding context (1 when
    no context — tests / single-device)."""
    from repro.sharding.context import current_rules
    rules = current_rules()
    if not rules:
        return 1
    sizes = rules.get("__sizes__", {})
    g = 1
    for a in rules.get("batch", ()):
        g *= sizes.get(a, 1)
    return max(g, 1)


def moe_mlp(x: jax.Array, p: Params, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss). Capacity-dropped tokens pass through 0.

    HIERARCHICAL dispatch (§Perf iteration): tokens sort/capacity LOCALLY per
    data-parallel group, so the only cross-device exchange is the (groups, E,
    C_loc, D) ↔ expert-major resharding — a true all-to-all — instead of a
    global gather of every token to every expert shard. With no sharding
    context this reduces to one group (= the reference global dispatch)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    ng = _n_data_groups()
    if n % ng != 0:
        ng = 1
    n_loc = n // ng
    cap = capacity(n_loc, cfg)
    xf = x.reshape(ng, n_loc, d)

    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)          # (ng, n_loc, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # switch aux: fraction routed vs mean prob per expert (global)
    frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(frac * probs.reshape(n, e).mean(0))

    # local sort-dispatch per group (static shapes)
    e_flat = top_e.reshape(ng, n_loc * k).astype(jnp.int32)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(n_loc, dtype=jnp.int32), k)[None], (ng, n_loc * k))
    w_flat = top_w.reshape(ng, n_loc * k)
    order = jnp.argsort(e_flat, axis=1, stable=True)
    es = jnp.take_along_axis(e_flat, order, axis=1)
    ts = jnp.take_along_axis(t_flat, order, axis=1)
    ws = jnp.take_along_axis(w_flat, order, axis=1)
    start = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(e, dtype=jnp.int32)))(es)   # (ng, E)
    pos = jnp.arange(n_loc * k, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(start, es, axis=1)
    keep = pos < cap
    slot = jnp.where(keep, es * cap + pos, e * cap)  # per-group trash slot

    gi = jnp.arange(ng, dtype=jnp.int32)[:, None]
    disp_tok = jnp.full((ng, e * cap + 1), n_loc, jnp.int32
                        ).at[gi, slot].set(jnp.where(keep, ts, n_loc))[:, :-1]
    disp_w = jnp.zeros((ng, e * cap + 1), jnp.float32
                       ).at[gi, slot].set(jnp.where(keep, ws, 0.0))[:, :-1]

    xpad = jnp.concatenate([xf, jnp.zeros((ng, 1, d), xf.dtype)], axis=1)
    xd = jnp.take_along_axis(xpad, disp_tok[..., None], axis=1)
    xd = xd.reshape(ng, e, cap, d)
    xd = constrain(xd, ("expert_groups", "experts", None, None))

    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xd, p["w_gate"]))
         * jnp.einsum("gecd,edf->gecf", xd, p["w_up"]))
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = constrain(y, ("expert_groups", "experts", None, None))

    out = jnp.zeros((ng, n_loc + 1, d), x.dtype).at[gi, disp_tok].add(
        (y.reshape(ng, e * cap, d) * disp_w[..., None]).astype(x.dtype))
    return out[:, :n_loc].reshape(b, s, d), aux


def _block(x, p, cfg: ModelConfig, positions, kv_chunk: int):
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, kk, vv = dense._qkv_rope(h, p["attn"], cfg, positions)
    o = attention(q, kk, vv, causal=True, kv_chunk=kv_chunk)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(o.shape[0], o.shape[1], -1),
                       p["attn"]["wo"])
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    mo, aux = moe_mlp(h, p, cfg)
    return bshard(x + mo), aux


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            kv_chunk: int = 1024):
    x = bshard(jnp.take(params["embed"], tokens, axis=0))
    positions = jnp.arange(tokens.shape[1])

    def body(xc, lp):
        xc, aux = _block(xc, lp, cfg, positions, kv_chunk)
        return xc, aux

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), auxs.mean()


def loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
         kv_chunk: int = 1024) -> jax.Array:
    x, aux = forward(params, batch["tokens"], cfg, kv_chunk)
    from repro.models.layers import chunked_ce
    return chunked_ce(x, params["unembed"], batch["targets"]) + AUX_COEF * aux


# -- serving -------------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    dtype = dtype_of(cfg.dtype)
    kv = {"k": jnp.zeros((cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.hd), dtype),
          "v": jnp.zeros((cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.hd), dtype)}
    return {"pos": jnp.zeros((), jnp.int32), **kv}


def cache_axes(cfg: ModelConfig) -> Params:
    t = ("layer", "batch", None, "kv_heads_c", "head_dim_c")
    return {"pos": (), "k": t, "v": t}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            kv_chunk: int = 1024, max_len: int = 0):
    tokens = batch["tokens"]
    b, s = tokens.shape
    ml = max(max_len, s)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)

    def body(xc, lp):
        h = rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, kk, vv = dense._qkv_rope(h, lp["attn"], cfg, positions)
        o = attention(q, kk, vv, causal=True, kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(b, s, -1), lp["attn"]["wo"])
        h = rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        mo, _aux = moe_mlp(h, lp, cfg)
        kk = jnp.pad(kk, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, ml - s), (0, 0), (0, 0)))
        return bshard(xc + mo), {"k": kk, "v": vv}

    x, kvs = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]).astype(jnp.float32)
    return logits, {"pos": jnp.asarray(s, jnp.int32), **kvs}


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig, kv_chunk: int = 2048):
    tok = batch["token"]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    b = x.shape[0]
    s_cache = cache["k"].shape[2]
    slot = jnp.minimum(pos, s_cache - 1)

    def body(xc, scanned):
        lp, ck, cv = scanned
        h = rmsnorm(xc, lp["attn_norm"], cfg.norm_eps)
        q, kk, vv = dense._qkv_rope(h, lp["attn"], cfg, pos[None])
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kk, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vv, slot, axis=1)
        o = attention(q, ck, cv, causal=False,
                      kv_valid_len=jnp.minimum(pos + 1, s_cache),
                      kv_chunk=kv_chunk)
        xc = xc + jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, -1), lp["attn"]["wo"])
        h = rmsnorm(xc, lp["mlp_norm"], cfg.norm_eps)
        mo, _aux = moe_mlp(h, lp, cfg)
        return bshard(xc + mo), {"k": ck, "v": cv}

    x, kvs = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"]).astype(jnp.float32)
    return logits, {"pos": pos + 1, **kvs}


# -- dense reference (tests) ------------------------------------------------------


def moe_mlp_reference(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    """No-capacity oracle: every token exactly through its top-k experts."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    h = (jax.nn.silu(jnp.einsum("nd,edf->nef", xf, p["w_gate"]))
         * jnp.einsum("nd,edf->nef", xf, p["w_up"]))
    y_all = jnp.einsum("nef,efd->ned", h, p["w_down"])           # (N, E, D)
    sel = jnp.take_along_axis(y_all, top_e[..., None], axis=1)    # (N, k, D)
    out = (sel * top_w[..., None]).sum(1).astype(x.dtype)
    return out.reshape(b, s, d)
