"""Chunked (flash-style) attention in pure JAX — the dry-run/compile path.

Online-softmax over KV chunks via lax.scan keeps peak memory at
O(S · chunk) instead of O(S²) — this is what lets prefill_32k and the 500k
decode cells compile with sane temp memory. Supports causal, sliding-window,
prefix-LM (bidirectional prefix), cross-attention, GQA/MQA, and single-token
decode against a cache. The Pallas kernel (repro.kernels.flash_attention)
implements the same math for the TPU hot path (validated in tests).

GQA layout convention: query head h attends kv head h // (H/Hkv)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True,
              window: int = 0,
              prefix_len: Optional[jax.Array] = None,
              q_offset=0,
              kv_valid_len: Optional[jax.Array] = None,
              kv_chunk: int = 1024,
              q_chunk: int = 0) -> jax.Array:
    """q: (B, Sq, H, Dh); k, v: (B, Sk, Hkv, Dh) → (B, Sq, H, Dh).

    prefix_len: (B,) or scalar — columns < prefix_len are always visible
    (prefix-LM). q_offset: global position of q row 0 (decode). kv_valid_len:
    (B,) or scalar — masks the unfilled cache tail.

    q_chunk > 0 additionally blocks the query dim (outer scan): peak score
    block becomes (B, q_chunk, H, kv_chunk) instead of (B, Sq, H, kv_chunk) —
    §Perf iteration 2 (flash-style double blocking)."""
    if q_chunk and q.shape[1] > q_chunk and q.shape[1] % q_chunk == 0:
        b_, sq_, h_, hd_ = q.shape
        nq = sq_ // q_chunk
        qb = q.reshape(b_, nq, q_chunk, h_, hd_).transpose(1, 0, 2, 3, 4)

        def one(args):
            qi, off = args
            return attention(qi, k, v, causal=causal, window=window,
                             prefix_len=prefix_len,
                             q_offset=q_offset + off * q_chunk,
                             kv_valid_len=kv_valid_len, kv_chunk=kv_chunk,
                             q_chunk=0)

        out = jax.lax.map(one, (qb, jnp.arange(nq)))
        return out.transpose(1, 0, 2, 3, 4).reshape(b_, sq_, h_, hd_)
    b, sq, h, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / (hd ** 0.5)

    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, hd)
    rows = q_offset + jnp.arange(sq)                      # (Sq,) global rows

    kv_chunk = min(kv_chunk, sk)
    n_chunks = -(-sk // kv_chunk)
    pad = n_chunks * kv_chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, n_chunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n_chunks, kv_chunk, hkv, hd).transpose(1, 0, 2, 3, 4)

    if kv_valid_len is None:
        valid_len = jnp.full((1,), sk, jnp.int32)
    else:
        valid_len = jnp.asarray(kv_valid_len, jnp.int32).reshape(-1)

    def body(carry, inputs):
        m_i, l_i, acc = carry
        ci, kci, vci = inputs
        cols = ci * kv_chunk + jnp.arange(kv_chunk)       # (C,) global cols
        # (B, Sq, Hkv, G, C)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg, kci.astype(jnp.float32))

        mask = cols[None, None, :] < valid_len[:, None, None]   # (B?,1,C)
        mask = jnp.broadcast_to(mask, (max(b, mask.shape[0]), sq, kv_chunk))
        if causal:
            cm = (cols[None, :] <= rows[:, None])[None]          # (1,Sq,C)
            if prefix_len is not None:
                pl = jnp.asarray(prefix_len, jnp.int32).reshape(-1, 1, 1)
                cm = cm | (cols[None, None, :] < pl)
            mask = mask & cm
        if window > 0:
            mask = mask & (cols[None, None, :] > rows[None, :, None] - window)

        s = jnp.where(mask[:, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p, vci.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    init = (jnp.full((b, sq, hkv, g), _NEG_INF, jnp.float32),
            jnp.zeros((b, sq, hkv, g), jnp.float32),
            jnp.zeros((b, sq, hkv, g, hd), jnp.float32))
    (_m, l_f, acc), _ = jax.lax.scan(body, init,
                                     (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)
