"""Uniform model interface: family dispatch + abstract specs for the dry-run.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no allocation): train batches, prefill
batches, or (cache + token) decode inputs, per the assigned shape cells.
Modality frontends are stubs per the assignment: paligemma gets precomputed
SigLIP patch embeddings, seamless gets precomputed audio-frame embeddings."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import dense, encdec, moe as moe_m, xlstm as xlstm_m, zamba
from repro.models.config import ModelConfig, ShapeConfig

VISION_FEAT = 1152   # SigLIP width (paligemma stub)
AUDIO_FEAT = encdec.AUDIO_FEAT


def _family(cfg: ModelConfig):
    return {
        "dense": dense, "vlm": dense,
        "moe": moe_m,
        "encdec": encdec,
        "xlstm": xlstm_m,
        "hybrid": zamba,
    }[cfg.family]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable          # key -> (params, axes)
    loss: Callable          # (params, batch) -> scalar
    prefill: Callable       # (params, batch, max_len=0) -> (logits, cache)
    decode_step: Callable   # (params, cache, batch) -> (logits, cache)
    make_cache: Callable    # (batch, seq) -> cache
    cache_axes: Callable    # () -> logical axes tree for the cache

    def abstract_params(self) -> Tuple[Any, Any]:
        """(ShapeDtypeStruct tree, logical axes tree) — no allocation.

        The axes tree is plain python built during init; we capture it from
        the abstract trace via a side channel."""
        box: Dict[str, Any] = {}

        def f(key):
            p, ax = self.init(key)
            box["ax"] = ax
            return p

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, box["ax"]

    def abstract_cache(self, batch: int, seq: int):
        return jax.eval_shape(lambda: self.make_cache(batch, seq))


def get_model(cfg: ModelConfig) -> ModelBundle:
    fam = _family(cfg)
    return ModelBundle(
        cfg=cfg,
        init=lambda key: fam.init(key, cfg),
        loss=lambda p, b: fam.loss(p, b, cfg),
        prefill=lambda p, b, max_len=0: fam.prefill(p, b, cfg, max_len=max_len),
        decode_step=lambda p, c, b: fam.decode_step(p, c, b, cfg),
        make_cache=lambda batch, seq: fam.make_cache(cfg, batch, seq),
        cache_axes=lambda: fam.cache_axes(cfg),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def batch_logical_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.mode in ("train", "prefill"):
        ax: Dict[str, Any] = {"tokens": ("batch", None)}
        if shape.mode == "train":
            ax["targets"] = ("batch", None)
        if cfg.family == "vlm":
            ax["img_embed"] = ("batch", None, None)
        if cfg.family == "encdec":
            ax["frames"] = ("batch", None, None)
        return ax
    return {"token": ("batch",)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    if shape.mode in ("train", "prefill"):
        batch = {"tokens": sds((b, s), jnp.int32)}
        if shape.mode == "train":
            batch["targets"] = sds((b, s), jnp.int32)
        if cfg.family == "vlm":
            batch["img_embed"] = sds((b, cfg.n_img_tokens, VISION_FEAT),
                                     jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = sds((b, max(s // cfg.audio_downsample, 1),
                                   AUDIO_FEAT), jnp.float32)
        return batch
    # decode cells: one new token against a seq_len cache
    return {"token": sds((b,), jnp.int32)}
