"""LM-family architecture zoo (assigned architectures, DESIGN.md §4)."""
