"""Dense decoder-only transformer family.

Covers qwen2.5 (QKV bias), mistral-large, stablelm (partial rotary),
gemma3 (5:1 local:global sliding-window pattern), and — with an image-prefix
projector + prefix-LM mask — paligemma.

Layers are stacked and scanned as *pattern groups*: the repeating window
pattern (e.g. gemma's (W,W,W,W,W,0)) forms one macro-layer whose params carry
a leading n_groups axis; the remainder layers form a second short scan. This
keeps windows static (no cond-in-scan double compute) while preserving exact
layer order and compact HLO.

Caches: global layers cache (B, S, Hkv, Dh); sliding-window layers cache a
ring buffer of size `window` (softmax is permutation-invariant, and RoPE is
applied pre-cache, so ring order is harmless) — this is what makes the
long_500k decode cell fit for gemma3."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.layers import (Params, apply_rope, attn_params, dense_init,
                                 dtype_of, embed_init, mlp_params, rmsnorm,
                                 split_keys, stack_params, stacked_axes, swiglu)
from repro.sharding.context import bshard


# -- layer pattern -------------------------------------------------------------


def layer_pattern(cfg: ModelConfig) -> Tuple[Tuple[int, ...], int, Tuple[int, ...]]:
    """(group_pattern, n_groups, remainder_pattern) of per-layer windows."""
    if cfg.local_global_ratio > 0:
        pat = (cfg.sliding_window,) * cfg.local_global_ratio + (0,)
    elif cfg.sliding_window > 0:
        pat = (cfg.sliding_window,)
    else:
        pat = (0,)
    n_groups = cfg.n_layers // len(pat)
    rem = cfg.n_layers - n_groups * len(pat)
    if cfg.local_global_ratio > 0:
        rem_pat = (cfg.sliding_window,) * rem
    else:
        rem_pat = (0,) * rem if pat == (0,) else (cfg.sliding_window,) * rem
    return pat, n_groups, rem_pat


# -- params ---------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    k1, k2 = split_keys(key, 2)
    attn_p, attn_ax = attn_params(k1, cfg, dtype)
    mlp_p, mlp_ax = mlp_params(k2, cfg.d_model, cfg.d_ff, dtype)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_p,
        "mlp": mlp_p,
    }
    ax = {
        "attn_norm": ("embed",),
        "mlp_norm": ("embed",),
        "attn": attn_ax,
        "mlp": mlp_ax,
    }
    return p, ax


def init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    dtype = dtype_of(cfg.dtype)
    pat, n_groups, rem = layer_pattern(cfg)
    keys = split_keys(key, 4 + cfg.n_layers)
    vp = cfg.vocab_padded

    params: Params = {
        "embed": embed_init(keys[0], (vp, cfg.d_model), dtype),
        "unembed": dense_init(keys[1], (cfg.d_model, vp), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    axes: Params = {
        "embed": ("vocab", "embed"),
        "unembed": ("embed", "vocab"),
        "final_norm": ("embed",),
    }

    li = iter(keys[4:])
    if n_groups > 0:
        groups = []
        for _g in range(n_groups):
            subs = {}
            for si in range(len(pat)):
                p, ax_l = _layer_init(next(li), cfg, dtype)
                subs[f"sub{si}"] = p
            groups.append(subs)
        params["groups"] = stack_params(groups)
        axes["groups"] = {f"sub{si}": stacked_axes(ax_l)
                          for si in range(len(pat))}
    for ri in range(len(rem)):
        p, ax_l = _layer_init(next(li), cfg, dtype)
        params[f"rem{ri}"] = p
        axes[f"rem{ri}"] = ax_l

    if cfg.n_img_tokens:  # paligemma projector (stub frontend → d_model)
        params["img_proj"] = dense_init(keys[2], (1152, cfg.d_model), dtype)
        axes["img_proj"] = (None, "embed")
    return params, axes


# -- forward --------------------------------------------------------------------


def _block(x, p, cfg: ModelConfig, window: int, positions, prefix_len,
           kv_chunk: int):
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv_rope(h, p["attn"], cfg, positions)
    o = attention(q, k, v, causal=True, window=window, prefix_len=prefix_len,
                  kv_chunk=kv_chunk)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(o.shape[0], o.shape[1], -1),
                   p["attn"]["wo"])
    x = x + o
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    x = x + swiglu(h, **p["mlp"])
    return bshard(x)


def _qkv_rope(h, ap, cfg: ModelConfig, positions):
    from repro.models.layers import qkv
    q, k, v = qkv(h, ap, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    return q, k, v


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
            img_embed: Optional[jax.Array] = None,
            kv_chunk: int = 1024) -> jax.Array:
    """→ final hidden states (B, S[, +N_img], D)."""
    x = bshard(jnp.take(params["embed"], tokens, axis=0))
    prefix_len = None
    if cfg.n_img_tokens and img_embed is not None:
        img = jnp.einsum("bnv,vd->bnd", img_embed.astype(x.dtype),
                         params["img_proj"])
        x = jnp.concatenate([img, x], axis=1)
        prefix_len = jnp.int32(cfg.n_img_tokens)
    s = x.shape[1]
    positions = jnp.arange(s)

    pat, n_groups, rem = layer_pattern(cfg)

    if n_groups > 0:
        def group_body(xc, gp):
            for si, win in enumerate(pat):
                xc = _block(xc, gp[f"sub{si}"], cfg, win, positions, prefix_len,
                            kv_chunk)
            return xc, None

        body = _maybe_remat(group_body, cfg)
        x, _ = jax.lax.scan(body, x, params["groups"])
    for ri, win in enumerate(rem):
        x = _block(x, params[f"rem{ri}"], cfg, win, positions, prefix_len,
                   kv_chunk)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
         kv_chunk: int = 1024) -> jax.Array:
    tokens = batch["tokens"]
    x = forward(params, tokens, cfg, img_embed=batch.get("img_embed"),
                kv_chunk=kv_chunk)
    if cfg.n_img_tokens:
        x = x[:, cfg.n_img_tokens:]
    from repro.models.layers import chunked_ce
    return chunked_ce(x, params["unembed"], batch["targets"])


# -- serving (cache) ---------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, seq: int) -> Params:
    """KV caches: ring buffer of size `window` for sliding-window layers."""
    pat, n_groups, rem = layer_pattern(cfg)
    dtype = dtype_of(cfg.dtype)
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def one(win):
        s = min(win, seq) if win > 0 else seq
        return {"k": jnp.zeros((batch, s, hkv, hd), dtype),
                "v": jnp.zeros((batch, s, hkv, hd), dtype)}

    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if n_groups > 0:
        cache["groups"] = {
            f"sub{si}": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape), one(w))
            for si, w in enumerate(pat)}
    for ri, w in enumerate(rem):
        cache[f"rem{ri}"] = one(w)
    return cache


def cache_axes(cfg: ModelConfig) -> Params:
    pat, n_groups, rem = layer_pattern(cfg)
    kv_ax = {"k": ("batch", None, "kv_heads_c", "head_dim_c"),
             "v": ("batch", None, "kv_heads_c", "head_dim_c")}
    ax: Params = {"pos": ()}
    if n_groups > 0:
        ax["groups"] = {f"sub{si}": jax.tree.map(
            lambda t: ("layer",) + t, kv_ax, is_leaf=lambda t: isinstance(t, tuple))
            for si in range(len(pat))}
    for ri in range(len(rem)):
        ax[f"rem{ri}"] = kv_ax
    return ax


def _block_decode(x, p, kvc, cfg: ModelConfig, window: int, pos, kv_chunk: int):
    """One-token decode through one layer; returns (x, new kv)."""
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv_rope(h, p["attn"], cfg, pos[None])
    s_cache = kvc["k"].shape[1]
    if window > 0:
        slot = pos % s_cache                      # ring buffer
    else:
        slot = jnp.minimum(pos, s_cache - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(kvc["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(kvc["v"], v, slot, axis=1)
    valid = jnp.minimum(pos + 1, s_cache)
    o = attention(q, ck, cv, causal=False, kv_valid_len=valid,
                  kv_chunk=kv_chunk)
    o = jnp.einsum("bsh,hd->bsd", o.reshape(o.shape[0], 1, -1), p["attn"]["wo"])
    x = x + o
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    x = bshard(x + swiglu(h, **p["mlp"]))
    return x, {"k": ck, "v": cv}


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            kv_chunk: int = 1024, max_len: int = 0):
    """Full-sequence forward that also fills the caches. Global-attention
    caches are padded to `max_len` (≥ S + decode budget); sliding-window
    layers keep a `window`-sized ring regardless."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    prefix_len = None
    if cfg.n_img_tokens and batch.get("img_embed") is not None:
        img = jnp.einsum("bnv,vd->bnd", batch["img_embed"].astype(x.dtype),
                         params["img_proj"])
        x = jnp.concatenate([img, x], axis=1)
        prefix_len = jnp.int32(cfg.n_img_tokens)
        s = x.shape[1]
    positions = jnp.arange(s)
    pat, n_groups, rem = layer_pattern(cfg)
    cache = {"pos": jnp.asarray(s, jnp.int32)}

    def fill_block(xc, p, win):
        h = rmsnorm(xc, p["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv_rope(h, p["attn"], cfg, positions)
        o = attention(q, k, v, causal=True, window=win, prefix_len=prefix_len,
                      kv_chunk=kv_chunk)
        o = jnp.einsum("bsh,hd->bsd", o.reshape(o.shape[0], o.shape[1], -1),
                       p["attn"]["wo"])
        xc = xc + o
        h = rmsnorm(xc, p["mlp_norm"], cfg.norm_eps)
        xc = bshard(xc + swiglu(h, **p["mlp"]))
        if win > 0:  # keep the last `win` positions, ring-aligned (slot = pos % win)
            wlen = min(win, s)
            k = jax.lax.dynamic_slice_in_dim(k, s - wlen, wlen, axis=1)
            v = jax.lax.dynamic_slice_in_dim(v, s - wlen, wlen, axis=1)
            if wlen == win:
                k = jnp.roll(k, shift=s % win, axis=1)
                v = jnp.roll(v, shift=s % win, axis=1)
            else:  # wlen < win ⇒ pos p already sits at slot p; pad ring
                k = jnp.pad(k, ((0, 0), (0, win - wlen), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, win - wlen), (0, 0), (0, 0)))
        elif max_len > s:  # room for subsequent decode steps
            k = jnp.pad(k, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, max_len - s), (0, 0), (0, 0)))
        return xc, {"k": k, "v": v}

    if n_groups > 0:
        def group_body(xc, gp):
            kvs = {}
            for si, win in enumerate(pat):
                xc, kv_ = fill_block(xc, gp[f"sub{si}"], win)
                kvs[f"sub{si}"] = kv_
            return xc, kvs

        x, gkvs = jax.lax.scan(group_body, x, params["groups"])
        cache["groups"] = gkvs
    for ri, win in enumerate(rem):
        x, kv_ = fill_block(x, params[f"rem{ri}"], win)
        cache[f"rem{ri}"] = kv_

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]).astype(jnp.float32)
    return logits, cache


def decode_step(params: Params, cache: Params, batch: Dict[str, jax.Array],
                cfg: ModelConfig, kv_chunk: int = 2048):
    """One-token decode. batch = {"token": (B,) int32}."""
    tok = batch["token"]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tok[:, None], axis=0)
    pat, n_groups, rem = layer_pattern(cfg)
    new_cache: Params = {"pos": pos + 1}

    if n_groups > 0:
        def group_body(xc, scanned):
            gp, gkv = scanned
            kvs = {}
            for si, win in enumerate(pat):
                xc, kv_ = _block_decode(xc, gp[f"sub{si}"], gkv[f"sub{si}"],
                                        cfg, win, pos, kv_chunk)
                kvs[f"sub{si}"] = kv_
            return xc, kvs

        x, gkvs = jax.lax.scan(group_body, x, (params["groups"], cache["groups"]))
        new_cache["groups"] = gkvs
    for ri, win in enumerate(rem):
        x, kv_ = _block_decode(x, params[f"rem{ri}"], cache[f"rem{ri}"], cfg,
                               win, pos, kv_chunk)
        new_cache[f"rem{ri}"] = kv_

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"]).astype(jnp.float32)
    return logits, new_cache
