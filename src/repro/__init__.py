"""repro — Nebula (city-scale 3DGS collaborative rendering) + multi-pod LM framework in JAX.

Layout:
  repro.core      — the paper's contribution: LoD search, Gaussian management,
                    stereo rasterization, collaborative pipeline.
  repro.kernels   — Pallas TPU kernels (+ pure-jnp oracles) for the hot spots.
  repro.models    — the assigned LM-family architecture zoo.
  repro.sharding  — logical-axis sharding rules (DP/FSDP/TP/EP/SP).
  repro.train     — optimizer, train step, trainer (fault tolerant).
  repro.serve     — the fleet LoD service (partial-fleet sync, deadline
                    scheduling, Δ-stream paging, recovery).
  repro.data      — synthetic data pipelines with prefetch.
  repro.checkpoint— mesh-agnostic checkpointing (elastic restore).
  repro.configs   — one config per assigned architecture (+ scene configs).
  repro.launch    — production mesh, multi-pod dry-run, roofline, drivers.
"""

__version__ = "0.1.0"
