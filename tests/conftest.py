"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device (multi-device tests
spawn subprocesses; see tests/multihost_utils.py)."""

import numpy as np
import pytest

from repro.core.gaussians import CityConfig, generate_city, random_gaussians
from repro.core.lod_tree import build_lod_tree


@pytest.fixture(scope="session")
def small_city():
    return generate_city(CityConfig(blocks_x=2, blocks_y=2, leaf_density=0.15, seed=1))


@pytest.fixture(scope="session")
def small_tree(small_city):
    return build_lod_tree(small_city, target_subtrees=16, seed=0)


@pytest.fixture(scope="session")
def tiny_tree():
    rng = np.random.default_rng(7)
    leaves = random_gaussians(rng, 150, sh_degree=1, extent=30.0)
    return build_lod_tree(leaves, branching=(2, 4), target_subtrees=8, seed=1)
