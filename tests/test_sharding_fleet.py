"""Mesh-sharded fleet service: client-axis × slab-axis partitioning.

The load-bearing claims pinned here:

  * PARITY — on a forced 8-device host-platform CPU mesh (clients×slabs =
    4×2), the sharded service's cuts, per-slot stats (wire bytes included),
    decoded Δ payload rows, and pooled fallback frames are BITWISE identical
    to the single-device service across a randomized admit/evict/sync
    schedule, for both the pooled and the vmapped scheduler (subprocess —
    the parent process must keep seeing the single real device);
  * `ServiceState` leaves carry the declared client-axis NamedSharding
    (`leaf.sharding.spec == PartitionSpec('clients', ...)`), the slab
    tables the slab-axis one;
  * `fleet_totals` reduces per-slot stats identically via the shard_map
    psum path and the plain sum;
  * the ONE divisibility/replicate-fallback rule: `partitioning.axes_for_dim`
    is shared by `logical_to_pspec` AND `context.constrain` (regression-
    pinned by monkeypatch, like the pow2_bucket pin in test_lod_search);
  * capacity SHRINK compacts a sparse fleet into the smaller pow2 bucket
    and survivors replay bitwise vs a never-shrunk service;
  * admission control denies (AdmissionDenied / None) past the configured
    budgets and leaves a denied service untouched;
  * recompile guard mirroring test_fleet_churn.py: with no mesh installed
    the jitted sync entry points never retrace inside a capacity bucket —
    and a MESHED service running in the same process adds its own traces
    without invalidating or growing the meshless ones.
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.serve import delta_path as dp
from repro.serve import fleet as flt
from repro.serve import lod_service as svc
from repro.sharding import context as shctx
from repro.sharding import fleet as shf
from repro.sharding import partitioning as shp

FOCAL = 1400.0
TAU = 32.0


def _fake_fleet_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("clients", "slabs"))


# ---------------------------------------------------------------------------
# (a) the ONE shared divisibility / replicate-fallback rule
# ---------------------------------------------------------------------------


def test_axes_for_dim_semantics():
    rules = {"batch": ("pod", "data"), "heads": ("model",)}
    sizes = {"pod": 2, "data": 3, "model": 4}
    names = set(sizes)
    # full multi-axis product divides -> keep both axes
    assert shp.axes_for_dim("batch", 12, rules, names, sizes) == ("pod",
                                                                  "data")
    # full product (6) does not divide 8 -> the WHOLE dim replicates
    assert shp.axes_for_dim("batch", 8, rules, names, sizes) == ()
    # axes not on the mesh are dropped before the check
    assert shp.axes_for_dim("batch", 9, rules, {"data"}, {"data": 3}) == (
        "data",)
    # unknown sizes (mesh given as bare names): divisibility not enforced
    assert shp.axes_for_dim("batch", 7, rules, names, None) == ("pod", "data")
    # PARTIALLY known sizes: unknowable, keep (the old context.constrain
    # multiplied only the known axes and could drop a divisible split)
    assert shp.axes_for_dim("batch", 8, rules, names, {"data": 3}) == (
        "pod", "data")
    # unknown logical name / None -> replicate
    assert shp.axes_for_dim("nope", 8, rules, names, sizes) == ()
    assert shp.axes_for_dim(None, 8, rules, names, sizes) == ()


def test_constrain_and_pspec_share_the_helper(monkeypatch):
    """Both rule paths route EVERY dimension through axes_for_dim — the
    regression pin that keeps them from drifting apart again."""
    calls = []
    real = shp.axes_for_dim

    def spy(name, dim, rules, mesh_names=None, mesh_sizes=None):
        calls.append(("ctx" if rules.get("__tag__") else "pspec", name, dim))
        return real(name, dim, rules, mesh_names, mesh_sizes)

    monkeypatch.setattr(shp, "axes_for_dim", spy)
    monkeypatch.setattr(shctx, "axes_for_dim", spy)

    mesh = _fake_fleet_mesh()
    assert shp.logical_to_pspec(("clients", None), mesh, (4, 3),
                                shf.fleet_axis_rules(mesh)) == P("clients",
                                                                 None)
    rules = {"batch": ("clients",), "__sizes__": {"clients": 1, "slabs": 1},
             "__tag__": True}
    with mesh, shctx.use_rules(rules):
        shctx.constrain(jnp.zeros((4, 3)), ("batch", None))
    tags = {c[0] for c in calls}
    assert tags == {"pspec", "ctx"}
    # every logical dim went through the helper (None dims included)
    assert ("pspec", "clients", 4) in calls and ("ctx", "batch", 4) in calls


# ---------------------------------------------------------------------------
# (b) the fleet sharding builder (single-device: specs declared, layout no-op)
# ---------------------------------------------------------------------------


def test_fleet_shardings_builder(tiny_tree):
    mesh = _fake_fleet_mesh()
    state = svc.service_init(tiny_tree, svc.SessionConfig(tau=TAU), 4)
    sh = shf.fleet_shardings(mesh, state)
    assert jax.tree_util.tree_structure(sh) == \
        jax.tree_util.tree_structure(state)
    assert sh.sync_index.spec == P("clients")
    assert sh.temporal.slab_cut0.spec == P("clients", None, None)
    assert sh.fleet.next_id.spec == P()           # scalar -> replicated
    tables = ls.SlabTables.from_tree(tiny_tree)
    tsh = shf.slab_shardings(mesh, tables)
    assert tsh.mu.spec == P("slabs", None, None)
    # placement on the 1x1 mesh is a bitwise no-op
    placed = shf.shard_service_state(mesh, state)
    for a, b in zip(jax.tree_util.tree_leaves(placed),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fleet_axis_rules_filters_to_mesh():
    mesh = _fake_fleet_mesh()
    rules = shf.fleet_axis_rules(mesh)
    assert rules["clients"] == ("clients",)
    assert rules["union"] == ("slabs",)
    assert rules["__sizes__"] == {"clients": 1, "slabs": 1}
    # a mesh without the axes: every rule empties (total replicate fallback)
    lone = Mesh(np.array(jax.devices()[:1]), ("data",))
    rules = shf.fleet_axis_rules(lone)
    assert rules["clients"] == () and rules["slabs"] == ()


def test_client_shards_divisibility():
    mesh = _fake_fleet_mesh()
    assert shf.client_shards(mesh, 8) == 1     # size-1 axis -> 1 shard
    assert shf.client_shards(None, 8) == 1


def test_fleet_totals_meshless(tiny_tree):
    cfg = svc.SessionConfig(tau=TAU, cut_budget=2048)
    service = svc.LodService(tiny_tree, cfg, 3, focal=FOCAL)
    stats = service.sync(np.asarray([[5, 5, 2], [9, 7, 2], [20, 15, 3]],
                                    np.float32))
    tot = shf.fleet_totals(stats)
    assert int(tot.cut_size) == int(np.asarray(stats.cut_size).sum())
    assert float(tot.sync_bytes) == pytest.approx(
        float(np.asarray(stats.sync_bytes).sum()))
    assert tot.overflow.dtype == jnp.int32      # bools count


# ---------------------------------------------------------------------------
# (c) capacity SHRINK
# ---------------------------------------------------------------------------


def _mk(tree, n, cap, **kw):
    cfg = svc.SessionConfig(tau=TAU, cut_budget=2048)
    return svc.LodService(tree, cfg, n, focal=FOCAL, capacity=cap,
                          mode="pooled", dedup=True, **kw)


def test_maybe_shrink_compacts_and_survivors_replay_bitwise(tiny_tree):
    rng = np.random.default_rng(3)
    cams = rng.uniform([2, 2, 1], [28, 28, 6], (6, 3)).astype(np.float32)
    a = _mk(tiny_tree, 6, 8)
    b = _mk(tiny_tree, 6, 8)
    for s in (a, b):
        s.sync(cams)
        s.sync({cid: cams[i] + 2.0 for i, cid in enumerate(s.active_ids)})
    for cid in (0, 2, 4, 5):
        a.evict(cid)
        b.evict(cid)
    assert a.maybe_shrink() == 2 and a.capacity == 2
    assert a.maybe_shrink() is None              # already right-sized
    assert a.active_ids == b.active_ids == [1, 3]
    # pre-shrink payload stays addressable (ref-mask rows were remapped)
    ids_a, dec_a = a.client_delta(1)
    ids_b, dec_b = b.client_delta(1)
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
    np.testing.assert_array_equal(np.asarray(dec_a.mu), np.asarray(dec_b.mu))
    # survivors replay bitwise vs the never-shrunk capacity-8 service
    for step in range(3):
        pos = {cid: cams[[1, 3].index(cid)] + 3.0 * (step + 1)
               for cid in (1, 3)}
        sa, sb = a.sync(dict(pos)), b.sync(dict(pos))
        for cid in (1, 3):
            ia, ib = a._slot_of(cid), b._slot_of(cid)
            for f in ("cut_size", "delta_size", "sync_bytes", "unique_delta",
                      "nodes_touched", "resweeps", "client_resident"):
                assert np.asarray(getattr(sa, f))[ia] == \
                    np.asarray(getattr(sb, f))[ib], (cid, f)
            np.testing.assert_array_equal(
                np.asarray(a.state.cut_gids[ia]),
                np.asarray(b.state.cut_gids[ib]), err_msg=f"cut {cid}")
            da, db = a.client_delta(cid), b.client_delta(cid)
            np.testing.assert_array_equal(np.asarray(da[0]),
                                          np.asarray(db[0]))


def test_shrink_gathered_free_slots_are_fresh(tiny_tree):
    service = _mk(tiny_tree, 5, 8)
    service.sync(np.tile(np.asarray([10, 10, 2], np.float32), (5, 1)))
    service.evict(3)
    service.evict(4)
    assert service.maybe_shrink() == 4           # 3 live -> pow2 bucket 4
    fresh = svc.service_init(tiny_tree, service.cfg, 0, capacity=4)
    # slot 3 (gathered from a FREE slot) must be bitwise the reset value
    for got, ref in zip(jax.tree_util.tree_leaves(
            (service.state.mgr, service.state.temporal,
             service.state.cut_gids, service.state.sync_index)),
            jax.tree_util.tree_leaves(
            (fresh.mgr, fresh.temporal, fresh.cut_gids, fresh.sync_index))):
        np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(ref[3]))
    assert not bool(service.state.fleet.active[3])
    # the freed slot is admissible again without growth
    cid = service.admit([1, 1, 1])
    assert service.capacity == 4 and service._slot_of(cid) == 3


def test_shrink_after_growth_with_stale_payload(tiny_tree):
    """A capacity growth between the last sync and a shrink must not break
    the payload remap (regression: `_grow` left `_delta_ids` at the old
    capacity — a later shrink indexed past it — and `ref_mask` rows predate
    the growth, so grown slots get an all-False row, never a wrong one)."""
    service = _mk(tiny_tree, 4, 4)
    service.sync(np.tile(np.asarray([10, 10, 2], np.float32), (4, 1)))
    cid = service.admit([11, 11, 2])        # grows 4 -> 8, no sync yet
    for c in (0, 1, 2, 3):
        service.evict(c)
    assert service.maybe_shrink() == 1 and service.active_ids == [cid]
    with pytest.raises(ValueError):         # payload predates cid's admit
        service.client_delta(cid)
    service.sync({cid: np.asarray([11, 11, 2], np.float32)})
    ids, _ = service.client_delta(cid)      # fresh payload addressable
    assert (np.asarray(ids) >= 0).any()


def test_take_slots_and_fleet_shrink_primitives():
    fleet = flt.fleet_init(4, 3)
    fleet = flt.fleet_evict_slot(fleet, 1)
    shrunk = flt.fleet_shrink(fleet, np.asarray([0, 2], np.int32))
    assert np.asarray(shrunk.active).tolist() == [True, True]
    assert np.asarray(shrunk.client_ids).tolist() == [0, 2]
    assert int(shrunk.next_id) == 3              # ids stay monotone
    batched = {"x": jnp.arange(12).reshape(4, 3)}
    out = flt.take_slots(batched, np.asarray([2, 0], np.int32))
    np.testing.assert_array_equal(np.asarray(out["x"]),
                                  [[6, 7, 8], [0, 1, 2]])


# ---------------------------------------------------------------------------
# (d) admission control
# ---------------------------------------------------------------------------


def test_admission_denied_max_clients(tiny_tree):
    service = _mk(tiny_tree, 2, 4, max_clients=2)
    service.sync(np.asarray([[5, 5, 2], [9, 7, 2]], np.float32))
    state_before = service.state
    with pytest.raises(svc.AdmissionDenied):
        service.admit([1, 1, 1])
    assert service.admit([1, 1, 1], required=False) is None
    # a denied admit is side-effect free
    assert service.n_clients == 2 and service.capacity == 4
    assert service.state is state_before
    service.evict(0)
    assert service.admit([1, 1, 1]) == 2         # room again -> admitted


def test_admission_denied_byte_budget(tiny_tree):
    service = _mk(tiny_tree, 2, 2)
    per_slot = service._slot_state_bytes()
    # budget covers the CURRENT 2 slots but not the pow2 growth to 4
    service.max_state_bytes = per_slot * 3
    with pytest.raises(svc.AdmissionDenied):
        service.admit([1, 1, 1])
    assert service.capacity == 2
    # an in-bucket admit (free slot, no growth) is always within budget
    service.evict(0)
    assert service.admit([1, 1, 1]) == 2


# ---------------------------------------------------------------------------
# (e) recompile guard (mirrors test_fleet_churn): meshless traces are
# unchanged by the sharding plumbing AND by a meshed service in-process
# ---------------------------------------------------------------------------


def _trace_counts():
    entries = {
        "top_and_staleness": ls.batched_top_and_staleness,
        "compact_stale_pairs": svc._compact_stale_pairs,
        "pooled_pair_sweep": svc._pooled_pair_sweep,
        "apply_pooled_updates": svc._apply_pooled_updates,
        "batched_cut_gids": svc._batched_cut_gids,
        "batched_cloud_sync": mgr.batched_cloud_sync,
        "union_mask": dp._union_mask,
        "union_refs": dp._union_refs,
        "admit_slot": svc.service_admit_slot,
        "evict_slot": svc.service_evict_slot,
    }
    return {name: fn._cache_size() for name, fn in entries.items()}


def test_meshless_recompile_guard_with_meshed_service_interleaved(tiny_tree):
    anchor = np.asarray([10.0, 10.0, 2.0], np.float32)
    plain = _mk(tiny_tree, 3, 4)
    plain.sync(np.tile(anchor, (3, 1)))
    plain.sync()
    cid = plain.admit(anchor)
    plain.sync()
    plain.evict(cid)
    plain.sync()
    base = _trace_counts()
    # a size-1x1 meshed service in the SAME process: its static mesh arg
    # keys separate cache entries, so it may add traces of its own...
    meshed = _mk(tiny_tree, 3, 4, mesh=_fake_fleet_mesh())
    meshed.sync(np.tile(anchor, (3, 1)))
    meshed.sync()
    with_mesh = _trace_counts()
    # ...but the meshless service keeps running trace-free either way
    for _ in range(6):
        plain.sync()
    cid = plain.admit(anchor)
    plain.sync()
    plain.evict(cid)
    plain.sync()
    assert _trace_counts() == with_mesh
    # and the meshed service's results agree with the meshless one
    np.testing.assert_array_equal(np.asarray(plain.state.fleet.active),
                                  np.asarray(meshed.state.fleet.active))


# ---------------------------------------------------------------------------
# (f) the 8-device parity subprocess (the acceptance contract)
# ---------------------------------------------------------------------------


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import lod_search as ls
from repro.core.camera import StereoRig, make_camera
from repro.core.gaussians import random_gaussians
from repro.core.lod_tree import build_lod_tree
from repro.launch.mesh import make_fleet_mesh
from repro.serve import lod_service as svc
from repro.sharding import fleet as shf

assert len(jax.devices()) == 8
STATS = ("cut_size", "delta_size", "sync_bytes", "unique_delta",
         "dedup_bytes_saved", "nodes_touched", "resweeps",
         "client_resident", "overflow", "delta_overflow",
         "delta_shipped", "delta_deferred", "pages")
GAUSS = ("mu", "log_scale", "quat", "opacity", "sh")

rng = np.random.default_rng(11)
leaves = random_gaussians(rng, 150, sh_degree=1, extent=30.0)
tree = build_lod_tree(leaves, branching=(2, 4), target_subtrees=8, seed=1)
cfg = svc.SessionConfig(tau=32.0, cut_budget=2048)
mesh = make_fleet_mesh(clients=4, slabs=2)

def mk(mode, m):
    return svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8,
                          mode=mode, dedup=True, mesh=m)

def rig_at(pos):
    cam = make_camera(list(np.asarray(pos, np.float32)),
                      list(np.asarray(pos, np.float32) + [10, 10, -0.2]),
                      focal_px=200.0, width=64, height=48, near=0.25)
    return StereoRig(left=cam, baseline=0.06)

def cmp_sync(tag, sb, ss, base, shrd):
    for f in STATS:
        np.testing.assert_array_equal(np.asarray(getattr(sb, f)),
                                      np.asarray(getattr(ss, f)),
                                      err_msg=f"{tag}:{f}")
    np.testing.assert_array_equal(np.asarray(base.state.cut_gids),
                                  np.asarray(shrd.state.cut_gids),
                                  err_msg=f"{tag}:cut_gids")
    for cid in base.active_ids:
        ib, dbv = base.client_delta(cid)
        is_, dsv = shrd.client_delta(cid)
        np.testing.assert_array_equal(np.asarray(ib), np.asarray(is_),
                                      err_msg=f"{tag}:ids:{cid}")
        sel = np.asarray(ib) >= 0
        for f in GAUSS:
            np.testing.assert_array_equal(
                np.asarray(getattr(dbv, f))[sel],
                np.asarray(getattr(dsv, f))[sel],
                err_msg=f"{tag}:rows:{f}:{cid}")

# randomized admit/evict/sync schedule (ids are monotone+deterministic, so
# the same host-side schedule drives every service)
def schedule(steps=7):
    r = np.random.default_rng(5)
    alive, nid = [0, 1, 2, 3], 4
    pos = {c: r.uniform([2, 2, 1], [28, 28, 6]).astype(np.float32)
           for c in alive}
    ev = []
    for t in range(steps):
        if len(alive) > 1 and r.random() < 0.35:
            c = alive.pop(int(r.integers(len(alive))))
            ev.append(("evict", c))
        if len(alive) < 6 and r.random() < 0.5:
            p = r.uniform([2, 2, 1], [28, 28, 6]).astype(np.float32)
            ev.append(("admit", nid, p)); pos[nid] = p
            alive.append(nid); nid += 1
        for c in alive:
            pos[c] = (pos[c] + r.normal(0, 3.0, 3)).astype(np.float32)
        ev.append(("sync", {c: pos[c].copy() for c in alive}))
    return ev

results = {}
for mode in ("pooled", "vmapped"):
    base, shrd = mk(mode, None), mk(mode, mesh)
    n_sync = 0
    for e in schedule():
        if e[0] == "admit":
            assert base.admit(e[2]) == e[1] and shrd.admit(e[2]) == e[1]
        elif e[0] == "evict":
            base.evict(e[1]); shrd.evict(e[1])
        else:
            cmp_sync(f"{mode}:{n_sync}", base.sync(dict(e[1])),
                     shrd.sync(dict(e[1])), base, shrd)
            n_sync += 1
    results[f"{mode}_syncs"] = n_sync

    # the declared client-axis NamedSharding on every slot-axis state leaf
    for leaf in jax.tree_util.tree_leaves(shrd.state):
        spec = leaf.sharding.spec
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == shrd.capacity:
            assert spec[0] == "clients", (leaf.shape, spec)
        else:
            assert spec == P(), (leaf.shape, spec)
    if mode == "pooled":
        assert shrd.tables.mu.sharding.spec[0] == "slabs"

    # fleet_totals: shard_map psum == plain sum, leafwise
    stats_s = shrd.sync()
    stats_b = base.sync()
    tot_p = shf.fleet_totals(stats_s, mesh)
    tot_r = shf.fleet_totals(stats_b, None)
    for a, b in zip(jax.tree_util.tree_leaves(tot_p),
                    jax.tree_util.tree_leaves(tot_r)):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.floating):
            # per-shard partial sums reassociate float adds (documented)
            np.testing.assert_allclose(a, b, rtol=1e-6)
        else:
            np.testing.assert_array_equal(a, b)

    # pooled fallback frames shard over clients and match bitwise
    rigs = [rig_at(p) for p in
            [base._slot_cams[base._slot_of(c)] for c in base.active_ids]]
    for path in ("vmap", "pooled"):
        il_b, ir_b, _ = base.render_fallback(rigs, list_len=128,
                                             max_pairs=1 << 15, path=path)
        il_s, ir_s, _ = shrd.render_fallback(rigs, list_len=128,
                                             max_pairs=1 << 15, path=path)
        np.testing.assert_array_equal(np.asarray(il_b), np.asarray(il_s),
                                      err_msg=f"{mode}:{path}:L")
        np.testing.assert_array_equal(np.asarray(ir_b), np.asarray(ir_s),
                                      err_msg=f"{mode}:{path}:R")
        assert il_s.sharding.spec[0] == "clients", (path, il_s.sharding)

# Pallas bucket sweep under the mesh: its pair inputs replicate (the
# kernel is opaque to the partitioner) and results stay bitwise
pb = svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8, mode="pooled",
                    sweep_impl="pallas", dedup=True)
ps = svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8, mode="pooled",
                    sweep_impl="pallas", dedup=True, mesh=mesh)
r = np.random.default_rng(9)
pos = r.uniform([2, 2, 1], [28, 28, 6], (4, 3)).astype(np.float32)
for t in range(2):
    cmp_sync(f"pallas:{t}", pb.sync(pos), ps.sync(pos), pb, ps)
    pos = (pos + r.normal(0, 3.0, (4, 3))).astype(np.float32)
results["pallas_ok"] = True

# SHRINK under the mesh: evict down to 2 and compact; survivors bitwise
for cid in list(base.active_ids)[:-2]:
    base.evict(cid); shrd.evict(cid)
assert base.maybe_shrink() == shrd.maybe_shrink() == 2
live = base.active_ids
pos = {c: np.asarray([12.0 + c, 9.0, 2.0], np.float32) for c in live}
cmp_sync("shrunk", base.sync(dict(pos)), shrd.sync(dict(pos)), base, shrd)
for leaf in jax.tree_util.tree_leaves(shrd.state):
    if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == 2:
        assert leaf.sharding.spec[0] in ("clients", None)

# paged Δ-stream under the mesh: a tight budget pages the cold union and
# the carried debt drains to bitwise equality with an un-budgeted fleet
am = svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8, mode="pooled",
                    dedup=True, mesh=mesh)
tp = svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8, mode="pooled",
                    dedup=True, delta_budget=32, page_size=16, mesh=mesh)
pos = np.asarray([[8.0, 8.0, 2.0], [20.0, 9.0, 2.5],
                  [10.0, 22.0, 3.0], [24.0, 24.0, 2.0]], np.float32)
st = tp.sync(pos); am.sync(pos)
assert int(np.asarray(st.delta_deferred).sum()) > 0
# overflow sync: width == budget (32), divisible by both mesh axes, so the
# declared union/clients layouts hold exactly
for leaf in (tp.last_delta.union_gids, tp.last_delta.payload.pos_q):
    assert leaf.sharding.spec[0] == "slabs", leaf.sharding
assert tp.last_delta.ref_mask.sharding.spec == P("clients", "slabs")
n_paged = 1
while np.asarray(tp.state.pending).any() and n_paged < 64:
    tp.sync(pos); am.sync(pos); n_paged += 1
assert not np.asarray(tp.state.pending).any()
np.testing.assert_array_equal(np.asarray(tp.state.mgr.client_has),
                              np.asarray(am.state.mgr.client_has))
results["paged_syncs"] = n_paged

# bounded recompilation with the mesh on: parked re-syncs add no traces
import repro.serve.lod_service as S
def counts():
    fns = (ls.batched_top_and_staleness, S._compact_stale_pairs,
           S._pooled_pair_sweep, S._apply_pooled_updates,
           S._batched_cut_gids)
    return [f._cache_size() for f in fns]
shrd.sync(); shrd.sync()
c0 = counts()
shrd.sync(); shrd.sync(); shrd.sync()
assert counts() == c0, (c0, counts())
results["ok"] = True
print(json.dumps(results))
"""


@pytest.mark.slow
def test_sharded_fleet_parity_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=900,
                         cwd=".")
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results["ok"] and results["pooled_syncs"] >= 5 \
        and results["vmapped_syncs"] >= 5
