"""The render/ subsystem: fleet-batched stereo rendering bit-accuracy, the
pooled Pallas bucket path, merge-overflow surfacing, per-client foveated τ,
and LoD-cut kernel parity with the vmapped service sweep."""

import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np

from repro import render as rnd
from repro.core import lod_search as ls
from repro.core.binning import BinConfig, bin_left
from repro.core.camera import StereoRig, make_camera
from repro.core.gaussians import Gaussians, random_gaussians
from repro.core.pipeline import (SessionConfig, render_stereo,
                                 render_stereo_reference)
from repro.core.projection import depth_ranks, project
from repro.core.stereo import n_categories, stereo_lists
from repro.kernels import ops
from repro.serve import lod_service as svc

FOCAL = 200.0


def _rig_at(pos, target, focal=FOCAL, width=96, height=64, near=0.25):
    cam = make_camera(list(pos), list(target), focal_px=focal, width=width,
                      height=height, near=near)
    return StereoRig(left=cam, baseline=0.06)


def _fleet(b=4, n=200):
    """B distinct rigs (distinct pose, orientation, AND focal — chosen so the
    per-rig n_cat stays shared, the fleet-static requirement) + B queues."""
    queues = [random_gaussians(np.random.default_rng(i), n, sh_degree=1,
                               extent=6.0) for i in range(b)]
    rigs = [_rig_at((3 * i - 4, -16 + i, 2 + 0.3 * i), (i - 2, 2 - i, 0),
                    focal=FOCAL + 5 * i) for i in range(b)]
    return queues, rigs


# -- (a) batched_render_stereo ≡ single-client render_stereo ≡ reference ------


def test_batched_render_bitwise_vs_single_and_reference():
    b = 4
    queues, rigs = _fleet(b=b)
    cfg = rnd.RenderConfig.for_fleet(rigs, tile=16, list_len=128,
                                     max_pairs=1 << 15)
    for r in rigs:  # the fleet premise: one static widening covers everyone
        assert n_categories(r.max_disparity_px(), cfg.tile) == cfg.n_cat

    bl, br, stats = rnd.batched_render_stereo(
        rnd.stack_pytrees(queues), rnd.stack_rigs(rigs), cfg, path="vmap")
    assert not np.asarray(stats.overflow).any()
    for i in range(b):
        # bitwise vs the legacy single-client pipeline surface
        il, ir, (_s, ll, rl, _st) = render_stereo(
            queues[i], rigs[i], tile=16, list_len=128, max_pairs=1 << 15)
        assert not bool(ll.overflow) and not bool(rl.overflow)
        np.testing.assert_array_equal(np.asarray(bl[i]), np.asarray(il))
        np.testing.assert_array_equal(np.asarray(br[i]), np.asarray(ir))
        # and hence vs the fully independent per-eye reference
        ref_l, ref_r = render_stereo_reference(queues[i], rigs[i])
        np.testing.assert_array_equal(np.asarray(bl[i]), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(br[i]), np.asarray(ref_r))


def test_batched_stats_match_single_client():
    b = 3
    queues, rigs = _fleet(b=b, n=150)
    cfg = rnd.RenderConfig.for_fleet(rigs, tile=16, list_len=128,
                                     max_pairs=1 << 15)
    _bl, _br, stats = rnd.batched_render_stereo(
        rnd.stack_pytrees(queues), rnd.stack_rigs(rigs), cfg, path="vmap")
    for i in range(b):
        plan = rnd.build_plan(queues[i], rigs[i], cfg)
        _il, _ir, hits = rnd.render_stereo(plan, cfg)
        st = rnd.frame_stats(plan, hits)
        for name in ("shared_preprocess", "left_blends", "right_candidates",
                     "right_alpha_skipped", "overflow"):
            assert np.asarray(getattr(stats, name))[i] == np.asarray(
                getattr(st, name)), (i, name)


# -- (b) pooled Pallas bucket path --------------------------------------------


def test_pooled_bucket_path_matches_per_client_kernels():
    """Fleet-pooled occupied-tile rasterization must be bitwise equal to
    per-client Pallas dispatches, and allclose (FMA contraction) to the
    vmapped XLA path — with identical work accounting."""
    b = 3
    queues, rigs = _fleet(b=b, n=150)
    cfg = rnd.RenderConfig.for_fleet(rigs, tile=16, list_len=64,
                                     max_pairs=1 << 14)
    qs, rs = rnd.stack_pytrees(queues), rnd.stack_rigs(rigs)
    xl, xr, xstats = rnd.batched_render_stereo(qs, rs, cfg, path="vmap")
    pl_l, pl_r, pstats = rnd.batched_render_stereo(qs, rs, cfg, path="pooled",
                                                   interpret=True)
    for i in range(b):
        plan = rnd.build_plan(queues[i], rigs[i], cfg)
        il, ir, _hits = rnd.rasterize(plan, cfg, use_pallas=True,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(pl_l[i]), np.asarray(il))
        np.testing.assert_array_equal(np.asarray(pl_r[i]), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(pl_l), np.asarray(xl),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pl_r), np.asarray(xr),
                               rtol=1e-5, atol=1e-6)
    for a, bb in zip(jax.tree_util.tree_leaves(xstats),
                     jax.tree_util.tree_leaves(pstats)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


# -- (c) merge overflow is surfaced, not silently truncated -------------------


def _epipolar_scene(n=60, list_len=48):
    """Splats along one epipolar line at many depths: their disparities fan
    the LEFT footprints across several tile columns (each left list small),
    but every right-eye footprint lands in the SAME right tile — the k-way
    merge, not the binning, is what overflows."""
    rig = _rig_at((0, 0, 2), (0, 10, 2))
    cam = rig.left
    rng = np.random.default_rng(0)
    disparity = np.linspace(5.0, 43.0, n)     # uniform fan over 3 columns
    depth = rig.baseline * FOCAL / disparity
    x_cam = np.full(n, rig.baseline)          # x_R ≡ cx for every depth
    y_cam = (-0.04 + rng.uniform(-0.005, 0.005, n)) * depth  # one tile row
    mu = (np.asarray(cam.pos)[None]
          + (np.asarray(cam.rot) @ np.stack([x_cam, y_cam, depth])).T)
    g = Gaussians(
        mu=jnp.asarray(mu, jnp.float32),
        log_scale=jnp.full((n, 3), -6.0, jnp.float32),
        quat=jnp.zeros((n, 4), jnp.float32).at[:, 0].set(1.0),
        opacity=jnp.full((n,), 0.9, jnp.float32),
        sh=jnp.asarray(rng.uniform(0.2, 0.8, (n, 1, 3)), jnp.float32))
    tile = 16
    n_cat = n_categories(rig.max_disparity_px(), tile)
    tiles_x_r = -(-cam.width // tile)
    wide = dc.replace(cam, width=(tiles_x_r + n_cat - 1) * tile)
    splats = project(g, rig, wide)
    ranks = depth_ranks(splats)
    cfg = BinConfig(tile=tile, max_pairs=1 << 14, list_len=list_len)
    left = bin_left(splats, wide.width, cam.height, cfg, ranks)
    return rig, splats, ranks, left, cfg, n_cat


def test_merge_overflow_surfaced_by_core_and_kernel():
    rig, splats, ranks, left, cfg, n_cat = _epipolar_scene()
    cam = rig.left
    assert not bool(left.overflow)            # binning is NOT the bottleneck
    merged = stereo_lists(left, splats, ranks, tile=cfg.tile,
                          width=cam.width, n_cat=n_cat)
    assert bool(merged.overflow)              # ...the merge is
    # the kernel surfaces the same flag (previously silent truncation)
    for use_pallas in (True, False):
        mk = ops.stereo_merge(left, splats, ranks, tile=cfg.tile,
                              width=cam.width, n_cat=n_cat,
                              use_pallas=use_pallas)
        assert bool(mk.overflow), use_pallas
        np.testing.assert_array_equal(np.asarray(mk.counts),
                                      np.asarray(merged.counts))


def test_merge_no_overflow_with_capacity():
    rig, splats, ranks, left, cfg, n_cat = _epipolar_scene(list_len=128)
    merged = stereo_lists(left, splats, ranks, tile=cfg.tile,
                          width=rig.left.width, n_cat=n_cat)
    assert not bool(merged.overflow)
    mk = ops.stereo_merge(left, splats, ranks, tile=cfg.tile,
                          width=rig.left.width, n_cat=n_cat, use_pallas=True)
    assert not bool(mk.overflow)
    np.testing.assert_array_equal(np.asarray(mk.lists),
                                  np.asarray(merged.lists))


# -- (d) per-client foveated τ ------------------------------------------------


def test_foveated_tau_fewer_cut_nodes(small_tree):
    """A client with a looser (larger) τ must receive strictly fewer cut
    nodes than a co-located client with a tight τ."""
    cfg = SessionConfig(tau=32.0, cut_budget=8192)
    cams = np.asarray([[30, 30, 2], [30, 30, 2]], np.float32)
    taus = np.asarray([32.0, 96.0], np.float32)
    state = svc.service_init(small_tree, cfg, 2)
    state, stats, _delta = svc.service_sync_vmapped(
        small_tree, cfg, state, cams, FOCAL, bytes_per_g=30.0, taus=taus)
    tight, loose = np.asarray(stats.cut_size)
    assert loose < tight, (tight, loose)


def test_foveated_tau_bitwise_vs_scalar_search(small_tree):
    """Each client of a mixed-τ batch must match the scalar-τ search run at
    its own threshold — for the vmapped AND the pooled scheduler."""
    b = 3
    taus = np.asarray([24.0, 48.0, 96.0], np.float32)
    cams = np.asarray([[30, 30, 2], [34, 31, 2], [28, 36, 2]], np.float32)
    m = small_tree.meta
    states = ls.TemporalState.initial_batched(m.Ns, m.S, b)
    cut, _ = ls.batched_temporal_search(small_tree, states, cams,
                                        jnp.float32(FOCAL), jnp.asarray(taus))
    masks = np.asarray(ls.batched_cut_mask(cut, small_tree))
    for i in range(b):
        ref, _ = ls.full_search(small_tree, cams[i], jnp.float32(FOCAL),
                                jnp.float32(taus[i]))
        assert (masks[i] == np.asarray(ref.mask(small_tree))).all(), i

    cfg = SessionConfig(tau=1.0, cut_budget=8192)  # cfg.tau must be ignored
    rng = np.random.default_rng(0)
    s_pool = svc.service_init(small_tree, cfg, b)
    s_vmap = svc.service_init(small_tree, cfg, b)
    walk = cams.copy()
    for _ in range(4):
        s_pool, _st, _d = svc.service_sync_pooled(
            small_tree, cfg, s_pool, walk, FOCAL, bytes_per_g=30.0, taus=taus)
        s_vmap, _sv, _d2 = svc.service_sync_vmapped(
            small_tree, cfg, s_vmap, walk, FOCAL, bytes_per_g=30.0, taus=taus)
        assert (np.asarray(s_pool.cut_gids)
                == np.asarray(s_vmap.cut_gids)).all()
        walk = walk + rng.normal(0, 6.0, walk.shape).astype(np.float32)


# -- (e) LoD-cut kernel parity with the vmapped service sweep -----------------


def test_lod_cut_kernel_parity_with_vmapped_service_sweep(small_tree):
    """Interpret-mode `kernels.lod_cut` vs the vmapped XLA sweep that
    `lod_service` runs: per client (own camera, own foveated τ), the kernel
    must reproduce the service's fresh slab cuts bit-for-bit."""
    b = 3
    cams = np.asarray([[250, 250, 120], [40, 40, 2], [120, 80, 10]],
                      np.float32)
    taus = np.asarray([48.0, 64.0, 32.0], np.float32)
    m = small_tree.meta
    states = ls.TemporalState.initial_batched(m.Ns, m.S, b)
    # first frame ⇒ every slab freshly swept by the vmapped XLA path
    cut, _ = ls.batched_temporal_search(small_tree, states, cams,
                                        jnp.float32(FOCAL), jnp.asarray(taus))
    _top, rpe, _stale = ls.batched_top_and_staleness(
        small_tree, states, cams, jnp.float32(FOCAL), jnp.asarray(taus))
    for i in range(b):
        cut_p, rexp_p, _rho = ops.lod_slab_sweep(
            small_tree, jnp.asarray(cams[i]), jnp.float32(FOCAL),
            jnp.float32(taus[i]), rpe[i], use_pallas=True)
        np.testing.assert_array_equal(np.asarray(cut_p),
                                      np.asarray(cut.slab_cut[i]), err_msg=str(i))
        np.testing.assert_array_equal(np.asarray(rexp_p),
                                      np.asarray(cut.root_expand[i]))
    # and the pooled primitive (mixed clients in one dispatch) agrees too
    sel_b = np.repeat(np.arange(b), m.Ns)
    sel_s = np.tile(np.arange(m.Ns), b)
    f_cut, f_rexp, _f_rho = ls.sweep_slab_camera_pairs(
        small_tree.slab_mu()[sel_s], small_tree.slab_size()[sel_s],
        small_tree.slab_parent[sel_s], small_tree.slab_level[sel_s],
        small_tree.slab_is_leaf[sel_s], small_tree.slab_valid[sel_s],
        rpe[sel_b, sel_s], jnp.asarray(cams)[sel_b],
        jnp.float32(FOCAL), jnp.asarray(taus)[sel_b], m.slab_max_depth)
    np.testing.assert_array_equal(
        np.asarray(f_cut).reshape(b, m.Ns, m.S), np.asarray(cut.slab_cut))


# -- (f) fleet render step in the service -------------------------------------


def test_service_render_step_matches_direct_render(small_tree):
    cfg = SessionConfig(tau=32.0, cut_budget=4096)
    b = 3
    cams = np.asarray([[30, 30, 2], [40, 32, 3], [26, 44, 2]], np.float32)
    service = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled")
    service.sync(cams)
    rigs = [_rig_at(c, np.asarray(c) + [10, 10, -0.2], width=64, height=48)
            for c in cams]
    il, ir, stats = service.render_fallback(rigs, list_len=128,
                                            max_pairs=1 << 15)
    assert il.shape == (b, 48, 64, 3) and ir.shape == (b, 48, 64, 3)
    rcfg = rnd.RenderConfig.for_fleet(rigs, tile=16, list_len=128,
                                      max_pairs=1 << 15)
    for i in range(b):
        gids = service.client_cut(i)
        queue = small_tree.gaussians.slice_rows(jnp.clip(gids, 0))
        queue = dc.replace(queue, opacity=jnp.where(gids >= 0, queue.opacity,
                                                    0.0))
        plan = rnd.build_plan(queue, rigs[i], rcfg)
        ref_l, ref_r, _ = rnd.render_stereo(plan, rcfg)
        np.testing.assert_array_equal(np.asarray(il[i]), np.asarray(ref_l))
        np.testing.assert_array_equal(np.asarray(ir[i]), np.asarray(ref_r))
    assert (np.asarray(stats.shared_preprocess) > 0).all()


def test_render_fallback_caches_config_and_stack(small_tree):
    """Repeated fleet renders must reuse the cached RenderConfig + stacked
    rig pytree (no per-call for_fleet/stack_rigs rebuild) and still produce
    identical frames; a new rig signature gets its own config."""
    cfg = SessionConfig(tau=32.0, cut_budget=4096)
    b = 2
    cams = np.asarray([[30, 30, 2], [40, 32, 3]], np.float32)
    service = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled")
    service.sync(cams)
    rigs = [_rig_at(c, np.asarray(c) + [10, 10, -0.2], width=64, height=48)
            for c in cams]
    il0, ir0, _ = service.render_fallback(rigs, list_len=128,
                                          max_pairs=1 << 15)
    assert len(service._rcfg_cache) == 1 and len(service._stack_cache) == 1
    (rcfg0,) = service._rcfg_cache.values()
    (stack0,) = service._stack_cache.values()
    il1, ir1, _ = service.render_fallback(rigs, list_len=128,
                                          max_pairs=1 << 15)
    # same signature: both caches hit (same objects, no growth)
    assert len(service._rcfg_cache) == 1 and len(service._stack_cache) == 1
    assert next(iter(service._rcfg_cache.values())) is rcfg0
    assert next(iter(service._stack_cache.values())) is stack0
    np.testing.assert_array_equal(np.asarray(il0), np.asarray(il1))
    np.testing.assert_array_equal(np.asarray(ir0), np.asarray(ir1))
    # a different static signature (resolution) adds a second entry
    rigs2 = [_rig_at(c, np.asarray(c) + [10, 10, -0.2], width=32, height=32)
             for c in cams]
    service.render_fallback(rigs2, list_len=128, max_pairs=1 << 15)
    assert len(service._rcfg_cache) == 2 and len(service._stack_cache) == 2
