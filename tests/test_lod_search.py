"""Streaming + temporal-aware LoD search: bit-accuracy vs the numpy oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import lod_search as ls

FOCAL = 1400.0


def _run_full(tree, cam, tau):
    cut, state = ls.full_search(tree, np.asarray(cam, np.float32),
                                jnp.float32(FOCAL), jnp.float32(tau))
    return np.asarray(cut.mask(tree)), state


@pytest.mark.parametrize("tau", [2.0, 16.0, 64.0, 256.0])
@pytest.mark.parametrize("cam", [[20, 20, 1.7], [300, 300, 150], [-100, 50, 30]])
def test_full_search_matches_oracle(small_tree, tau, cam):
    got, _ = _run_full(small_tree, cam, tau)
    ref = ls.reference_search_np(small_tree, np.asarray(cam, np.float32), FOCAL, tau)
    assert (got == ref).all()


def test_cut_is_antichain_and_maximal(small_tree):
    """No cut node is an ancestor of another; every root-leaf path crosses the
    cut exactly once (fundamental property of an LoD cut)."""
    cam = np.array([250, 250, 120], np.float32)
    got, _ = _run_full(small_tree, cam, 64.0)
    parent = ls.global_parent_np(small_tree)
    valid = np.asarray(small_tree.valid_mask())
    # walk up from every cut node: no ancestor may be in the cut
    idxs = np.where(got)[0]
    for i in idxs[:: max(1, len(idxs) // 64)]:
        p = parent[i]
        while p >= 0:
            assert not got[p]
            p = parent[p]
    # walk up from every leaf: exactly one cut crossing
    level = ls.global_level_np(small_tree)
    is_leaf = np.concatenate([
        np.asarray(small_tree.top_is_leaf),
        np.asarray(small_tree.slab_is_leaf).reshape(-1)])
    leaves = np.where(is_leaf & valid)[0]
    for i in leaves[:: max(1, len(leaves) // 64)]:
        crossings, p = int(got[i]), parent[i]
        while p >= 0:
            crossings += int(got[p])
            p = parent[p]
        assert crossings == 1


def test_temporal_bit_accurate_walk(small_tree):
    rng = np.random.default_rng(0)
    cam = np.array([20, 20, 1.7], np.float32)
    _, state = _run_full(small_tree, cam, 24.0)
    for _ in range(25):
        cam = cam + rng.normal(0, 0.05, 3).astype(np.float32)
        cut, state = ls.temporal_search(small_tree, state, cam,
                                        jnp.float32(FOCAL), jnp.float32(24.0))
        ref = ls.reference_search_np(small_tree, cam, FOCAL, 24.0)
        assert (np.asarray(cut.mask(small_tree)) == ref).all()


def test_temporal_bit_accurate_flyout(small_tree):
    """Fly from street level to altitude — crosses LoD boundaries, forcing
    resweeps; accuracy must hold on the resweep path too."""
    cam = np.array([40, 40, 2], np.float32)
    cut, state = ls.full_search(small_tree, cam, jnp.float32(FOCAL), jnp.float32(64.0))
    total_resweeps = 0
    for _ in range(40):
        cam = cam + np.array([4, 4, 60], np.float32)
        cut, state = ls.temporal_search(small_tree, state, cam,
                                        jnp.float32(FOCAL), jnp.float32(64.0))
        ref = ls.reference_search_np(small_tree, cam, FOCAL, 64.0)
        assert (np.asarray(cut.mask(small_tree)) == ref).all()
        total_resweeps += int(np.asarray(cut.resweep).sum())
    assert total_resweeps > 0  # the reuse bound must actually have been crossed


def test_hybrid_matches_jit_variant(small_tree):
    rng = np.random.default_rng(1)
    cam = np.array([30, 30, 2], np.float32)
    _, s1 = _run_full(small_tree, cam, 48.0)
    _, s2 = _run_full(small_tree, cam, 48.0)
    for _ in range(12):
        cam = cam + rng.normal(0, 8.0, 3).astype(np.float32)
        c1, s1 = ls.temporal_search(small_tree, s1, cam,
                                    jnp.float32(FOCAL), jnp.float32(48.0))
        c2, s2 = ls.temporal_search_hybrid(small_tree, s2, cam, FOCAL, 48.0)
        assert (np.asarray(c1.mask(small_tree)) == np.asarray(c2.mask(small_tree))).all()


def test_nodes_touched_monotonicity(small_tree):
    """Temporal search must touch no more nodes than the full sweep."""
    cam = np.array([20, 20, 1.7], np.float32)
    cut_full, state = ls.full_search(small_tree, cam, jnp.float32(FOCAL),
                                     jnp.float32(24.0))
    cut_t, _ = ls.temporal_search(small_tree, state, cam + 0.01,
                                  jnp.float32(FOCAL), jnp.float32(24.0))
    assert int(cut_t.nodes_touched) <= int(cut_full.nodes_touched)


def test_cut_gids_compaction(small_tree):
    cam = np.array([250, 250, 120], np.float32)
    cut, _ = ls.full_search(small_tree, cam, jnp.float32(FOCAL), jnp.float32(64.0))
    n = int(cut.count())
    gids, count, overflow = ls.cut_gids(cut, small_tree, budget=n + 8)
    assert int(count) == n and not bool(overflow)
    g = np.asarray(gids)
    assert (g[:n] >= 0).all() and (g[n:] == -1).all()
    assert (np.diff(g[:n]) > 0).all()  # sorted unique
    mask = np.asarray(cut.mask(small_tree))
    assert mask[g[:n]].all()


@settings(max_examples=15, deadline=None)
@given(
    tau=st.floats(4.0, 512.0),
    x=st.floats(-200.0, 400.0),
    y=st.floats(-200.0, 400.0),
    z=st.floats(1.0, 500.0),
)
def test_property_full_search_matches_oracle(tiny_tree, tau, x, y, z):
    cam = np.array([x, y, z], np.float32)
    cut, _ = ls.full_search(tiny_tree, cam, jnp.float32(FOCAL), jnp.float32(tau))
    ref = ls.reference_search_np(tiny_tree, cam, FOCAL, tau)
    assert (np.asarray(cut.mask(tiny_tree)) == ref).all()
