"""Streaming + temporal-aware LoD search: bit-accuracy vs the numpy oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import lod_search as ls

FOCAL = 1400.0


def _run_full(tree, cam, tau):
    cut, state = ls.full_search(tree, np.asarray(cam, np.float32),
                                jnp.float32(FOCAL), jnp.float32(tau))
    return np.asarray(cut.mask(tree)), state


@pytest.mark.parametrize("tau", [2.0, 16.0, 64.0, 256.0])
@pytest.mark.parametrize("cam", [[20, 20, 1.7], [300, 300, 150], [-100, 50, 30]])
def test_full_search_matches_oracle(small_tree, tau, cam):
    got, _ = _run_full(small_tree, cam, tau)
    ref = ls.reference_search_np(small_tree, np.asarray(cam, np.float32), FOCAL, tau)
    assert (got == ref).all()


def test_cut_is_antichain_and_maximal(small_tree):
    """No cut node is an ancestor of another; every root-leaf path crosses the
    cut exactly once (fundamental property of an LoD cut)."""
    cam = np.array([250, 250, 120], np.float32)
    got, _ = _run_full(small_tree, cam, 64.0)
    parent = ls.global_parent_np(small_tree)
    valid = np.asarray(small_tree.valid_mask())
    # walk up from every cut node: no ancestor may be in the cut
    idxs = np.where(got)[0]
    for i in idxs[:: max(1, len(idxs) // 64)]:
        p = parent[i]
        while p >= 0:
            assert not got[p]
            p = parent[p]
    # walk up from every leaf: exactly one cut crossing
    level = ls.global_level_np(small_tree)
    is_leaf = np.concatenate([
        np.asarray(small_tree.top_is_leaf),
        np.asarray(small_tree.slab_is_leaf).reshape(-1)])
    leaves = np.where(is_leaf & valid)[0]
    for i in leaves[:: max(1, len(leaves) // 64)]:
        crossings, p = int(got[i]), parent[i]
        while p >= 0:
            crossings += int(got[p])
            p = parent[p]
        assert crossings == 1


def test_temporal_bit_accurate_walk(small_tree):
    rng = np.random.default_rng(0)
    cam = np.array([20, 20, 1.7], np.float32)
    _, state = _run_full(small_tree, cam, 24.0)
    for _ in range(25):
        cam = cam + rng.normal(0, 0.05, 3).astype(np.float32)
        cut, state = ls.temporal_search(small_tree, state, cam,
                                        jnp.float32(FOCAL), jnp.float32(24.0))
        ref = ls.reference_search_np(small_tree, cam, FOCAL, 24.0)
        assert (np.asarray(cut.mask(small_tree)) == ref).all()


def test_temporal_bit_accurate_flyout(small_tree):
    """Fly from street level to altitude — crosses LoD boundaries, forcing
    resweeps; accuracy must hold on the resweep path too."""
    cam = np.array([40, 40, 2], np.float32)
    cut, state = ls.full_search(small_tree, cam, jnp.float32(FOCAL), jnp.float32(64.0))
    total_resweeps = 0
    for _ in range(40):
        cam = cam + np.array([4, 4, 60], np.float32)
        cut, state = ls.temporal_search(small_tree, state, cam,
                                        jnp.float32(FOCAL), jnp.float32(64.0))
        ref = ls.reference_search_np(small_tree, cam, FOCAL, 64.0)
        assert (np.asarray(cut.mask(small_tree)) == ref).all()
        total_resweeps += int(np.asarray(cut.resweep).sum())
    assert total_resweeps > 0  # the reuse bound must actually have been crossed


def test_hybrid_matches_jit_variant(small_tree):
    rng = np.random.default_rng(1)
    cam = np.array([30, 30, 2], np.float32)
    _, s1 = _run_full(small_tree, cam, 48.0)
    _, s2 = _run_full(small_tree, cam, 48.0)
    for _ in range(12):
        cam = cam + rng.normal(0, 8.0, 3).astype(np.float32)
        c1, s1 = ls.temporal_search(small_tree, s1, cam,
                                    jnp.float32(FOCAL), jnp.float32(48.0))
        c2, s2 = ls.temporal_search_hybrid(small_tree, s2, cam, FOCAL, 48.0)
        assert (np.asarray(c1.mask(small_tree)) == np.asarray(c2.mask(small_tree))).all()


def test_nodes_touched_monotonicity(small_tree):
    """Temporal search must touch no more nodes than the full sweep."""
    cam = np.array([20, 20, 1.7], np.float32)
    cut_full, state = ls.full_search(small_tree, cam, jnp.float32(FOCAL),
                                     jnp.float32(24.0))
    cut_t, _ = ls.temporal_search(small_tree, state, cam + 0.01,
                                  jnp.float32(FOCAL), jnp.float32(24.0))
    assert int(cut_t.nodes_touched) <= int(cut_full.nodes_touched)


def test_cut_gids_compaction(small_tree):
    cam = np.array([250, 250, 120], np.float32)
    cut, _ = ls.full_search(small_tree, cam, jnp.float32(FOCAL), jnp.float32(64.0))
    n = int(cut.count())
    gids, count, overflow = ls.cut_gids(cut, small_tree, budget=n + 8)
    assert int(count) == n and not bool(overflow)
    g = np.asarray(gids)
    assert (g[:n] >= 0).all() and (g[n:] == -1).all()
    assert (np.diff(g[:n]) > 0).all()  # sorted unique
    mask = np.asarray(cut.mask(small_tree))
    assert mask[g[:n]].all()


@settings(max_examples=15, deadline=None)
@given(
    tau=st.floats(4.0, 512.0),
    x=st.floats(-200.0, 400.0),
    y=st.floats(-200.0, 400.0),
    z=st.floats(1.0, 500.0),
)
def test_property_full_search_matches_oracle(tiny_tree, tau, x, y, z):
    cam = np.array([x, y, z], np.float32)
    cut, _ = ls.full_search(tiny_tree, cam, jnp.float32(FOCAL), jnp.float32(tau))
    ref = ls.reference_search_np(tiny_tree, cam, FOCAL, tau)
    assert (np.asarray(cut.mask(tiny_tree)) == ref).all()


# -- the shared bounded-recompilation bucket policy ---------------------------


def _pow2_ceil(n: int) -> int:
    b = 1
    while b < n:
        b <<= 1
    return b


@settings(max_examples=40, deadline=None)
@given(n=st.integers(0, 1 << 20), cap=st.integers(1, 1 << 20))
def test_property_pow2_bucket(n, cap):
    """pow2_bucket is the ONE bucket policy every host-driven scheduler
    shares; pin its algebra: the result is the next power of two (clamped to
    the cap), covers n whenever the cap allows, is monotone in n, and is a
    fixed point of itself (re-bucketing a bucket never grows it)."""
    b = ls.pow2_bucket(n, cap)
    want = min(_pow2_ceil(max(n, 1)), cap)
    assert b == max(1, want)
    # power of two unless the (possibly non-pow2) cap clamped it
    assert (b & (b - 1)) == 0 or b == cap
    assert 1 <= b <= max(cap, 1)
    if _pow2_ceil(max(n, 1)) <= cap:
        assert b >= n  # the bucket really holds n items
    # monotone in n
    assert ls.pow2_bucket(max(n - 1, 0), cap) <= b
    assert b <= ls.pow2_bucket(n + 1, cap)
    # idempotent
    assert ls.pow2_bucket(b, cap) == b


def test_pow2_bucket_is_the_policy_of_all_host_schedulers(
        small_tree, monkeypatch):
    """Regression-pin the SHARED policy: the four host-driven schedulers —
    the hybrid stale-slab sweep, the service's pooled (client, slab)
    compaction, the Δ-union encode width, and the fleet occupied-tile
    render pooling — must all route their bucket choice through
    ls.pow2_bucket (and dispatch exactly the bucket it returns)."""
    import jax

    from repro.core.pipeline import SessionConfig
    from repro.serve import delta_path as dp
    from repro.serve import lod_service as svc
    from repro import render as rnd
    from repro.render import batched as rb

    calls = []
    real = ls.pow2_bucket

    def recording(n, cap):
        b = real(n, cap)
        calls.append((int(n), int(cap), int(b)))
        return b

    monkeypatch.setattr(ls, "pow2_bucket", recording)
    cam = np.array([30.0, 30.0, 2.0], np.float32)

    # (1) host-driven hybrid search (lod_search module-global lookup)
    _, state = ls.full_search(small_tree, cam, jnp.float32(FOCAL),
                              jnp.float32(48.0))
    calls.clear()
    cut, _ = ls.temporal_search_hybrid(small_tree, state, cam + 50.0,
                                       FOCAL, 48.0)
    n_stale = int(np.asarray(cut.resweep).sum())
    assert n_stale > 0 and calls == [(n_stale, small_tree.meta.Ns,
                                      real(n_stale, small_tree.meta.Ns))]

    # (2) pooled (client, slab) compaction + (3) Δ-union encode width
    cfg = SessionConfig(tau=32.0, cut_budget=4096)
    codec, bpg = svc.session_wire_format(small_tree, cfg)
    st = svc.service_init(small_tree, cfg, 2)
    calls.clear()
    st, stats, batch = svc.service_sync_pooled(
        small_tree, cfg, st, np.stack([cam, cam + 3.0]), FOCAL,
        bytes_per_g=bpg, codec=codec, dedup=True,
        delta_budget=small_tree.n_pad)
    pool_n = int(np.asarray(stats.resweeps).sum())
    union_n = int(batch.n_union)
    assert (pool_n, 2 * small_tree.meta.Ns,
            real(pool_n, 2 * small_tree.meta.Ns)) in calls
    assert (union_n, small_tree.n_pad,
            real(union_n, small_tree.n_pad)) in calls
    assert len(calls) == 2

    # (4) fleet occupied-tile pooling on the pooled render path
    from repro.core.camera import StereoRig, make_camera
    from repro.core.gaussians import random_gaussians
    rig = StereoRig(left=make_camera([0, -16, 2], [0, 0, 0], focal_px=200.0,
                                     width=48, height=32, near=0.25),
                    baseline=0.06)
    queues = jax.tree_util.tree_map(
        lambda a: jnp.stack([a, a]),
        random_gaussians(np.random.default_rng(0), 64, sh_degree=1,
                         extent=10.0))
    rigs = rnd.stack_rigs([rig, rig])
    rcfg = rnd.RenderConfig.for_rig(rig, tile=16, list_len=64,
                                    max_pairs=1 << 12)
    calls.clear()
    rb.batched_render_stereo(queues, rigs, rcfg, path="pooled")
    assert len(calls) == 1
    occ, cap, got = calls[0]
    assert occ > 0 and got == real(occ, cap)
