"""Loop-aware HLO cost analyzer: validated against unrolled-loop ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


XS = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_scan_flops_match_unrolled():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    def f_unroll(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    a = analyze(_compile(f_scan, XS, XS).as_text())
    b = analyze(_compile(f_unroll, XS, XS).as_text())
    assert a["flops"] == b["flops"] == 10 * 2 * 128 ** 3


def test_nested_scan_flops():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    a = analyze(_compile(f, XS, XS).as_text())
    assert a["flops"] == 15 * 2 * 128 ** 3


def test_gqa_einsum_flops():
    def f(q, k):
        return jnp.einsum("bqhgd,bchd->bqhgc", q, k)

    q = jax.ShapeDtypeStruct((2, 16, 4, 3, 8), jnp.float32)
    k = jax.ShapeDtypeStruct((2, 32, 4, 8), jnp.float32)
    a = analyze(_compile(f, q, k).as_text())
    # out (2,16,4,3,32) × contracted 8 × 2
    assert a["flops"] == pytest.approx(2 * 16 * 4 * 3 * 32 * 8 * 2, rel=0.01)


def test_bytes_scale_with_trip_count():
    def mk(n):
        def f(x):
            def body(c, _):
                return jnp.tanh(c * 2.0 + 1.0), None
            out, _ = jax.lax.scan(body, x, None, length=n)
            return out
        return f

    a5 = analyze(_compile(mk(5), XS).as_text())["hbm_bytes"]
    a50 = analyze(_compile(mk(50), XS).as_text())["hbm_bytes"]
    assert 8 < a50 / a5 < 12  # ≈10× (loop-invariant part amortized)


def test_dtype_sizes():
    def f(x):
        return x.astype(jnp.bfloat16) @ x.astype(jnp.bfloat16).T

    a = analyze(_compile(f, XS).as_text())
    assert a["flops"] == 2 * 128 ** 3
