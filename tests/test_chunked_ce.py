"""Chunked cross-entropy (§Perf A4): value and gradient ≡ full-logits CE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_ce


def _full_ce(x, w, t):
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, t[..., None], axis=-1)[..., 0].mean()


@pytest.mark.parametrize("b,s,d,v,chunk", [
    (2, 32, 16, 100, 8),
    (1, 64, 8, 257, 16),
    (3, 24, 12, 50, 24),   # chunk == s
    (2, 30, 8, 64, 7),     # indivisible → fallback path
])
def test_chunked_ce_matches_full(b, s, d, v, chunk):
    rng = np.random.default_rng(b * s + v)
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    t = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    np.testing.assert_allclose(float(chunked_ce(x, w, t, seq_chunk=chunk)),
                               float(_full_ce(x, w, t)), rtol=1e-5)
    g1 = jax.grad(lambda xx: chunked_ce(xx, w, t, seq_chunk=chunk))(x)
    g2 = jax.grad(lambda xx: _full_ce(xx, w, t))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-6)
    gw1 = jax.grad(lambda ww: chunked_ce(x, ww, t, seq_chunk=chunk))(w)
    gw2 = jax.grad(lambda ww: _full_ce(x, ww, t))(w)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                               rtol=1e-4, atol=1e-6)


def test_chunked_ce_bf16_inputs():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(8, 40)), jnp.bfloat16)
    t = jnp.asarray(rng.integers(0, 40, (2, 16)), jnp.int32)
    out = chunked_ce(x, w, t, seq_chunk=4)
    assert out.dtype == jnp.float32 and bool(jnp.isfinite(out))
