"""`hypothesis` shim so property tests run with or without the dependency.

When `hypothesis` is installed the real `given` / `settings` / `st` are
re-exported unchanged. When it is not, a deterministic fallback expands each
`@given(...)` into a `pytest.mark.parametrize` over seeded examples (the seed
is derived from the test name, so runs are reproducible and independent of
collection order). The fallback supports exactly the strategy surface our
tests use: `st.floats(lo, hi)` and `st.integers(lo, hi)`.

This keeps the tier-1 suite green on the minimal container image while still
getting full randomized coverage wherever `hypothesis` is available
(see requirements-dev.txt).
"""

from __future__ import annotations

import importlib.util
import zlib

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st  # noqa: F401
else:
    import numpy as np
    import pytest

    FALLBACK_EXAMPLES = 10  # seeded examples per property test

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: "np.random.Generator"):
            return self._draw_fn(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value, max_value, **_kw):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    st = _Strategies()

    def settings(**_kw):
        """No-op in the fallback (example count is FALLBACK_EXAMPLES)."""
        return lambda fn: fn

    def given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            seed = zlib.crc32(fn.__name__.encode())
            cases = []
            for i in range(FALLBACK_EXAMPLES):
                rng = np.random.default_rng([seed, i])
                cases.append(tuple(strategies[n].draw(rng) for n in names))
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
