"""Δcut codec: roundtrip bounds, VQ correctness, wire-size accounting."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as comp
from repro.core.gaussians import random_gaussians


@pytest.fixture(scope="module")
def scene():
    rng = np.random.default_rng(0)
    return random_gaussians(rng, 4096, sh_degree=2, extent=100.0)


@pytest.fixture(scope="module")
def codec(scene):
    return comp.fit_codec(scene, k_codes=256, iters=6, seed=0)


def test_roundtrip_geometry_bounds(scene, codec):
    out = comp.roundtrip(codec, scene)
    pos_err = np.abs(np.asarray(out.mu - scene.mu))
    rng = np.asarray(codec.pos_hi - codec.pos_lo)
    assert (pos_err <= rng / 65535.0).all()  # within 1 LSB
    ls_err = np.abs(np.asarray(out.log_scale - scene.log_scale))
    srange = float(codec.scale_hi - codec.scale_lo)
    assert (ls_err <= srange / 65535.0 + 1e-6).all()
    op_err = np.abs(np.asarray(out.opacity - scene.opacity))
    assert (op_err <= 1.5 / 65535.0).all()
    # quaternions stay unit and close
    qn = np.linalg.norm(np.asarray(out.quat), axis=1)
    np.testing.assert_allclose(qn, 1.0, atol=1e-3)


def test_dc_color_preserved(scene, codec):
    out = comp.roundtrip(codec, scene)
    # DC band is fp16 — relative error ~1e-3
    np.testing.assert_allclose(np.asarray(out.sh[:, 0, :]),
                               np.asarray(scene.sh[:, 0, :]), rtol=2e-3, atol=2e-3)


def test_vq_reduces_ac_error_vs_zero(scene, codec):
    """The codebook must beat the trivial all-zeros quantizer on AC energy."""
    out = comp.roundtrip(codec, scene)
    ac = np.asarray(scene.sh[:, 1:, :])
    err_vq = np.mean((np.asarray(out.sh[:, 1:, :]) - ac) ** 2)
    err_zero = np.mean(ac ** 2)
    assert err_vq < 0.7 * err_zero


def test_vq_assign_is_nearest(codec):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(512, codec.codebook.shape[1])).astype(np.float32))
    idx = comp.vq_assign_ref(x, codec.codebook)
    d = np.linalg.norm(np.asarray(x)[:, None, :] - np.asarray(codec.codebook)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(idx), d.argmin(1))


def test_wire_bytes(codec):
    bpg = comp.wire_bytes_per_gaussian(codec)
    # dc 6 + code 1 (256 codes) + pos 6 + scale 6 + quat 8 + opa 2
    assert bpg == 6 + 1 + 6 + 6 + 8 + 2


def test_sh_degree0_roundtrip():
    rng = np.random.default_rng(2)
    g = random_gaussians(rng, 128, sh_degree=0)
    codec = comp.fit_codec(g, k_codes=16, iters=2)
    out = comp.roundtrip(codec, g)
    assert out.sh.shape == g.sh.shape
