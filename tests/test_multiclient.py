"""Batched multi-client LoD serving: bit-accuracy of the vmapped search, the
cross-client pooled scheduler, and the functional session core."""

import dataclasses as dc

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core.camera import StereoRig, make_camera
from repro.core.pipeline import (CollaborativeSession, SessionConfig,
                                 cloud_sync_step, idle_step, session_init,
                                 session_step, session_wire_format)
from repro.serve import lod_service as svc

FOCAL = 1400.0
TAU = 32.0


def _client_walks(rng, b, frames, start=(30.0, 30.0, 2.0), step_sigma=4.0):
    """(frames, B, 3) correlated random walks — one headset per column."""
    starts = np.asarray(start, np.float32) + rng.normal(0, 25.0, (b, 3))
    starts[:, 2] = np.abs(starts[:, 2]) + 1.0
    cams = [starts.astype(np.float32)]
    for _ in range(frames - 1):
        cams.append((cams[-1] + rng.normal(0, step_sigma, (b, 3))
                     ).astype(np.float32))
    return np.stack(cams)


# -- (a) vmapped multi-client search vs per-client search + oracle ------------


def test_batched_search_bit_accurate_vs_per_client(small_tree):
    rng = np.random.default_rng(0)
    b, frames = 4, 10
    walks = _client_walks(rng, b, frames)
    m = small_tree.meta
    states = ls.TemporalState.initial_batched(m.Ns, m.S, b)
    for f in range(frames):
        cut, states = ls.batched_temporal_search(
            small_tree, states, walks[f], jnp.float32(FOCAL), jnp.float32(TAU))
        masks = np.asarray(ls.batched_cut_mask(cut, small_tree))
        for i in range(b):
            full, _ = ls.full_search(small_tree, walks[f, i],
                                     jnp.float32(FOCAL), jnp.float32(TAU))
            assert (masks[i] == np.asarray(full.mask(small_tree))).all(), (f, i)
            ref = ls.reference_search_np(small_tree, walks[f, i], FOCAL, TAU)
            assert (masks[i] == ref).all(), (f, i)


def test_batched_search_clients_are_independent(small_tree):
    """A moving client must not disturb a parked client's reuse state."""
    m = small_tree.meta
    b = 2
    parked = np.array([40.0, 40.0, 2.0], np.float32)
    states = ls.TemporalState.initial_batched(m.Ns, m.S, b)
    cams = np.stack([parked, parked + 5.0])
    cut, states = ls.batched_temporal_search(
        small_tree, states, cams, jnp.float32(FOCAL), jnp.float32(TAU))
    rng = np.random.default_rng(1)
    for _ in range(6):
        cams = np.stack([parked, cams[1] + rng.normal(0, 12.0, 3).astype(np.float32)])
        cut, states = ls.batched_temporal_search(
            small_tree, states, cams, jnp.float32(FOCAL), jnp.float32(TAU))
        resweeps = np.asarray(cut.resweep)
        assert resweeps[0].sum() == 0  # parked client fully reuses its cut


# -- (b) cross-client pooled scheduler ≡ sequential hybrid per client ---------


@pytest.mark.parametrize("b", [1, 3, 5])
def test_pooled_scheduler_matches_sequential_hybrid(small_tree, b):
    rng = np.random.default_rng(2)
    frames = 8
    walks = _client_walks(rng, b, frames)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    state = svc.service_init(small_tree, cfg, b)
    seq_states = [ls.TemporalState.initial(small_tree.meta.Ns,
                                           small_tree.meta.S)
                  for _ in range(b)]
    for f in range(frames):
        state, stats, _delta = svc.service_sync_pooled(
            small_tree, cfg, state, walks[f], FOCAL, bytes_per_g=30.0)
        for i in range(b):
            cut, seq_states[i] = ls.temporal_search_hybrid(
                small_tree, seq_states[i], walks[f, i], FOCAL, TAU)
            mask_seq = np.asarray(cut.mask(small_tree))
            gids = np.asarray(state.cut_gids[i])
            mask_pool = np.zeros(small_tree.n_pad, bool)
            mask_pool[gids[gids >= 0]] = True
            assert (mask_pool == mask_seq).all(), (f, i)
            assert int(stats.resweeps[i]) == int(np.asarray(cut.resweep).sum())
            assert int(stats.nodes_touched[i]) == int(cut.nodes_touched)
        # pooled temporal state must equal the stacked sequential states
        for leaf, name in [(state.temporal.slab_cut0, "slab_cut0"),
                           (state.temporal.rho, "rho"),
                           (state.temporal.cam0, "cam0"),
                           (state.temporal.parent_expand0, "parent_expand0")]:
            stacked = np.stack([np.asarray(getattr(seq_states[i], name))
                                for i in range(b)])
            assert (np.asarray(leaf) == stacked).all(), (f, name)


def test_pooled_matches_vmapped_service(small_tree):
    rng = np.random.default_rng(3)
    b, frames = 4, 6
    walks = _client_walks(rng, b, frames)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    s_pool = svc.service_init(small_tree, cfg, b)
    s_vmap = svc.service_init(small_tree, cfg, b)
    for f in range(frames):
        s_pool, st_p, _dp = svc.service_sync_pooled(
            small_tree, cfg, s_pool, walks[f], FOCAL, bytes_per_g=30.0)
        s_vmap, st_v, _dv = svc.service_sync_vmapped(
            small_tree, cfg, s_vmap, walks[f], FOCAL, bytes_per_g=30.0)
        assert (np.asarray(s_pool.cut_gids) == np.asarray(s_vmap.cut_gids)).all()
        assert (np.asarray(st_p.sync_bytes) == np.asarray(st_v.sync_bytes)).all()
        assert (np.asarray(st_p.delta_size) == np.asarray(st_v.delta_size)).all()
        assert (np.asarray(st_p.client_resident)
                == np.asarray(st_v.client_resident)).all()
        # vmapped path sweeps everything; pooled must never touch more
        assert (np.asarray(st_p.nodes_touched)
                <= np.asarray(st_v.nodes_touched)).all()


def test_service_manager_matches_reference_trace(small_tree):
    """Per-client management tables of the batched service must follow the
    straight-line numpy oracle of the paper's table semantics."""
    rng = np.random.default_rng(4)
    b, frames = 3, 10
    walks = _client_walks(rng, b, frames, step_sigma=6.0)
    cfg = SessionConfig(tau=TAU, w_star=4, cut_budget=8192)
    state = svc.service_init(small_tree, cfg, b)
    masks_per_client = [[] for _ in range(b)]
    stats_log = []
    for f in range(frames):
        state, stats, _delta = svc.service_sync_pooled(
            small_tree, cfg, state, walks[f], FOCAL, bytes_per_g=30.0)
        stats_log.append(stats)
        for i in range(b):
            gids = np.asarray(state.cut_gids[i])
            mask = np.zeros(small_tree.n_pad, bool)
            mask[gids[gids >= 0]] = True
            masks_per_client[i].append(mask)
    for i in range(b):
        deltas, residents = mgr.reference_manager_np(
            np.stack(masks_per_client[i]), w_star=cfg.w_star)
        for f in range(frames):
            assert int(stats_log[f].delta_size[i]) == deltas[f], (f, i)
            assert int(stats_log[f].client_resident[i]) == residents[f], (f, i)


# -- (b2) on-device pooled scheduling + dedup + pallas sweep ------------------


def test_pooled_issues_no_host_nonzero(small_tree, monkeypatch):
    """The pooled scheduler must never pull the staleness mask to the host:
    compaction happens on device (the old path called np.nonzero on it)."""
    rng = np.random.default_rng(6)
    b = 3
    walks = _client_walks(rng, b, 5)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    state = svc.service_init(small_tree, cfg, b)

    real_nonzero = np.nonzero

    def _guarded(a, *rest, **k):
        # jax's tracer calls np.nonzero on small python lists internally;
        # only a bool ARRAY argument can be the staleness mask
        if getattr(a, "dtype", None) == np.bool_ and getattr(a, "ndim", 0):
            raise AssertionError("host np.nonzero on the pooled sync path")
        return real_nonzero(a, *rest, **k)

    monkeypatch.setattr(svc.np, "nonzero", _guarded)
    for f in range(5):
        state, stats, _delta = svc.service_sync_pooled(
            small_tree, cfg, state, walks[f], FOCAL, bytes_per_g=30.0)
    assert int(np.asarray(stats.cut_size).sum()) > 0


def test_pooled_dedup_matches_vmapped_dedup(small_tree):
    """With the encode-once tail on, pooled and vmapped schedulers must agree
    on the ENTIRE wire product: union gids, per-client references, encoded
    payload, and the shared-payload byte accounting."""
    rng = np.random.default_rng(7)
    b, frames = 4, 6
    walks = _client_walks(rng, b, frames)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    codec, bpg = session_wire_format(small_tree, cfg)
    budget = small_tree.n_pad
    s_pool = svc.service_init(small_tree, cfg, b)
    s_vmap = svc.service_init(small_tree, cfg, b)
    for f in range(frames):
        s_pool, st_p, d_p = svc.service_sync_pooled(
            small_tree, cfg, s_pool, walks[f], FOCAL, bytes_per_g=bpg,
            codec=codec, dedup=True, delta_budget=budget)
        s_vmap, st_v, d_v = svc.service_sync_vmapped(
            small_tree, cfg, s_vmap, walks[f], FOCAL, bytes_per_g=bpg,
            codec=codec, dedup=True, delta_budget=budget)
        assert (np.asarray(s_pool.cut_gids) == np.asarray(s_vmap.cut_gids)).all()
        assert int(d_p.n_union) == int(d_v.n_union)
        np.testing.assert_array_equal(np.asarray(d_p.union_gids),
                                      np.asarray(d_v.union_gids))
        np.testing.assert_array_equal(np.asarray(d_p.ref_mask),
                                      np.asarray(d_v.ref_mask))
        np.testing.assert_array_equal(np.asarray(d_p.payload.pos_q),
                                      np.asarray(d_v.payload.pos_q))
        np.testing.assert_array_equal(np.asarray(st_p.sync_bytes),
                                      np.asarray(st_v.sync_bytes))
        np.testing.assert_array_equal(np.asarray(st_p.unique_delta),
                                      np.asarray(st_v.unique_delta))
        np.testing.assert_array_equal(np.asarray(st_p.dedup_bytes_saved),
                                      np.asarray(st_v.dedup_bytes_saved))
        # union partition: first-owner counts sum to the union size
        assert int(np.asarray(st_p.unique_delta).sum()) == int(d_p.n_union)


def test_pallas_sweep_impl_bit_parity(small_tree):
    """LodService(sweep_impl="pallas") — the Pallas lod-cut pair kernel wired
    into the pooled bucket sweep — must be bit-identical to the XLA sweep
    AND to the always-sweep vmapped reference, sync after sync (foveated τ
    included)."""
    rng = np.random.default_rng(8)
    b, frames = 3, 6
    walks = _client_walks(rng, b, frames)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    taus = np.asarray([24.0, 48.0, 96.0], np.float32)
    mk = lambda **kw: svc.LodService(small_tree, cfg, b, focal=FOCAL,
                                     taus=taus, **kw)
    s_pal = mk(mode="pooled", sweep_impl="pallas")
    s_xla = mk(mode="pooled", sweep_impl="xla")
    s_ref = mk(mode="vmapped")
    for f in range(frames):
        s_pal.sync(walks[f]); s_xla.sync(walks[f]); s_ref.sync(walks[f])
        np.testing.assert_array_equal(np.asarray(s_pal.state.cut_gids),
                                      np.asarray(s_xla.state.cut_gids),
                                      err_msg=str(f))
        np.testing.assert_array_equal(np.asarray(s_pal.state.cut_gids),
                                      np.asarray(s_ref.state.cut_gids),
                                      err_msg=str(f))
        for name in ("slab_cut0", "rho", "cam0", "root_expand0"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s_pal.state.temporal, name)),
                np.asarray(getattr(s_xla.state.temporal, name)),
                err_msg=f"{f} {name}")
    with pytest.raises(ValueError):
        mk(mode="vmapped", sweep_impl="pallas")


def test_service_dedup_client_payload_roundtrip(small_tree):
    """End-to-end service check: each client's decode of the shared stream
    carries exactly its Δcut rows of this sync."""
    rng = np.random.default_rng(9)
    b = 3
    walks = _client_walks(rng, b, 3)
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    service = svc.LodService(small_tree, cfg, b, focal=FOCAL, dedup=True)
    prev_has = np.asarray(service.state.mgr.client_has).copy()
    for f in range(3):
        stats = service.sync(walks[f])
        for i in range(b):
            ids, _dec = service.client_delta(i)
            got = np.sort(np.asarray(ids)[np.asarray(ids) >= 0])
            gids = np.asarray(service.state.cut_gids[i])
            cut = np.zeros(small_tree.n_pad, bool)
            cut[gids[gids >= 0]] = True
            want = np.where(cut & ~prev_has[i])[0]
            np.testing.assert_array_equal(got, want, err_msg=f"{f}/{i}")
            assert int(stats.delta_size[i]) == len(want)
        prev_has = np.asarray(service.state.mgr.client_has).copy()


# -- (c) functional session core ≡ legacy CollaborativeSession ----------------


def _rig_at(pos, focal_px=200.0):
    cam = make_camera(pos, np.asarray(pos) + [10, 10, -0.2],
                      focal_px=focal_px, width=64, height=48, near=0.2)
    return StereoRig(left=cam, baseline=0.06)


def test_functional_step_matches_legacy_session(small_tree):
    rng = np.random.default_rng(5)
    cfg = SessionConfig(tau=TAU, w=3, w_star=8, cut_budget=8192)
    rig0 = _rig_at([30.0, 30.0, 2.0])
    sess = CollaborativeSession(small_tree, cfg, rig0)
    codec, bytes_per_g = session_wire_format(small_tree, cfg)
    state = session_init(small_tree, cfg)

    pos = np.array([30.0, 30.0, 2.0], np.float32)
    focal = jnp.float32(rig0.left.focal)
    for f in range(12):
        rig = _rig_at(pos)
        legacy_stats, _ = sess.step(rig, render=False)
        state, st = session_step(small_tree, codec, cfg, state, pos, focal,
                                 bytes_per_g)
        assert bool(st.synced) == legacy_stats.synced, f
        assert int(st.cut_size) == legacy_stats.cut_size, f
        assert int(st.delta_size) == legacy_stats.delta_size, f
        assert float(st.sync_bytes) == legacy_stats.sync_bytes, f
        assert int(st.resweeps) == legacy_stats.resweeps, f
        assert int(st.nodes_touched) == legacy_stats.nodes_touched, f
        assert int(st.client_resident) == legacy_stats.client_resident, f
        assert (np.asarray(state.cut_gids)
                == np.asarray(sess.state.cut_gids)).all(), f
        pos = pos + rng.normal(0, 2.0, 3).astype(np.float32)


def test_functional_sync_cadence(small_tree):
    """cloud_sync_step/idle_step compose into the w-frame cadence and keep
    the client holding its full render queue."""
    cfg = SessionConfig(tau=TAU, w=4, cut_budget=8192)
    codec, bytes_per_g = session_wire_format(small_tree, cfg)
    state = session_init(small_tree, cfg)
    pos = np.array([40.0, 40.0, 2.0], np.float32)
    for f in range(9):
        if f % cfg.w == 0:
            state, st = cloud_sync_step(small_tree, codec, cfg, state, pos,
                                        jnp.float32(FOCAL), bytes_per_g)
            assert bool(st.synced)
        else:
            state, st = idle_step(state)
            assert not bool(st.synced)
            assert float(st.sync_bytes) == mgr.POSE_UPLINK_BYTES
        gids = np.asarray(state.cut_gids)
        has = np.asarray(state.client.has)
        assert has[gids[gids >= 0]].all(), f
        pos = pos + 1.0
    assert int(state.frame_index) == 9
    assert int(state.sync_index) == 3
