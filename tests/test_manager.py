"""Runtime Gaussian management: cloud/client consistency, eviction, Δ minimality."""

import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import manager as mgr


def _random_cut_sequence(rng, n, frames, churn=0.05):
    """Cut sequences with paper-like temporal similarity (~95-99% overlap)."""
    cut = rng.random(n) < 0.3
    seq = [cut.copy()]
    for _ in range(frames - 1):
        flip = rng.random(n) < churn
        cut = np.where(flip, ~cut, cut)
        seq.append(cut.copy())
    return np.stack(seq)


def _drive(cuts, w_star):
    n = cuts.shape[1]
    cloud = mgr.ManagerState.initial(n)
    client = mgr.ClientState.initial(n)
    stats = []
    for t, cut in enumerate(cuts):
        cloud, plan = mgr.cloud_sync(cloud, jnp.asarray(cut), jnp.int32(t),
                                     jnp.int32(w_star))
        client = mgr.client_sync(client, plan.delta_data, plan.cut_add,
                                 plan.cut_remove, jnp.int32(t), jnp.int32(w_star))
        stats.append((plan, cloud, client, cut))
    return stats


def test_cloud_client_tables_identical():
    rng = np.random.default_rng(0)
    cuts = _random_cut_sequence(rng, 512, 40)
    for t, (plan, cloud, client, cut) in enumerate(_drive(cuts, w_star=8)):
        assert (np.asarray(cloud.client_has) == np.asarray(client.has)).all(), t
        assert (np.asarray(client.cut) == cut).all(), t


def test_client_always_holds_current_cut():
    rng = np.random.default_rng(1)
    cuts = _random_cut_sequence(rng, 256, 30)
    for plan, cloud, client, cut in _drive(cuts, w_star=4):
        has = np.asarray(client.has)
        assert has[cut].all()  # never render a Gaussian we don't hold


def test_delta_minimality():
    """Δcut must contain exactly the cut members the client lacked."""
    rng = np.random.default_rng(2)
    cuts = _random_cut_sequence(rng, 256, 20)
    n = cuts.shape[1]
    cloud = mgr.ManagerState.initial(n)
    prev_has = np.zeros(n, bool)
    for t, cut in enumerate(cuts):
        cloud, plan = mgr.cloud_sync(cloud, jnp.asarray(cut), jnp.int32(t),
                                     jnp.int32(8))
        expect = cut & ~prev_has
        assert (np.asarray(plan.delta_data) == expect).all()
        prev_has = np.asarray(cloud.client_has)


def test_eviction_after_reuse_window():
    n = 8
    cloud = mgr.ManagerState.initial(n)
    cut0 = np.zeros(n, bool); cut0[0] = True
    empty = np.zeros(n, bool)
    cloud, _ = mgr.cloud_sync(cloud, jnp.asarray(cut0), jnp.int32(0), jnp.int32(3))
    for t in range(1, 4):
        cloud, _ = mgr.cloud_sync(cloud, jnp.asarray(empty), jnp.int32(t), jnp.int32(3))
        assert bool(cloud.client_has[0])  # within window
    cloud, plan = mgr.cloud_sync(cloud, jnp.asarray(empty), jnp.int32(4), jnp.int32(3))
    assert not bool(cloud.client_has[0])  # evicted exactly past w_r*
    assert bool(plan.evicted[0])


def test_matches_reference_trace():
    rng = np.random.default_rng(3)
    cuts = _random_cut_sequence(rng, 300, 25, churn=0.1)
    ref_delta, ref_res = mgr.reference_manager_np(cuts, w_star=5)
    n = cuts.shape[1]
    cloud = mgr.ManagerState.initial(n)
    for t, cut in enumerate(cuts):
        cloud, plan = mgr.cloud_sync(cloud, jnp.asarray(cut), jnp.int32(t),
                                     jnp.int32(5))
        assert int(plan.n_delta) == ref_delta[t]
        assert int(plan.n_resident) == ref_res[t]


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    w_star=st.integers(1, 12),
    churn=st.floats(0.0, 0.4),
)
def test_property_consistency_and_residency(seed, w_star, churn):
    rng = np.random.default_rng(seed)
    cuts = _random_cut_sequence(rng, 128, 15, churn=churn)
    for plan, cloud, client, cut in _drive(cuts, w_star):
        assert (np.asarray(cloud.client_has) == np.asarray(client.has)).all()
        assert np.asarray(client.has)[cut].all()
        # resident set is bounded by everything used within the window
        assert int(plan.n_resident) <= 128


def test_wire_bytes_accounting():
    n = 64
    cloud = mgr.ManagerState.initial(n)
    cut = np.zeros(n, bool); cut[:10] = True
    cloud, plan = mgr.cloud_sync(cloud, jnp.asarray(cut), jnp.int32(0), jnp.int32(8))
    b = float(plan.wire_bytes(bytes_per_gaussian=30.0))
    assert b == 10 * 30.0 + 10 * mgr.ID_BYTES_DELTA + mgr.SYNC_HEADER_BYTES
