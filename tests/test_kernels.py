"""Per-kernel interpret-mode validation vs pure-jnp oracles.

Each kernel is swept over shapes/dtypes per the deliverable: Pallas
(interpret=True on CPU) must allclose (mostly bit-equal) the ref.py oracle."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lod_search as ls
from repro.core.binning import BinConfig, bin_left
from repro.core.camera import StereoRig, make_camera
from repro.core.compression import vq_assign_ref
from repro.core.gaussians import random_gaussians
from repro.core.projection import depth_ranks, project
from repro.core.raster import render_tiles
from repro.core.stereo import n_categories, stereo_lists
from repro.kernels import ops, ref as kref


def _scene(n=300, seed=0, width=96, height=64, focal=200.0):
    rng = np.random.default_rng(seed)
    g = random_gaussians(rng, n, sh_degree=1, extent=5.0)
    cam = make_camera([0, -15, 2], [0, 0, 0], focal_px=focal,
                      width=width, height=height, near=0.25)
    rig = StereoRig(left=cam, baseline=0.06)
    tile = 16
    n_cat = n_categories(rig.max_disparity_px(), tile)
    tiles_x_r = -(-cam.width // tile)
    wide = dataclasses.replace(cam, width=(tiles_x_r + n_cat - 1) * tile)
    splats = project(g, rig, wide)
    ranks = depth_ranks(splats)
    cfg = BinConfig(tile=tile, max_pairs=1 << 14, list_len=64)
    lists = bin_left(splats, wide.width, cam.height, cfg, ranks)
    return g, rig, wide, splats, ranks, lists, cfg


# -- rasterize ---------------------------------------------------------------


@pytest.mark.parametrize("n,seed", [(100, 0), (300, 1), (800, 2)])
def test_rasterize_kernel_vs_oracle(n, seed):
    _g, _rig, wide, splats, ranks, lists, cfg = _scene(n=n, seed=seed)
    entries, counts = ops.gather_entries(lists, splats, "left")
    from repro.kernels.rasterize import rasterize_tiles_pallas
    img_p, hit_p = rasterize_tiles_pallas(entries, counts, tile=cfg.tile,
                                          tiles_x=lists.tiles_x, eps_t=0.0)
    img_r, hit_r = kref.ref_rasterize(entries, counts, tile=cfg.tile,
                                      tiles_x=lists.tiles_x, eps_t=0.0)
    np.testing.assert_array_equal(np.asarray(img_p), np.asarray(img_r))
    np.testing.assert_array_equal(np.asarray(hit_p), np.asarray(hit_r))


def test_rasterize_kernel_matches_core_renderer():
    """Cross-compilation comparison: same math, different program structure —
    XLA CPU FMA contraction differs, so allclose (≤ few ulp), not bitwise.
    (Bitwise equality is asserted kernel-vs-oracle above, where the program
    structure is identical.)"""
    _g, rig, wide, splats, ranks, lists, cfg = _scene()
    cam = rig.left
    img_core, hits_core = render_tiles(lists, splats, width=cam.width,
                                       height=cam.height, tile=cfg.tile, eye="left")
    img_k, hits_k = ops.rasterize(lists, splats, width=cam.width,
                                  height=cam.height, tile=cfg.tile, eye="left")
    np.testing.assert_allclose(np.asarray(img_k), np.asarray(img_core),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(hits_k), np.asarray(hits_core))


def test_rasterize_early_termination_bounded():
    """eps_t early-exit may only perturb pixels by ≤ eps_t in color."""
    _g, rig, wide, splats, ranks, lists, cfg = _scene(n=800, seed=3)
    cam = rig.left
    img0, _ = ops.rasterize(lists, splats, width=cam.width, height=cam.height,
                            tile=cfg.tile, eye="left", eps_t=0.0)
    img1, _ = ops.rasterize(lists, splats, width=cam.width, height=cam.height,
                            tile=cfg.tile, eye="left", eps_t=1e-3)
    assert np.abs(np.asarray(img0) - np.asarray(img1)).max() <= 1e-3 + 1e-6


# -- vq ------------------------------------------------------------------------


@pytest.mark.parametrize("m,kc,d", [(64, 16, 9), (500, 256, 24), (1000, 128, 45)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_vq_kernel(m, kc, d, dtype):
    rng = np.random.default_rng(m + kc)
    x = jnp.asarray(rng.normal(size=(m, d)), dtype)
    cb = jnp.asarray(rng.normal(size=(kc, d)), dtype)
    idx_p = ops.vq_assign(x, cb, use_pallas=True)
    idx_r = vq_assign_ref(x, cb)
    np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_r))


# -- preprocess -----------------------------------------------------------------


@pytest.mark.parametrize("n,sh_degree", [(64, 0), (300, 1), (200, 2)])
def test_preprocess_kernel(n, sh_degree):
    rng = np.random.default_rng(n)
    g = random_gaussians(rng, n, sh_degree=sh_degree, extent=5.0)
    cam = make_camera([0, -15, 2], [0, 0, 0], focal_px=200.0, width=96,
                      height=64, near=0.25)
    rig = StereoRig(left=cam, baseline=0.06)
    wide = dataclasses.replace(cam, width=160)
    s_ref = project(g, rig, wide)
    s_ker = ops.preprocess(g, rig, wide, use_pallas=True)
    for name in ("mean2d", "depth", "conic", "ext", "color_l", "color_r",
                 "opacity", "disparity"):
        np.testing.assert_allclose(np.asarray(getattr(s_ker, name)),
                                   np.asarray(getattr(s_ref, name)),
                                   rtol=2e-5, atol=2e-5, err_msg=name)
    np.testing.assert_array_equal(np.asarray(s_ker.visible),
                                  np.asarray(s_ref.visible))


# -- LoD sweep -------------------------------------------------------------------


def test_lod_sweep_kernel(small_tree):
    cam = np.array([250, 250, 120], np.float32)
    top_expand, _ = ls.top_sweep(small_tree, jnp.asarray(cam), jnp.float32(1400.0),
                                 jnp.float32(64.0))
    rpe = top_expand[small_tree.slab_root_parent_top]
    cut_p, rexp_p, rho_p = ops.lod_slab_sweep(
        small_tree, jnp.asarray(cam), jnp.float32(1400.0), jnp.float32(64.0), rpe,
        use_pallas=True)
    cut_r, rexp_r, rho_r = kref.ref_lod_slab_sweep(
        small_tree.slab_mu(), small_tree.slab_size(), small_tree.slab_parent,
        small_tree.slab_level, small_tree.slab_is_leaf, small_tree.slab_valid,
        rpe, jnp.asarray(cam), jnp.float32(1400.0), jnp.float32(64.0),
        max_depth=small_tree.meta.slab_max_depth)
    np.testing.assert_array_equal(np.asarray(cut_p), np.asarray(cut_r))
    np.testing.assert_array_equal(np.asarray(rexp_p), np.asarray(rexp_r))
    np.testing.assert_allclose(np.asarray(rho_p), np.asarray(rho_r), rtol=1e-6)


# -- stereo merge ------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stereo_merge_kernel(seed):
    g, rig, wide, splats, ranks, lists, cfg = _scene(n=400, seed=seed)
    cam = rig.left
    n_cat = n_categories(rig.max_disparity_px(), cfg.tile)
    right_core = stereo_lists(lists, splats, ranks, tile=cfg.tile,
                              width=cam.width, n_cat=n_cat)
    right_p = ops.stereo_merge(lists, splats, ranks, tile=cfg.tile,
                               width=cam.width, n_cat=n_cat, use_pallas=True)
    right_r = ops.stereo_merge(lists, splats, ranks, tile=cfg.tile,
                               width=cam.width, n_cat=n_cat, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(right_p.lists), np.asarray(right_core.lists))
    np.testing.assert_array_equal(np.asarray(right_r.lists), np.asarray(right_core.lists))
    np.testing.assert_array_equal(np.asarray(right_p.counts), np.asarray(right_core.counts))
    # the merge kernel surfaces its overflow flag (matching TileLists.overflow)
    assert bool(right_p.overflow) == bool(right_core.overflow)
    assert bool(right_r.overflow) == bool(right_core.overflow)


# -- flash attention ----------------------------------------------------------------


@pytest.mark.parametrize("b,h,hkv,lq,lk,d", [
    (1, 4, 4, 64, 64, 32),
    (2, 8, 2, 128, 128, 16),   # GQA
    (1, 4, 1, 96, 96, 32),     # MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, h, hkv, lq, lk, d, causal, window, dtype):
    rng = np.random.default_rng(h * lq + window)
    q = jnp.asarray(rng.normal(size=(b, h, lq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, lk, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, lk, d)), dtype)
    out_p = ops.flash_attention(q, k, v, causal=causal, window=window,
                                use_pallas=True, interpret=True)
    out_r = kref.ref_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32), rtol=tol, atol=tol)
