"""Checkpointing: atomicity, async, keep-k GC, elastic reshard-on-load."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(3, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extras={"note": "x"})
    out = ckpt.restore(str(tmp_path), 7, t)
    _assert_tree_equal(t, out)
    assert ckpt.read_extras(str(tmp_path), 7)["note"] == "x"
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_atomicity_partial_save_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-save: a stale .tmp directory + a step dir without
    # a manifest must both be ignored
    os.makedirs(tmp_path / "step_00000002.tmp")
    os.makedirs(tmp_path / "step_00000003")
    assert ckpt.latest_step(str(tmp_path)) == 1
    out = ckpt.restore(str(tmp_path), 1, t)
    _assert_tree_equal(t, out)


def test_manager_async_and_gc(tmp_path):
    m = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in [10, 20, 30, 40]:
        m.save_async(s, _tree(s))
    m.wait()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000030", "step_00000040"]
    out = m.restore(_tree(40))
    _assert_tree_equal(_tree(40), out)


def test_save_overwrites_same_step(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), 5, t1)
    ckpt.save(str(tmp_path), 5, t2)
    _assert_tree_equal(t2, ckpt.restore(str(tmp_path), 5, t1))


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": t["params"]["b"]},
           "opt": t["opt"]}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_restore_casts_to_manifest_dtype_both_paths(tmp_path):
    """The manifest dtype is authoritative: a leaf file whose on-disk dtype
    drifted (e.g. rewritten by a foreign tool at float64) restores CAST on
    both the plain and the sharded path — the sharded path used to
    device_put the drifted dtype uncast, silently."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    step_dir = tmp_path / "step_00000001"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    entry = next(e for e in manifest["leaves"] if e["key"] == "params/w")
    assert entry["dtype"] == "float32"
    drifted = np.load(step_dir / entry["file"]).astype(np.float64)
    np.save(step_dir / entry["file"], drifted)

    out = ckpt.restore(str(tmp_path), 1, t)
    assert out["params"]["w"].dtype == jnp.float32
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("a", "b"))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    out_sh = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    assert out_sh["params"]["w"].dtype == jnp.float32
    _assert_tree_equal(out, out_sh)


def test_discovery_survives_junk_step_names(tmp_path):
    """`latest_step` / `valid_steps` / manager GC must shrug off junk in
    the checkpoint directory: non-integer `step_*` names, foreign files,
    and `.tmp` leftovers (a stray `step_backup` used to ValueError)."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    ckpt.save(str(tmp_path), 7, t)
    os.makedirs(tmp_path / "step_backup")
    os.makedirs(tmp_path / "step_12abc")
    os.makedirs(tmp_path / "step_00000009.tmp")
    (tmp_path / "notes.txt").write_text("x")
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert ckpt.valid_steps(str(tmp_path)) == [7, 3]
    m = ckpt.CheckpointManager(str(tmp_path), keep=1)
    m._gc()  # must not raise, must not touch the junk
    assert ckpt.valid_steps(str(tmp_path)) == [7]
    assert (tmp_path / "step_backup").is_dir()
    _assert_tree_equal(t, m.restore(_tree()))


def test_manager_async_error_surfaces_on_wait(tmp_path):
    """A failure inside the background save thread must surface as an
    exception on the NEXT wait()/latest() — and clear, so the manager is
    usable afterwards."""
    m = ckpt.CheckpointManager(str(tmp_path), keep=2)
    # extras that cannot be JSON-serialized make save() raise in the worker
    m.save_async(1, _tree(), extras={"bad": object()})
    with pytest.raises(TypeError):
        m.wait()
    m.wait()  # error was consumed, not sticky
    m.save_async(2, _tree(2))
    assert m.latest() == 2
    _assert_tree_equal(_tree(2), m.restore(_tree()))


def test_gc_never_deletes_step_under_concurrent_restore(tmp_path,
                                                        monkeypatch):
    """keep=1 GC racing a restore of an older step: the reader's step is
    protected until the read finishes, then collectable."""
    import threading

    t = _tree()
    m = ckpt.CheckpointManager(str(tmp_path), keep=1)
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, _tree(2))

    in_read, resume = threading.Event(), threading.Event()
    real_restore = ckpt.restore

    def slow_restore(directory, step, like, shardings=None):
        in_read.set()
        assert resume.wait(timeout=30)
        return real_restore(directory, step, like, shardings)

    monkeypatch.setattr(ckpt, "restore", slow_restore)
    result = {}
    reader = threading.Thread(
        target=lambda: result.update(out=m.restore(_tree(), step=1)))
    reader.start()
    assert in_read.wait(timeout=30)
    m._gc()  # would delete step 1 (keep=1) — but a reader holds it
    assert (tmp_path / "step_00000001" / "manifest.json").exists()
    resume.set()
    reader.join(timeout=30)
    _assert_tree_equal(t, result["out"])
    m._gc()  # reader gone: now it is collectable
    assert not (tmp_path / "step_00000001").exists()
    assert (tmp_path / "step_00000002").exists()


def test_elastic_reshard_on_load(tmp_path):
    """Save from one 'mesh', restore with shardings for another (the elastic
    scaling path). Uses the single real device but exercises the API."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    out = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    _assert_tree_equal(t, out)
    for leaf in jax.tree.leaves(out):
        assert leaf.sharding.mesh.axis_names == ("data", "model")
