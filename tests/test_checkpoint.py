"""Checkpointing: atomicity, async, keep-k GC, elastic reshard-on-load."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.asarray(3, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extras={"note": "x"})
    out = ckpt.restore(str(tmp_path), 7, t)
    _assert_tree_equal(t, out)
    assert ckpt.read_extras(str(tmp_path), 7)["note"] == "x"
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_atomicity_partial_save_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-save: a stale .tmp directory + a step dir without
    # a manifest must both be ignored
    os.makedirs(tmp_path / "step_00000002.tmp")
    os.makedirs(tmp_path / "step_00000003")
    assert ckpt.latest_step(str(tmp_path)) == 1
    out = ckpt.restore(str(tmp_path), 1, t)
    _assert_tree_equal(t, out)


def test_manager_async_and_gc(tmp_path):
    m = ckpt.CheckpointManager(str(tmp_path), keep=2)
    for s in [10, 20, 30, 40]:
        m.save_async(s, _tree(s))
    m.wait()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000030", "step_00000040"]
    out = m.restore(_tree(40))
    _assert_tree_equal(_tree(40), out)


def test_save_overwrites_same_step(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), 5, t1)
    ckpt.save(str(tmp_path), 5, t2)
    _assert_tree_equal(t2, ckpt.restore(str(tmp_path), 5, t1))


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": t["params"]["b"]},
           "opt": t["opt"]}
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, bad)


def test_elastic_reshard_on_load(tmp_path):
    """Save from one 'mesh', restore with shardings for another (the elastic
    scaling path). Uses the single real device but exercises the API."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), t)
    out = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    _assert_tree_equal(t, out)
    for leaf in jax.tree.leaves(out):
        assert leaf.sharding.mesh.axis_names == ("data", "model")
