"""Encode-once fleet Δcut dedup (repro.serve.delta_path): per-client decoded
payloads must be bitwise identical to the encode-per-client path across
overlap factors and ragged per-client Δ sizes, codec work must be one batched
encode per sync, and fleet bytes must grow with unique Gaussians, not B."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compression as comp
from repro.core.pipeline import SessionConfig, session_wire_format
from repro.serve import delta_path as dp
from repro.serve import lod_service as svc

FOCAL = 1400.0
TAU = 32.0


def _masks_for_overlap(n: int, b: int, overlap: float, rng,
                       sizes=(600, 350, 150)) -> np.ndarray:
    """(B, N) bool Δ masks with a controlled shared fraction and RAGGED
    per-client sizes (client i requests sizes[i % len] rows, of which
    ~overlap are drawn from one shared pool)."""
    masks = np.zeros((b, n), bool)
    pool = rng.permutation(n)
    shared_pool = pool[: n // 2]
    private_pool = pool[n // 2 :]
    p_off = 0
    for i in range(b):
        k = sizes[i % len(sizes)]
        k_shared = int(round(k * overlap))
        own = shared_pool[:k_shared].tolist()
        own += private_pool[p_off : p_off + (k - k_shared)].tolist()
        p_off += k - k_shared
        masks[i, own] = True
    return masks


@pytest.mark.parametrize("overlap", [0.0, 0.5, 1.0])
def test_dedup_decode_bitwise_matches_per_client(small_tree, overlap):
    rng = np.random.default_rng(11)
    b, n = 3, small_tree.n_pad
    sizes = (600, 600, 600) if overlap == 1.0 else (600, 350, 150)
    if overlap == 1.0:  # identical masks: the fully co-located sync
        one = _masks_for_overlap(n, 1, 1.0, rng, sizes=(600,))
        masks = np.repeat(one, b, axis=0)
    else:
        masks = _masks_for_overlap(n, b, overlap, rng, sizes=sizes)
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    sh_k = small_tree.gaussians.sh.shape[1]
    budget = int(masks.any(axis=0).sum()) + 32

    batch = dp.build_delta_batch(small_tree.gaussians, codec,
                                 jnp.asarray(masks), budget)
    assert not bool(batch.overflow)
    assert int(batch.n_union) == int(masks.any(axis=0).sum())
    ref = dp.encode_per_client(small_tree.gaussians, codec,
                               jnp.asarray(masks), budget)

    for i in range(b):
        ids_u, dec_u = dp.decode_client(codec, batch, sh_k, i)
        ids_u = np.asarray(ids_u)
        sel_u = ids_u >= 0
        ids_r, enc_r = ref[i]
        ids_r = np.asarray(ids_r)
        sel_r = ids_r >= 0
        # same rows, same ascending-gid order
        np.testing.assert_array_equal(ids_u[sel_u], ids_r[sel_r], err_msg=str(i))
        # encoded representation: union rows referenced by this client vs its
        # own unicast stream — bitwise equal, field by field
        enc_u = batch.payload
        for field in ("dc", "code", "pos_q", "scale_q", "quat_q", "opa_q"):
            np.testing.assert_array_equal(
                np.asarray(getattr(enc_u, field))[sel_u],
                np.asarray(getattr(enc_r, field))[sel_r],
                err_msg=f"client {i} field {field}")
        # and so is the decode the client store would ingest
        dec_r = comp.decode(codec, enc_r, sh_k)
        for field in ("mu", "log_scale", "quat", "opacity", "sh"):
            np.testing.assert_array_equal(
                np.asarray(getattr(dec_u, field))[sel_u],
                np.asarray(getattr(dec_r, field))[sel_r],
                err_msg=f"client {i} field {field}")


def test_all_clients_idle_sync(small_tree):
    """The all-idle sync (no client needs anything) must produce an empty,
    well-formed batch."""
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    masks = jnp.zeros((4, small_tree.n_pad), bool)
    batch = dp.build_delta_batch(small_tree.gaussians, codec, masks, 64)
    assert int(batch.n_union) == 0
    assert not bool(batch.overflow)
    assert not np.asarray(batch.ref_mask).any()
    ids, _dec = dp.decode_client(codec, batch,
                                 small_tree.gaussians.sh.shape[1], 2)
    assert (np.asarray(ids) == -1).all()
    assert np.asarray(dp.first_owner_counts(masks)).sum() == 0


def test_union_overflow_flagged(small_tree):
    rng = np.random.default_rng(3)
    masks = _masks_for_overlap(small_tree.n_pad, 2, 0.0, rng,
                               sizes=(100, 80))
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    batch = dp.build_delta_batch(small_tree.gaussians, codec,
                                 jnp.asarray(masks), 64)
    assert bool(batch.overflow)


def test_first_owner_counts_partition_union(small_tree):
    rng = np.random.default_rng(5)
    masks = _masks_for_overlap(small_tree.n_pad, 4, 0.5, rng)
    u = np.asarray(dp.first_owner_counts(jnp.asarray(masks)))
    assert u.sum() == masks.any(axis=0).sum()
    assert (u <= masks.sum(axis=1)).all()


# -- service-level: one codec call per sync, bytes grow with unique ----------


def _count_encodes(monkeypatch):
    calls = {"n": 0}
    real = comp.encode

    def counting_encode(codec, g):
        calls["n"] += 1
        return real(codec, g)

    monkeypatch.setattr(comp, "encode", counting_encode)
    return calls


def test_service_encodes_once_per_sync(small_tree, monkeypatch):
    """B co-located clients: the dedup service runs the codec ONCE per sync;
    the per-client reference path runs it B times."""
    b = 6
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.broadcast_to(np.asarray([40.0, 40.0, 2.0], np.float32),
                           (b, 3)).copy()
    service = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled",
                             dedup=True)
    calls = _count_encodes(monkeypatch)
    service.sync(cams)
    assert calls["n"] == 1
    service.sync(cams + 1.0)
    assert calls["n"] == 2  # still one per sync, B-independent

    masks = np.asarray(service.state.mgr.cut_prev)
    calls["n"] = 0
    dp.encode_per_client(small_tree.gaussians, service.codec,
                         jnp.asarray(masks), 256)
    assert calls["n"] == b

    off = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled",
                         dedup=False)
    calls["n"] = 0
    off.sync(cams)
    assert calls["n"] == 0  # unicast accounting path never touches the codec


def test_colocated_fleet_bytes_grow_with_unique_not_b(small_tree):
    """Identical cameras: fleet downlink = one shared payload + B thin
    framings — total sync_bytes for B clients must equal the single-client
    total plus (B-1) framings, NOT B× the single-client total."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cam = np.asarray([[40.0, 40.0, 2.0]], np.float32)
    b = 8

    s1 = svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True)
    st1 = s1.sync(cam)
    sb = svc.LodService(small_tree, cfg, b, focal=FOCAL, dedup=True)
    stb = sb.sync(np.repeat(cam, b, axis=0))

    total1 = float(np.asarray(st1.sync_bytes).sum())
    totalb = float(np.asarray(stb.sync_bytes).sum())
    ids = float(np.asarray(st1.cut_size)[0])  # first sync: cut_add == cut
    framing = ids * 2 + 64  # ID_BYTES_DELTA * ids + SYNC_HEADER_BYTES
    assert np.isclose(totalb, total1 + (b - 1) * framing, rtol=1e-5), \
        (totalb, total1, framing)
    # payload part is O(unique): far below B x the unicast accounting
    assert totalb < 0.35 * b * total1
    assert int(np.asarray(stb.unique_delta).sum()) == int(sb.last_delta.n_union)
    assert float(np.asarray(stb.dedup_bytes_saved).sum()) > 0.0


def test_service_surfaces_delta_overflow(small_tree):
    """A too-small delta_budget truncates the encode-once stream — the
    service must surface that in ServiceStats, not just on last_delta."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [41.0, 40.0, 2.0]], np.float32)
    tight = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                           delta_budget=64)
    st = tight.sync(cams)
    assert np.asarray(st.delta_overflow).all()
    assert bool(tight.last_delta.overflow)
    ok = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True)
    st = ok.sync(cams)  # default budget bounds the union — never truncates
    assert not np.asarray(st.delta_overflow).any()
