"""Encode-once fleet Δcut dedup (repro.serve.delta_path): per-client decoded
payloads must be bitwise identical to the encode-per-client path across
overlap factors and ragged per-client Δ sizes, codec work must be one batched
encode per sync, and fleet bytes must grow with unique Gaussians, not B."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import compression as comp
from repro.core.pipeline import SessionConfig, session_wire_format
from repro.serve import delta_path as dp
from repro.serve import lod_service as svc

FOCAL = 1400.0
TAU = 32.0


def _masks_for_overlap(n: int, b: int, overlap: float, rng,
                       sizes=(600, 350, 150)) -> np.ndarray:
    """(B, N) bool Δ masks with a controlled shared fraction and RAGGED
    per-client sizes (client i requests sizes[i % len] rows, of which
    ~overlap are drawn from one shared pool)."""
    masks = np.zeros((b, n), bool)
    pool = rng.permutation(n)
    shared_pool = pool[: n // 2]
    private_pool = pool[n // 2 :]
    p_off = 0
    for i in range(b):
        k = sizes[i % len(sizes)]
        k_shared = int(round(k * overlap))
        own = shared_pool[:k_shared].tolist()
        own += private_pool[p_off : p_off + (k - k_shared)].tolist()
        p_off += k - k_shared
        masks[i, own] = True
    return masks


@pytest.mark.parametrize("overlap", [0.0, 0.5, 1.0])
def test_dedup_decode_bitwise_matches_per_client(small_tree, overlap):
    rng = np.random.default_rng(11)
    b, n = 3, small_tree.n_pad
    sizes = (600, 600, 600) if overlap == 1.0 else (600, 350, 150)
    if overlap == 1.0:  # identical masks: the fully co-located sync
        one = _masks_for_overlap(n, 1, 1.0, rng, sizes=(600,))
        masks = np.repeat(one, b, axis=0)
    else:
        masks = _masks_for_overlap(n, b, overlap, rng, sizes=sizes)
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    sh_k = small_tree.gaussians.sh.shape[1]
    budget = int(masks.any(axis=0).sum()) + 32

    batch = dp.build_delta_batch(small_tree.gaussians, codec,
                                 jnp.asarray(masks), budget)
    assert not bool(batch.overflow)
    assert int(batch.n_union) == int(masks.any(axis=0).sum())
    assert int(batch.n_shipped) == int(batch.n_union)  # ample: no paging
    assert not np.asarray(batch.deferred).any()
    ref = dp.encode_per_client(small_tree.gaussians, codec,
                               jnp.asarray(masks), budget)

    for i in range(b):
        ids_u, dec_u = dp.decode_client(codec, batch, sh_k, i)
        ids_u = np.asarray(ids_u)
        sel_u = ids_u >= 0
        ids_r, enc_r, ovf_r = ref[i]
        # a truncated reference stream would make the parity below
        # meaningless — the budget must have been ample for BOTH paths
        assert not bool(ovf_r), f"client {i} reference stream truncated"
        ids_r = np.asarray(ids_r)
        sel_r = ids_r >= 0
        # same rows, same ascending-gid order
        np.testing.assert_array_equal(ids_u[sel_u], ids_r[sel_r], err_msg=str(i))
        # encoded representation: union rows referenced by this client vs its
        # own unicast stream — bitwise equal, field by field
        enc_u = batch.payload
        for field in ("dc", "code", "pos_q", "scale_q", "quat_q", "opa_q"):
            np.testing.assert_array_equal(
                np.asarray(getattr(enc_u, field))[sel_u],
                np.asarray(getattr(enc_r, field))[sel_r],
                err_msg=f"client {i} field {field}")
        # and so is the decode the client store would ingest
        dec_r = comp.decode(codec, enc_r, sh_k)
        for field in ("mu", "log_scale", "quat", "opacity", "sh"):
            np.testing.assert_array_equal(
                np.asarray(getattr(dec_u, field))[sel_u],
                np.asarray(getattr(dec_r, field))[sel_r],
                err_msg=f"client {i} field {field}")


def test_all_clients_idle_sync(small_tree):
    """The all-idle sync (no client needs anything) must produce an empty,
    well-formed batch."""
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    masks = jnp.zeros((4, small_tree.n_pad), bool)
    batch = dp.build_delta_batch(small_tree.gaussians, codec, masks, 64)
    assert int(batch.n_union) == 0
    assert not bool(batch.overflow)
    assert not np.asarray(batch.ref_mask).any()
    ids, _dec = dp.decode_client(codec, batch,
                                 small_tree.gaussians.sh.shape[1], 2)
    assert (np.asarray(ids) == -1).all()
    assert np.asarray(dp.first_owner_counts(masks)).sum() == 0


def test_union_overflow_flagged(small_tree):
    rng = np.random.default_rng(3)
    masks = _masks_for_overlap(small_tree.n_pad, 2, 0.0, rng,
                               sizes=(100, 80))
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    batch = dp.build_delta_batch(small_tree.gaussians, codec,
                                 jnp.asarray(masks), 64)
    assert bool(batch.overflow)
    # ... but nothing is lost: exactly budget rows shipped, the rest is
    # reported as per-client deferred carry-over
    assert int(batch.n_shipped) == 64
    assert int(batch.n_union) == 180
    delivered = np.asarray(batch.delivered)
    deferred = np.asarray(batch.deferred)
    np.testing.assert_array_equal(delivered | deferred, masks)
    assert not (delivered & deferred).any()
    assert deferred.any(axis=1).all()  # both clients lost rows to paging
    assert np.asarray(batch.client_overflow).all()


def test_paged_stream_ships_coarse_rows_first(small_tree):
    """With a priority key, the shipped subset must be exactly the lowest-
    priority-ranked union rows, and the stream must stay ascending by gid."""
    rng = np.random.default_rng(9)
    masks = _masks_for_overlap(small_tree.n_pad, 3, 0.3, rng)
    codec, _ = session_wire_format(small_tree, SessionConfig(tau=TAU))
    prio = np.asarray(small_tree.node_levels())
    batch = dp.build_delta_batch(small_tree.gaussians, codec,
                                 jnp.asarray(masks), 128,
                                 priority=small_tree.node_levels())
    union = masks.any(axis=0)
    gids = np.asarray(batch.union_gids)
    shipped = gids[gids >= 0]
    assert shipped.size == 128 == int(batch.n_shipped)
    assert (np.diff(shipped) > 0).all()          # ascending, delta-codable
    # priority cut: every shipped row ranks <= every deferred row under
    # (level, -requesters, gid) lexicographic order
    req = masks.sum(axis=0)
    rank = sorted((int(prio[g]), -int(req[g]), int(g))
                  for g in np.flatnonzero(union))
    want = {g for _, _, g in rank[:128]}
    assert set(shipped.tolist()) == want


def test_first_owner_counts_partition_union(small_tree):
    rng = np.random.default_rng(5)
    masks = _masks_for_overlap(small_tree.n_pad, 4, 0.5, rng)
    u = np.asarray(dp.first_owner_counts(jnp.asarray(masks)))
    assert u.sum() == masks.any(axis=0).sum()
    assert (u <= masks.sum(axis=1)).all()


# -- service-level: one codec call per sync, bytes grow with unique ----------


def _count_encodes(monkeypatch):
    calls = {"n": 0}
    real = comp.encode

    def counting_encode(codec, g):
        calls["n"] += 1
        return real(codec, g)

    monkeypatch.setattr(comp, "encode", counting_encode)
    return calls


def test_service_encodes_once_per_sync(small_tree, monkeypatch):
    """B co-located clients: the dedup service runs the codec ONCE per sync;
    the per-client reference path runs it B times."""
    b = 6
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.broadcast_to(np.asarray([40.0, 40.0, 2.0], np.float32),
                           (b, 3)).copy()
    service = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled",
                             dedup=True)
    calls = _count_encodes(monkeypatch)
    service.sync(cams)
    assert calls["n"] == 1
    service.sync(cams + 1.0)
    assert calls["n"] == 2  # still one per sync, B-independent

    masks = np.asarray(service.state.mgr.cut_prev)
    calls["n"] = 0
    dp.encode_per_client(small_tree.gaussians, service.codec,
                         jnp.asarray(masks), 256)
    assert calls["n"] == b

    off = svc.LodService(small_tree, cfg, b, focal=FOCAL, mode="pooled",
                         dedup=False)
    calls["n"] = 0
    off.sync(cams)
    assert calls["n"] == 0  # unicast accounting path never touches the codec


def test_colocated_fleet_bytes_grow_with_unique_not_b(small_tree):
    """Identical cameras: fleet downlink = one shared payload + B thin
    framings — total sync_bytes for B clients must equal the single-client
    total plus (B-1) framings, NOT B× the single-client total."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cam = np.asarray([[40.0, 40.0, 2.0]], np.float32)
    b = 8

    s1 = svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True)
    st1 = s1.sync(cam)
    sb = svc.LodService(small_tree, cfg, b, focal=FOCAL, dedup=True)
    stb = sb.sync(np.repeat(cam, b, axis=0))

    total1 = float(np.asarray(st1.sync_bytes).sum())
    totalb = float(np.asarray(stb.sync_bytes).sum())
    ids = float(np.asarray(st1.cut_size)[0])  # first sync: cut_add == cut
    # co-located clients pull from the same priority pages, so per-client
    # framing = membership ids + sync header + page headers
    pages = float(np.asarray(stb.pages)[0])
    assert pages == float(np.asarray(st1.pages)[0])
    framing = ids * 2 + 64 + pages * 16
    # ID_BYTES_DELTA * ids + SYNC_HEADER_BYTES + pages * PAGE_HEADER_BYTES
    assert np.isclose(totalb, total1 + (b - 1) * framing, rtol=1e-5), \
        (totalb, total1, framing)
    # payload part is O(unique): far below B x the unicast accounting
    assert totalb < 0.35 * b * total1
    assert int(np.asarray(stb.unique_delta).sum()) == int(sb.last_delta.n_union)
    assert float(np.asarray(stb.dedup_bytes_saved).sum()) > 0.0


def test_service_surfaces_delta_overflow(small_tree):
    """A too-small delta_budget pages the encode-once stream — the service
    must surface that PER CLIENT in ServiceStats (exactly the clients with
    deferred rows), not as a fleet-wide broadcast."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [41.0, 40.0, 2.0]], np.float32)
    tight = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                           delta_budget=64)
    st = tight.sync(cams)
    deferred = np.asarray(tight.last_delta.deferred).any(axis=1)
    np.testing.assert_array_equal(np.asarray(st.delta_overflow), deferred)
    assert deferred.all()  # both clients' Δs dwarf 64 rows here
    assert bool(tight.last_delta.overflow)
    # shipped + owed partitions each client's Δ; bytes charge only shipped
    shipped = np.asarray(st.delta_shipped)
    owed = np.asarray(st.delta_deferred)
    np.testing.assert_array_equal(shipped + owed, np.asarray(st.delta_size))
    assert (shipped <= 64).all()
    ok = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True)
    st = ok.sync(cams)  # default budget bounds the union — never defers
    assert not np.asarray(st.delta_overflow).any()
    assert not np.asarray(st.delta_deferred).any()


def test_tight_budget_bytes_charge_only_shipped_rows(small_tree):
    """Regression (the silent-overcharge bug): with a tight delta_budget,
    per-client sync_bytes must count only the union rows actually shipped
    this sync plus the page/sync framing — NOT the full requested Δ."""
    from repro.core import manager as mgr
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [41.0, 40.0, 2.0]], np.float32)
    tight = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                           delta_budget=64, page_size=16)
    st = tight.sync(cams)
    batch = tight.last_delta
    delivered = np.asarray(batch.delivered)
    share = delivered.sum(axis=0)
    ids = np.asarray(st.cut_size)  # first sync: cut_add == cut, no removes
    want = np.empty(2)
    for b in range(2):
        frac = (1.0 / np.maximum(share[delivered[b]], 1)).sum()
        want[b] = (frac * (tight.bytes_per_g + mgr.ID_BYTES_DELTA)
                   + ids[b] * mgr.ID_BYTES_DELTA + mgr.SYNC_HEADER_BYTES
                   + int(np.asarray(batch.client_pages)[b])
                   * mgr.PAGE_HEADER_BYTES)
    np.testing.assert_allclose(np.asarray(st.sync_bytes), want, rtol=1e-5)
    # the old accounting would have charged every requested row:
    assert np.asarray(st.sync_bytes).sum() < (
        np.asarray(st.delta_size, np.float64).sum() * tight.bytes_per_g)


# -- paging convergence: tight budgets defer, never lose ---------------------


def _converge(service, cams, oracle_delivered, budget):
    """Drive `service` at static `cams` until its pending debt drains;
    assert bitwise convergence to `oracle_delivered` within the page bound.
    Returns the number of syncs taken."""
    u = int(oracle_delivered.any(axis=0).sum())
    max_syncs = -(-u // budget)  # ceil: one full-width page-set per sync
    got = np.zeros_like(oracle_delivered)
    for k in range(max_syncs):
        service.sync(cams)
        got |= np.asarray(service.last_delta.delivered)
        if not np.asarray(service.state.pending).any():
            break
    assert not np.asarray(service.state.pending).any(), \
        f"debt left after {max_syncs} syncs"
    np.testing.assert_array_equal(got, oracle_delivered)
    return k + 1


@pytest.mark.parametrize("mode,impl", [("vmapped", "xla"), ("pooled", "xla"),
                                       ("pooled", "pallas")])
def test_paged_syncs_converge_bitwise_to_unbudgeted_oracle(small_tree, mode,
                                                           impl):
    """delta_budget < true union: every client's store must converge
    BITWISE to the unbudgeted baseline in <= ceil(U/width) syncs — rows
    arrive later, never never. All three sweep paths."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [46.0, 41.0, 2.5],
                       [38.0, 47.0, 3.0]], np.float32)
    kw = dict(focal=FOCAL, mode=mode, sweep_impl=impl, dedup=True)
    base = svc.LodService(small_tree, cfg, 3, **kw)
    base.sync(cams)
    oracle = np.asarray(base.last_delta.delivered)
    assert not np.asarray(base.state.pending).any()  # ample: no debt, ever

    budget = 128
    tight = svc.LodService(small_tree, cfg, 3, delta_budget=budget,
                           page_size=64, **kw)
    n_syncs = _converge(tight, cams, oracle, budget)
    assert n_syncs > 1  # the budget actually paged the stream


def _store_scatter(store, ids, dec):
    sel = np.asarray(ids) >= 0
    gids = np.asarray(ids)[sel]
    for f in ("mu", "log_scale", "quat", "opacity", "sh"):
        store.setdefault(f, {})
        rows = np.asarray(getattr(dec, f))[sel]
        for g, row in zip(gids.tolist(), rows):
            store[f][g] = row
    return store


def test_paged_decoded_store_bitwise_equals_oracle_store(small_tree):
    """The decode-side proof: accumulate one client's per-sync decoded Δ
    slices from the paged stream and compare every row bitwise against the
    single unbudgeted sync."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [44.0, 43.0, 2.5]], np.float32)
    base = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True)
    base.sync(cams)
    want = _store_scatter({}, *base.client_delta(0))

    budget = 128
    tight = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                           delta_budget=budget, page_size=32)
    got, syncs = {}, 0
    while True:
        tight.sync(cams)
        got = _store_scatter(got, *tight.client_delta(0))
        syncs += 1
        if not np.asarray(tight.state.pending).any():
            break
        assert syncs < 64, "paged stream failed to drain"
    assert syncs > 1
    for f in want:
        assert got[f].keys() == want[f].keys(), f
        for g in want[f]:
            np.testing.assert_array_equal(got[f][g], want[f][g],
                                          err_msg=f"{f}/gid{g}")


def test_paged_convergence_under_churn(small_tree):
    """Churn safety: an evicted slot DROPS its deferred pages (no debt ever
    reattaches to the slot's next tenant), an admitted client starts clean,
    and survivors still converge bitwise to their unbudgeted replay."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cam_a = np.asarray([40.0, 40.0, 2.0], np.float32)
    cam_b = np.asarray([46.0, 42.0, 2.5], np.float32)
    cam_c = np.asarray([38.0, 47.0, 3.0], np.float32)
    budget = 128
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                             capacity=4, delta_budget=budget, page_size=64)
    service.sync(np.stack([cam_a, cam_b]))
    assert np.asarray(service.state.pending).any()  # tight budget: debt

    # evict the indebted client 1: its slot's debt must vanish immediately
    slot_b = service._slot_of(1)
    assert np.asarray(service.state.pending)[slot_b].any()
    service.evict(1)
    assert not np.asarray(service.state.pending)[slot_b].any()

    # admit a newcomer (recycles the slot) — starts with zero debt
    cid_c = service.admit(cam_c)
    slot_c = service._slot_of(cid_c)
    assert not np.asarray(service.state.pending)[slot_c].any()

    # drive to convergence for the survivors
    for _ in range(32):
        service.sync({0: cam_a, cid_c: cam_c})
        if not np.asarray(service.state.pending).any():
            break
    assert not np.asarray(service.state.pending).any()

    # each survivor's store == a fresh ample single-client replay's store
    for cid, cam in ((0, cam_a), (cid_c, cam_c)):
        ref = svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True)
        ref.sync(cam[None])
        slot = service._slot_of(cid)
        np.testing.assert_array_equal(
            np.asarray(service.state.mgr.client_has[slot]),
            np.asarray(ref.state.mgr.client_has[0]), err_msg=f"cid{cid}")


# -- closed-loop bitrate control ---------------------------------------------


def test_rate_control_step_unit():
    """The controller's pure update rule, pinned: multiplicative tracking
    clipped to [x0.5, x2], one-page floor, tau escalation only at the floor,
    decay once comfortably under target, uncontrolled slots untouched."""
    target = np.asarray([1e4, 1e4, np.inf, 1e4])
    allowance = np.asarray([1000, 64, -1, 1000])
    tau = np.ones(4, np.float32)
    # client 0 overshoots 4x -> clipped halving; client 1 at the floor ->
    # tau escalates; client 2 uncontrolled; client 3 on target -> unchanged
    measured = np.asarray([4e4, 4e4, 123.0, 1e4])
    allow2, tau2 = svc.rate_control_step(target, measured, allowance, tau,
                                         page_size=64, max_rows=4096)
    assert allow2.tolist() == [500, 64, -1, 1000]
    assert tau2[0] == 1.0 and tau2[1] == pytest.approx(1.25)
    assert tau2[2] == 1.0 and tau2[3] == 1.0
    # undershoot far below target: allowance doubles (clip x2), and an
    # escalated tau decays back toward 1.0
    measured = np.asarray([1e3, 1e3, 0.0, 1e3])
    allow3, tau3 = svc.rate_control_step(target, measured, allow2, tau2,
                                         page_size=64, max_rows=4096)
    assert allow3.tolist() == [1000, 128, -1, 2000]
    assert tau3[1] == 1.0  # 1.25 / 1.25, floored at 1.0
    # idle sync (0 measured bytes) leaves the controlled state alone
    assert allow3[2] == -1 and tau3[2] == 1.0


def test_rate_control_idle_client_relaxes_escalation():
    """Regression (burst-then-idle): a client that bursts to the floor and
    escalates tau, then goes IDLE, must be released — `measured == 0` under
    a finite target is maximal headroom, not "no signal". Pre-fix the
    update forced ratio to 1.0 at zero measurement, so an idle client's
    allowance froze at the floor and its escalated tau never decayed: one
    bursty sync pinned it coarse forever."""
    target = np.asarray([1e4])
    allow = np.asarray([64])
    tau = np.asarray([2.0], np.float32)
    # the burst: 8x over target at the one-page floor -> tau escalates
    allow, tau = svc.rate_control_step(target, [8e4], allow, tau,
                                       page_size=64, max_rows=4096)
    assert allow.tolist() == [64] and tau[0] == pytest.approx(2.5)
    # first idle sync: full x2 allowance step AND a tau relax
    allow, tau = svc.rate_control_step(target, [0.0], allow, tau,
                                       page_size=64, max_rows=4096)
    assert allow.tolist() == [128] and tau[0] == pytest.approx(2.0)
    # sustained idle drains the escalation completely and re-opens the
    # allowance to the stream budget
    for _ in range(8):
        allow, tau = svc.rate_control_step(target, [0.0], allow, tau,
                                           page_size=64, max_rows=4096)
    assert tau[0] == 1.0 and allow[0] == 4096


def test_page_size_budget_degenerate_config(small_tree):
    """Regression: `page_size > delta_budget` used to invert the
    controller's `np.clip(..., page_size, max_rows)` bounds — numpy
    silently returns the max everywhere, freezing the loop at an allowance
    the stream can never serve. The config is now a typed error at
    construction, the default page adapts to small budgets, and the
    controller floor is `min(page_size, max_rows)` so the bounds can never
    invert."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    with pytest.raises(ValueError, match="page_size"):
        svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True,
                       delta_budget=64, page_size=256)
    with pytest.raises(ValueError, match="page_size"):
        svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True,
                       delta_budget=64, page_size=0)
    service = svc.LodService(small_tree, cfg, 1, focal=FOCAL, dedup=True,
                             delta_budget=64)
    assert service.page_size == 64        # default clamps to the budget
    # the pure update rule floors at the EFFECTIVE page (min with the
    # budget): an overshooting client lands exactly on the serveable floor
    # and the tau fallback still engages there
    allow, tau = svc.rate_control_step(
        [1e4], [4e4], [64], np.ones(1, np.float32),
        page_size=512, max_rows=128)
    assert allow.tolist() == [128] and tau[0] == pytest.approx(1.25)


def test_bandwidth_tiers_shape_the_stream(small_tree):
    """Heterogeneous bandwidth on one fleet: the narrow client is paced
    (rows deferred, allowance tightened by the loop) while the uncapped
    client drinks the full stream — and once the fleet goes static, every
    deferred row still arrives (rate control never loses data)."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    rng = np.random.default_rng(17)
    cams = np.asarray([[40.0, 40.0, 2.0], [41.0, 40.5, 2.2]], np.float32)
    narrow = 2e3  # bytes/sync — far below any cold Δcut
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                             bandwidth=[narrow, 1e9], page_size=64)
    assert service.client_bandwidth(0)[0] == narrow
    seed_allow = service.client_bandwidth(0)[1]
    # the uncapped client's allowance saturates at the stream budget
    assert service.client_bandwidth(1)[1] == service.delta_budget

    narrow_bytes, wide_bytes, narrow_deferred = [], [], 0
    for _ in range(6):
        st = service.sync(cams)
        narrow_bytes.append(float(np.asarray(st.sync_bytes)[0]))
        wide_bytes.append(float(np.asarray(st.sync_bytes)[1]))
        narrow_deferred += int(np.asarray(st.delta_deferred)[0] > 0)
        cams = cams + rng.uniform(1.0, 3.0, cams.shape).astype(np.float32)
    # the cold sync's union dwarfs the narrow client's row allowance...
    assert narrow_deferred > 0
    # ...so it is paced far below the uncapped client
    assert narrow_bytes[0] < wide_bytes[0]
    # the loop reacts to the overshoot: allowance never exceeds its seed,
    # and the tau fallback only ever escalates (scale >= 1)
    assert service.client_bandwidth(0)[1] <= seed_allow
    assert service.client_bandwidth(0)[2] >= 1.0
    assert service.client_bandwidth(1)[1] == service.delta_budget

    # stop moving: the narrow client's debt must fully drain (paged, never
    # lost) — the acceptance claim under rate control
    for _ in range(64):
        service.sync(cams)
        if not np.asarray(service.state.pending).any():
            break
    assert not np.asarray(service.state.pending).any()

    # tier names resolve through BANDWIDTH_TIERS at admission too
    cid = service.admit(cams[0], bandwidth="phone")
    assert service.client_bandwidth(cid)[0] == svc.BANDWIDTH_TIERS["phone"]


# -- page checksums + NACK retransmit ----------------------------------------


def test_page_checksums_and_row_page_wellformed(small_tree):
    """The wire-framing checksum layer on a genuinely paged stream:
    `row_page` maps every shipped wire row to a valid priority page with
    per-page populations bounded by page_size, and `page_checksums` is an
    order-independent per-page digest that a receiver can re-derive from
    the rows it parsed — and that flips when a row is dropped or migrates
    between pages."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [46.0, 41.0, 2.5]], np.float32)
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                             delta_budget=128, page_size=32)
    service.sync(cams)
    batch = service.last_delta
    row_page = np.asarray(batch.row_page)
    gids = np.asarray(batch.union_gids)
    n_shipped = int(np.asarray(batch.n_shipped))
    n_pages = int(np.asarray(batch.pages))
    assert n_pages > 1  # the budget actually paged the stream

    # well-formedness: shipped rows carry a real page id, padding carries -1
    shipped = row_page >= 0
    assert int(shipped.sum()) == n_shipped
    assert (gids[shipped] >= 0).all()
    assert row_page[shipped].max() == n_pages - 1
    counts = np.bincount(row_page[shipped], minlength=n_pages)
    assert (counts > 0).all() and (counts <= service.page_size).all()
    # per-client page pulls can never exceed the stream's page count
    assert (np.asarray(batch.client_pages) <= n_pages).all()

    # receiver-side recompute, in shuffled order: bitwise the header values
    want = service.delta_checksums()
    assert want.shape == (n_pages,) and want.dtype == np.uint32
    rng = np.random.default_rng(0)
    got = np.zeros_like(want)
    for i in rng.permutation(np.flatnonzero(shipped)):
        with np.errstate(over="ignore"):
            got[row_page[i]] += (np.uint32(gids[i]) * dp._CKSUM_MIX
                                 + np.uint32(1))
    np.testing.assert_array_equal(got, want)

    # a dropped row flips exactly its page's checksum...
    import dataclasses as _dc
    drop = int(np.flatnonzero(shipped)[0])
    mangled = row_page.copy()
    mangled[drop] = -1
    broken = _dc.replace(batch, row_page=jnp.asarray(mangled))
    diff = dp.page_checksums(broken) != want
    assert diff[row_page[drop]] and diff.sum() == 1
    # ...and a row migrating between pages flips both (same gid total)
    src, dst = int(row_page[drop]), (int(row_page[drop]) + 1) % n_pages
    moved = row_page.copy()
    moved[drop] = dst
    diff2 = dp.page_checksums(
        _dc.replace(batch, row_page=jnp.asarray(moved))) != want
    assert diff2[src] and diff2[dst] and diff2.sum() == 2


def test_lost_row_mask_is_clients_refs_in_lost_pages(small_tree):
    """`lost_row_mask` re-queues exactly the rows the client INGESTED from
    the named pages — never another client's rows, never rows of intact
    pages."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [46.0, 41.0, 2.5]], np.float32)
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                             delta_budget=128, page_size=32)
    service.sync(cams)
    batch = service.last_delta
    row_page = np.asarray(batch.row_page)
    gids = np.asarray(batch.union_gids)
    n_pages = int(np.asarray(batch.pages))
    for slot in (0, 1):
        ref = np.asarray(batch.ref_mask)[slot]
        lost = [0, n_pages - 1]
        mask = dp.lost_row_mask(batch, slot, lost)
        rows = ref & np.isin(row_page, lost) & (gids >= 0)
        want = np.zeros_like(mask)
        want[gids[rows]] = True
        np.testing.assert_array_equal(mask, want, err_msg=f"slot{slot}")
        # a NACK for every page is exactly this sync's delivered set
        all_mask = dp.lost_row_mask(batch, slot, range(n_pages))
        np.testing.assert_array_equal(
            all_mask, np.asarray(batch.delivered)[slot],
            err_msg=f"slot{slot}:all")


def test_nack_retransmit_converges_under_seeded_loss(small_tree):
    """The loss loop end-to-end: every sync, each priority page of the
    paged stream is independently lost with ~10% probability (seeded); the
    client ingests only intact pages and NACKs the rest. The accumulated
    store must converge BITWISE to the lossless unbudgeted oracle — page
    loss costs retransmit syncs, never data."""
    cfg = SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [44.0, 43.0, 2.5]], np.float32)
    base = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True)
    base.sync(cams)
    want = _store_scatter({}, *base.client_delta(0))

    lossy = svc.LodService(small_tree, cfg, 2, focal=FOCAL, dedup=True,
                           delta_budget=128, page_size=32)
    rng = np.random.default_rng(23)
    got, losses, syncs = {}, 0, 0
    for syncs in range(1, 64 + 1):
        lossy.sync(cams)
        batch = lossy.last_delta
        n_pages = int(np.asarray(batch.pages))
        lost = [p for p in range(n_pages) if rng.random() < 0.10]
        losses += len(lost)
        # the client keeps only rows of pages whose checksum verified
        ids, dec = lossy.client_delta(0)
        keep = np.asarray(ids) >= 0
        if lost:
            keep &= ~np.isin(np.asarray(batch.row_page), lost)
        kept_ids = np.where(keep, np.asarray(ids), -1)
        got = _store_scatter(got, kept_ids, dec)
        if lost:
            assert lossy.nack(0, lost) >= 0  # re-queue as pending debt
        if not np.asarray(lossy.state.pending).any() and not lost:
            break
    assert losses > 0, "seed never dropped a page — test is vacuous"
    assert not np.asarray(lossy.state.pending).any()
    for f in want:
        assert got[f].keys() == want[f].keys(), f
        for g in want[f]:
            np.testing.assert_array_equal(got[f][g], want[f][g],
                                          err_msg=f"{f}/gid{g}")
