"""Chunked-parallel recurrences vs sequential oracles (mLSTM, sLSTM, SSD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models.mamba2 import ssd_chunked, ssd_step
from repro.models.xlstm import (mlstm_chunked, mlstm_recurrent_step,
                                slstm_scan)


def _mlstm_ref(q, k, v, log_f, log_i):
    b, s, h, d = q.shape
    state = (jnp.zeros((b, h, d, d)), jnp.zeros((b, h, d)),
             jnp.full((b, h), -1e30))
    hs = []
    for t in range(s):
        state, ht = mlstm_recurrent_step(state, q[:, t], k[:, t], v[:, t],
                                         log_f[:, t], log_i[:, t])
        hs.append(ht)
    return jnp.stack(hs, 1), state


@pytest.mark.parametrize("s,chunk", [(16, 4), (37, 8), (33, 33), (20, 64)])
def test_mlstm_chunked_matches_recurrent(s, chunk):
    rng = np.random.default_rng(s * 131 + chunk)
    b, h, d = 2, 3, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    log_f = jnp.asarray(-np.abs(rng.normal(0, 1, (b, s, h))), jnp.float32)
    log_i = jnp.asarray(rng.normal(0, 1, (b, s, h)), jnp.float32)
    ref, ref_state = _mlstm_ref(q, k, v, log_f, log_i)
    out, state = mlstm_chunked(q, k, v, log_f, log_i, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state[0]), np.asarray(ref_state[0]),
                               rtol=1e-4, atol=1e-4)


def test_mlstm_state_carry_across_calls():
    """Two chunked calls with carried state == one call over the full seq."""
    rng = np.random.default_rng(0)
    b, s, h, d = 1, 24, 2, 4
    mk = lambda sh: jnp.asarray(rng.normal(size=sh), jnp.float32)
    q, k, v = mk((b, s, h, d)), mk((b, s, h, d)), mk((b, s, h, d))
    log_f = -jnp.abs(mk((b, s, h)))
    log_i = mk((b, s, h))
    full, _ = mlstm_chunked(q, k, v, log_f, log_i, chunk=6)
    h1, st = mlstm_chunked(q[:, :12], k[:, :12], v[:, :12],
                           log_f[:, :12], log_i[:, :12], chunk=6)
    h2, _ = mlstm_chunked(q[:, 12:], k[:, 12:], v[:, 12:],
                          log_f[:, 12:], log_i[:, 12:], chunk=6, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)


def _ssd_ref(x, dt, A, B, C):
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        st, y = ssd_step(st, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, 1), st


@pytest.mark.parametrize("s,chunk", [(16, 4), (29, 8), (29, 29), (12, 64)])
def test_ssd_chunked_matches_recurrent(s, chunk):
    rng = np.random.default_rng(s * 7 + chunk)
    b, h, p, n = 2, 3, 8, 6
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.3, (b, s, h))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1, 0.3, h)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    ref, ref_st = _ssd_ref(x, dt, A, B, C)
    out, st = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ref_st),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), s=st.integers(2, 24),
       chunk=st.integers(1, 32))
def test_property_ssd_chunk_invariance(seed, s, chunk):
    """Result must be independent of the chunk size (exactness property)."""
    rng = np.random.default_rng(seed)
    b, h, p, n = 1, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(0.5, 0.3, (b, s, h))), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(1, 0.3, h)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    out1, _ = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    out2, _ = ssd_chunked(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)


def test_slstm_state_carry():
    rng = np.random.default_rng(0)
    b, s, h, d = 2, 14, 2, 4
    gates = jnp.asarray(rng.normal(size=(b, s, h, 4, d)), jnp.float32)
    r = jnp.asarray(rng.normal(size=(h, 4, d, d)) * 0.2, jnp.float32)
    full, _ = slstm_scan(gates, r)
    h1, st = slstm_scan(gates[:, :7], r)
    h2, _ = slstm_scan(gates[:, 7:], r, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)


def test_moe_dispatch_vs_reference():
    from repro.models import moe
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=48, vocab=100, head_dim=16,
                      n_experts=8, top_k=2, capacity_factor=8.0,
                      dtype="float32", remat=False)
    params, _ = moe.init(jax.random.PRNGKey(0), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    out, aux = moe.moe_mlp(x, lp, cfg)
    ref = moe.moe_mlp_reference(x, lp, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With capacity 1.0 the dropped fraction must stay small for balanced
    routing, and outputs stay finite."""
    from repro.models import moe
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=48, vocab=100, head_dim=16,
                      n_experts=4, top_k=2, capacity_factor=1.0,
                      dtype="float32", remat=False)
    params, _ = moe.init(jax.random.PRNGKey(1), cfg)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 32, 32)), jnp.float32)
    out, _ = moe.moe_mlp(x, lp, cfg)
    assert bool(jnp.isfinite(out).all())
