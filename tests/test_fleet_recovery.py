"""Elastic fault-tolerant serving (repro.serve.recovery): snapshot/restore,
sync-journal crash recovery, and restore-onto-a-new-mesh.

The load-bearing claims pinned here:

  * KILL/RESTORE CONFORMANCE — a service snapshotted mid-churn, killed, and
    restored replays the rest of its schedule BITWISE against the
    uninterrupted service (per-sync records including the shared-payload
    byte split, every `ServiceState` leaf, and the host control-plane
    mirrors), across the vmapped, pooled-XLA, and pooled-Pallas sweeps;
  * JOURNAL RECOVERY — a crash at ANY point of a journaled run recovers
    from the newest intact snapshot + journal-tail replay to the exact
    pre-crash trajectory (randomized crash indices), including the
    closed-loop bitrate controller's one-sync-delayed feedback and
    carried paging debt;
  * FAULT INJECTION — every injected fault (mid-write `.tmp` leftovers,
    truncated leaf files, corrupt manifests, torn/corrupt journals,
    mismatched trees, disagreeing snapshot halves) ends in a clean restore
    from an earlier consistent point or a typed `RecoveryError` — silent
    divergence is never an outcome;
  * MESH RESIZE — restore onto a different `clients`×`slabs` mesh (bigger,
    smaller, none) is bitwise the single-device restore (subprocess with 8
    forced host devices), and `resize_mesh` relocates a LIVE service
    without perturbing its trajectory.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from test_fleet_churn import (FOCAL, TAU, _assert_records_equal, _cam,
                              _gen_schedule, _record)

from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_fleet_mesh
from repro.serve import lod_service as svc
from repro.serve import recovery as rec


def _play(ops, service, events, log=None):
    """Drive `ops` (a LodService or a RecoveryManager over `service`)
    through schedule `events`, recording every live client's per-sync view
    (the churn-conformance record format)."""
    log = {} if log is None else log
    for ev in events:
        if ev[0] == "admit":
            cid = ops.admit(ev[2])
            assert cid == ev[1]
            log.setdefault(cid, [])
        elif ev[0] == "evict":
            ops.evict(ev[1])
        else:
            stats = ops.sync(dict(ev[1]))
            for cid in service.active_ids:
                log.setdefault(cid, []).append(
                    _record(service, stats, cid, payload=service.dedup))
    return log


def _assert_logs_equal(a, b, ctx):
    assert a.keys() == b.keys(), (ctx, sorted(a), sorted(b))
    for cid in a:
        assert len(a[cid]) == len(b[cid]), (ctx, cid)
        for k, (x, y) in enumerate(zip(a[cid], b[cid])):
            _assert_records_equal(x, y, f"{ctx}/cid{cid}/sync{k}")


def _assert_services_bitwise(got, want, ctx=""):
    """Every ServiceState leaf and every host control-plane mirror agrees
    bitwise — the strongest form of `got` == `want`."""
    for i, (x, y) in enumerate(zip(jax.tree_util.tree_leaves(got.state),
                                   jax.tree_util.tree_leaves(want.state))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{ctx}:state leaf {i}")
    for f in ("_active", "_client_ids", "_slot_cams", "_delta_ids",
              "_bw_target", "_allowance", "_tau_scale"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f"{ctx}:{f}")
    assert got._next_id == want._next_id, ctx
    assert (got.taus is None) == (want.taus is None), ctx
    if got.taus is not None:
        np.testing.assert_array_equal(got.taus, want.taus, err_msg=ctx)


# ---------------------------------------------------------------------------
# (a) save -> kill -> restore replays bitwise, on all three sweep paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,impl", [("pooled", "xla"), ("vmapped", "xla"),
                                       ("pooled", "pallas")])
def test_kill_restore_bitwise_across_paths(tiny_tree, tmp_path, mode, impl):
    """One randomized churn schedule; the victim is snapshotted halfway,
    dropped, and restored from disk. The restored service must finish the
    schedule with per-sync records (cuts, decoded-Δ accounting, bytes) and
    final state bitwise identical to the never-interrupted oracle."""
    rng = np.random.default_rng(31)
    schedule = _gen_schedule(rng, steps=6, start_clients=2, max_clients=4)
    cut = len(schedule) // 2
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)

    def mk():
        return svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4,
                              mode=mode, sweep_impl=impl)

    oracle = mk()
    _play(oracle, oracle, schedule[:cut])
    victim = mk()
    _play(victim, victim, schedule[:cut])
    victim.snapshot(str(tmp_path))
    del victim  # the "kill": nothing in-memory survives

    restored = svc.LodService.restore(tiny_tree, str(tmp_path))
    _assert_services_bitwise(restored, oracle, f"{mode}/{impl}:post-restore")
    log_r = _play(restored, restored, schedule[cut:])
    log_o = _play(oracle, oracle, schedule[cut:])
    _assert_logs_equal(log_r, log_o, f"{mode}/{impl}")
    _assert_services_bitwise(restored, oracle, f"{mode}/{impl}:final")


def test_restore_preserves_debt_and_rate_controller(small_tree, tmp_path):
    """The hard state: a tight delta budget leaves carried paging debt, and
    a bandwidth-controlled client's loop feeds on the PREVIOUS sync's
    measured bytes. Snapshot mid-debt, restore, and drain — every post-
    restore sync (byte split included) must match the uninterrupted run."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    cams = np.asarray([[40.0, 40.0, 2.0], [46.0, 41.0, 2.5],
                       [38.0, 47.0, 3.0]], np.float32)

    def mk():
        return svc.LodService(small_tree, cfg, 3, focal=FOCAL, dedup=True,
                              delta_budget=128, page_size=64)

    oracle, victim = mk(), mk()
    for s in (oracle, victim):
        s.set_bandwidth(0, 6000.0)  # close the loop on client 0
        s.sync(cams)
    assert np.asarray(victim.state.pending).any()  # debt is being carried
    victim.snapshot(str(tmp_path))
    del victim

    restored = svc.LodService.restore(small_tree, str(tmp_path))
    assert np.asarray(restored.state.pending).any()
    assert restored.client_bandwidth(0)[0] == 6000.0
    for k in range(32):
        st_r, st_o = restored.sync(cams), oracle.sync(cams)
        for cid in (0, 1, 2):
            _assert_records_equal(
                _record(restored, st_r, cid, payload=True),
                _record(oracle, st_o, cid, payload=True),
                f"drain/sync{k}/cid{cid}")
        if not np.asarray(oracle.state.pending).any():
            break
    assert not np.asarray(restored.state.pending).any()
    _assert_services_bitwise(restored, oracle, "drained")


def test_restored_payload_tenancy_refuses_stale_reads(tiny_tree, tmp_path):
    """The Δ payload is a per-sync artifact and is NOT serialized: decode
    and NACK against a restored service must fail typed until its first
    sync, then work normally."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4)
    cams = np.stack([_cam(np.random.default_rng(3)) for _ in range(2)])
    s.sync(cams)
    s.client_delta(0)  # live payload decodes fine
    s.snapshot(str(tmp_path))
    r = svc.LodService.restore(tiny_tree, str(tmp_path))
    with pytest.raises(ValueError, match="no sync performed yet"):
        r.client_delta(0)
    with pytest.raises(ValueError, match="no sync performed yet"):
        r.resolve_nack(0, [0])
    r.sync(cams)
    ids, _ = r.client_delta(0)
    assert np.asarray(ids).shape[0] > 0


# ---------------------------------------------------------------------------
# (b) journaled runs recover from randomized crash points
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,crash_at", [(3, 1), (11, 4), (19, 7)])
def test_journal_recover_randomized_crash(tiny_tree, tmp_path, seed,
                                          crash_at):
    """Drive a journaled service, kill it at an arbitrary event index, and
    `recover`: the snapshot + journal-tail replay must land bitwise on the
    uninterrupted oracle's trajectory, and the rest of the schedule must
    replay bitwise through the resumed manager."""
    rng = np.random.default_rng(seed)
    schedule = _gen_schedule(rng, steps=6, start_clients=1, max_clients=4)
    crash_at = min(crash_at, len(schedule) - 1)
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)

    def mk():
        return svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4,
                              mode="pooled")

    oracle = mk()
    _play(oracle, oracle, schedule[:crash_at])

    victim = mk()
    mgr = rec.RecoveryManager(victim, str(tmp_path), every=2, keep=2)
    _play(mgr, victim, schedule[:crash_at])
    del victim, mgr  # crash

    mgr2, replayed = rec.recover(tiny_tree, str(tmp_path))
    assert 0 <= replayed <= len(schedule)
    _assert_services_bitwise(mgr2.service, oracle, "post-recover")
    log_r = _play(mgr2, mgr2.service, schedule[crash_at:])
    log_o = _play(oracle, oracle, schedule[crash_at:])
    _assert_logs_equal(log_r, log_o, "post-recover")
    _assert_services_bitwise(mgr2.service, oracle, "final")


def test_journal_replays_nack_and_bandwidth(tiny_tree, tmp_path):
    """NACKs journal their RESOLVED gids (never page numbers of a payload
    that died with the process) and bandwidth re-tiers replay — a crash
    right after both still recovers the exact pending debt and controller
    seed."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    cams = np.asarray([[12.0, 9.0, 2.0], [20.0, 18.0, 3.0]], np.float32)

    def mk():
        return svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4,
                              mode="pooled", dedup=True)

    oracle, victim = mk(), mk()
    mgr = rec.RecoveryManager(victim, str(tmp_path), every=100, keep=2)
    oracle.sync(cams)
    mgr.sync(cams)
    assert int(np.asarray(victim.last_delta.pages)) >= 1
    # client 0 loses page 0; both fleets re-queue the same rows
    n_o = oracle.nack(0, [0])
    n_v = mgr.nack(0, [0])
    assert n_o == n_v > 0
    oracle.set_bandwidth(1, 4000.0)
    mgr.set_bandwidth(1, 4000.0)
    del victim, mgr  # crash: only the base snapshot + journal survive

    mgr2, replayed = rec.recover(tiny_tree, str(tmp_path))
    assert replayed == 3  # sync + nack + bandwidth, all journal-replayed
    _assert_services_bitwise(mgr2.service, oracle, "nack-replay")
    # the re-queued debt drains identically
    st_r, st_o = mgr2.sync(cams), oracle.sync(cams)
    for cid in (0, 1):
        _assert_records_equal(_record(mgr2.service, st_r, cid, True),
                              _record(oracle, st_o, cid, True),
                              f"post-nack/cid{cid}")


def test_manager_denied_admit_never_journaled(tiny_tree, tmp_path):
    """Admission control is pre-checked BEFORE journaling: a denied admit
    leaves no record (replay would re-raise mid-recovery otherwise)."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4,
                       max_clients=1)
    mgr = rec.RecoveryManager(s, str(tmp_path), every=8)
    assert mgr.admit(required=False) is None
    with pytest.raises(svc.AdmissionDenied):
        mgr.admit(cam=_cam(np.random.default_rng(0)))
    records = rec.SyncJournal.read(os.path.join(str(tmp_path),
                                                rec.JOURNAL_NAME))
    assert [r["kind"] for r in records] == []
    mgr2, replayed = rec.recover(tiny_tree, str(tmp_path))
    assert replayed == 0
    assert mgr2.service.active_ids == [0]


def test_snapshot_every_k_bounds_replay_and_gc_bounds_disk(tiny_tree,
                                                           tmp_path):
    """every=K caps the journal tail a recovery replays at K syncs, and
    keep-last-k GC caps the snapshot count on disk."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4)
    mgr = rec.RecoveryManager(s, str(tmp_path), every=2, keep=2)
    cam = _cam(np.random.default_rng(1))
    for _ in range(7):
        mgr.sync({0: cam})
    steps = ckpt.valid_steps(mgr.snapshot_dir)
    assert len(steps) == 2  # keep-last-2, GC'd
    del s, mgr
    mgr2, replayed = rec.recover(tiny_tree, str(tmp_path), every=2, keep=2)
    assert replayed <= 2  # at most one snapshot interval of tail


# ---------------------------------------------------------------------------
# (c) fault injection: clean restore from an earlier point, or typed error
# ---------------------------------------------------------------------------


def _journaled_run(tree, directory, steps=5):
    """A journaled single-client run with >= 2 surviving snapshots.
    Returns (oracle service, camera) — the oracle ran the identical
    schedule uninterrupted."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    cam = _cam(np.random.default_rng(5))

    def mk():
        return svc.LodService(tree, cfg, 1, focal=FOCAL, capacity=4)

    oracle = mk()
    s = mk()
    mgr = rec.RecoveryManager(s, directory, every=2, keep=3)
    for k in range(steps):
        pos = (cam + k).astype(np.float32)
        oracle.sync({0: pos})
        mgr.sync({0: pos})
    assert len(ckpt.valid_steps(mgr.snapshot_dir)) >= 2
    return oracle, cam


def test_fault_tmp_leftover_swept(tiny_tree, tmp_path):
    """A save killed mid-write leaves a `step_*.tmp` dir: recovery sweeps
    it and restores from the real snapshots, bitwise."""
    oracle, _ = _journaled_run(tiny_tree, str(tmp_path))
    snap = os.path.join(str(tmp_path), rec.SNAPSHOT_DIRNAME)
    torn = os.path.join(snap, "step_00000099.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "leaf_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY garbage")
    mgr, _ = rec.recover(tiny_tree, str(tmp_path))
    assert not os.path.exists(torn)
    _assert_services_bitwise(mgr.service, oracle, "tmp-leftover")


def test_fault_truncated_leaf_falls_back_a_step(tiny_tree, tmp_path):
    """A truncated leaf file in the NEWEST snapshot: recovery falls back to
    the previous snapshot and replays a longer journal tail — same bitwise
    endpoint, nothing lost but replay time."""
    oracle, _ = _journaled_run(tiny_tree, str(tmp_path))
    snap = os.path.join(str(tmp_path), rec.SNAPSHOT_DIRNAME)
    newest = ckpt.valid_steps(snap)[0]
    step_dir = os.path.join(snap, f"step_{newest:08d}")
    leaf = sorted(n for n in os.listdir(step_dir) if n.endswith(".npy"))[0]
    path = os.path.join(step_dir, leaf)
    with open(path, "rb") as f:
        raw = f.read()
    with open(path, "wb") as f:
        f.write(raw[: max(1, len(raw) // 2)])
    mgr, replayed = rec.recover(tiny_tree, str(tmp_path))
    assert replayed >= 1  # the longer tail was actually replayed
    _assert_services_bitwise(mgr.service, oracle, "truncated-leaf")


def test_fault_corrupt_manifest_falls_back_a_step(tiny_tree, tmp_path):
    oracle, _ = _journaled_run(tiny_tree, str(tmp_path))
    snap = os.path.join(str(tmp_path), rec.SNAPSHOT_DIRNAME)
    newest = ckpt.valid_steps(snap)[0]
    with open(os.path.join(snap, f"step_{newest:08d}", "manifest.json"),
              "w") as f:
        f.write("{not json")
    mgr, _ = rec.recover(tiny_tree, str(tmp_path))
    _assert_services_bitwise(mgr.service, oracle, "corrupt-manifest")


def test_fault_every_snapshot_corrupt_is_typed(tiny_tree, tmp_path):
    """When NO snapshot survives, recovery raises `RecoveryError` carrying
    every per-step failure — never a silently diverged fleet."""
    _journaled_run(tiny_tree, str(tmp_path))
    snap = os.path.join(str(tmp_path), rec.SNAPSHOT_DIRNAME)
    for step in ckpt.valid_steps(snap):
        with open(os.path.join(snap, f"step_{step:08d}", "manifest.json"),
                  "w") as f:
            f.write("{not json")
    with pytest.raises(rec.RecoveryError, match="cannot recover"):
        rec.recover(tiny_tree, str(tmp_path))


def test_fault_journal_torn_tail_truncated(tiny_tree, tmp_path):
    """A partial final append (the write the crash interrupted) is a torn
    tail: truncated away, recovery proceeds from the valid prefix."""
    oracle, _ = _journaled_run(tiny_tree, str(tmp_path))
    jpath = os.path.join(str(tmp_path), rec.JOURNAL_NAME)
    n_before = len(rec.SyncJournal.read(jpath, repair=False))
    with open(jpath, "ab") as f:
        f.write(b'{"kind": "sync", "cams"')  # no newline, no CRC
    mgr, _ = rec.recover(tiny_tree, str(tmp_path))
    assert len(rec.SyncJournal.read(jpath, repair=False)) == n_before
    _assert_services_bitwise(mgr.service, oracle, "torn-journal")


def test_fault_journal_midfile_corruption_is_typed(tiny_tree, tmp_path):
    """A corrupt record FOLLOWED by valid ones is a hole — replaying around
    it would silently diverge, so it must raise."""
    _journaled_run(tiny_tree, str(tmp_path))
    jpath = os.path.join(str(tmp_path), rec.JOURNAL_NAME)
    with open(jpath, "r", encoding="utf-8") as f:
        lines = f.read().splitlines()
    assert len(lines) >= 3
    lines[1] = lines[1][:-8] + 'X' * 8  # smash the CRC field
    with open(jpath, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(rec.RecoveryError, match="hole, not a torn tail"):
        rec.recover(tiny_tree, str(tmp_path))


def test_fault_journal_seq_hole_is_typed(tiny_tree, tmp_path):
    _journaled_run(tiny_tree, str(tmp_path))
    jpath = os.path.join(str(tmp_path), rec.JOURNAL_NAME)
    with open(jpath, "r", encoding="utf-8") as f:
        lines = f.read().splitlines()
    del lines[1]  # a whole record vanished
    with open(jpath, "w", encoding="utf-8") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(rec.RecoveryError, match="records are missing"):
        rec.recover(tiny_tree, str(tmp_path))


def test_fault_wrong_tree_is_typed(tiny_tree, small_tree, tmp_path):
    """Restoring fleet state against a different city tree would reindex
    every gid — the fingerprint turns it into a typed error."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4)
    s.sync({0: _cam(np.random.default_rng(2))})
    s.snapshot(str(tmp_path))
    with pytest.raises(rec.RecoveryError, match="different tree"):
        svc.LodService.restore(small_tree, str(tmp_path))


def test_fault_disagreeing_snapshot_halves_is_typed(tiny_tree, tmp_path):
    """The restored device FleetState is cross-checked against the
    snapshotted host mirror: rewrite the host `active` leaf so the halves
    disagree — restore must refuse."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4)
    s.sync(np.stack([_cam(np.random.default_rng(4)) for _ in range(2)]))
    s.snapshot(str(tmp_path))
    step_dir = os.path.join(str(tmp_path), "step_00000000")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    entry = next(e for e in manifest["leaves"] if e["key"] == "host/active")
    flipped = ~np.load(os.path.join(step_dir, entry["file"]))
    np.save(os.path.join(step_dir, entry["file"]), flipped)
    with pytest.raises(rec.RecoveryError, match="disagrees"):
        svc.LodService.restore(tiny_tree, str(tmp_path))


def test_restore_empty_directory_is_typed(tiny_tree, tmp_path):
    with pytest.raises(rec.RecoveryError, match="no complete snapshot"):
        svc.LodService.restore(tiny_tree, str(tmp_path))
    with pytest.raises(rec.RecoveryError, match="cannot recover"):
        rec.recover(tiny_tree, str(tmp_path))


# ---------------------------------------------------------------------------
# (d) the journal file format itself
# ---------------------------------------------------------------------------


def test_sync_journal_roundtrip_and_repair(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    j = rec.SyncJournal(path)
    for k in range(5):
        assert j.append({"kind": "sync", "cams": {"0": [1.0, 2.0, k]}}) == k
    recs = rec.SyncJournal.read(path)
    assert [r["seq"] for r in recs] == list(range(5))
    assert recs[3]["cams"]["0"] == [1.0, 2.0, 3]
    # torn tail: garbage after the last valid record is truncated on read
    with open(path, "ab") as f:
        f.write(b'{"kind": "syn\xff\xfe')
    assert len(rec.SyncJournal.read(path, repair=True)) == 5
    with open(path, "rb") as f:
        raw = f.read()
    assert raw.endswith(b"\n") and b"\xff" not in raw
    # resuming appends continue the dense seq
    j2 = rec.SyncJournal(path, seq=5)
    j2.append({"kind": "shrink"})
    assert [r["seq"] for r in rec.SyncJournal.read(path)] == list(range(6))


def test_sync_journal_cam_roundtrip_is_bitwise(tmp_path):
    """float32 cameras survive JSON exactly (float32 -> float64 -> float32
    is exact), so a journal replay syncs the identical positions."""
    cam = _cam(np.random.default_rng(9))
    back = np.asarray(rec._jsonable_cam(cam), np.float32)
    np.testing.assert_array_equal(cam, back)
    j = rec.SyncJournal(str(tmp_path / "j.jsonl"))
    j.append({"kind": "sync",
              "cams": {"0": rec._jsonable_cam(cam)}})
    recs = rec.SyncJournal.read(j.path)
    got = np.asarray(recs[0]["cams"]["0"], np.float32)
    np.testing.assert_array_equal(cam, got)


def test_replay_unknown_kind_is_typed(tiny_tree):
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s = svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4)
    with pytest.raises(rec.RecoveryError, match="unknown journal record"):
        rec.replay(s, [{"kind": "frobnicate", "seq": 0}])


# ---------------------------------------------------------------------------
# (e) mesh resize: live and across restore
# ---------------------------------------------------------------------------


def test_resize_mesh_live_is_bitwise(tiny_tree):
    """Moving a LIVE single-device service onto a (1x1) fleet mesh and back
    to no mesh must not perturb its trajectory."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    cams = np.stack([_cam(np.random.default_rng(6)) for _ in range(2)])
    control = svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4)
    moved = svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4)
    control.sync(cams)
    moved.sync(cams)
    moved.resize_mesh(make_fleet_mesh(1, 1))
    st_c, st_m = control.sync(cams), moved.sync(cams)
    for cid in (0, 1):
        _assert_records_equal(_record(moved, st_m, cid, True),
                              _record(control, st_c, cid, True),
                              f"onto-mesh/cid{cid}")
    moved.resize_mesh(None)
    st_c, st_m = control.sync(cams), moved.sync(cams)
    for cid in (0, 1):
        _assert_records_equal(_record(moved, st_m, cid, True),
                              _record(control, st_c, cid, True),
                              f"off-mesh/cid{cid}")
    _assert_services_bitwise(moved, control, "after-resizes")


def test_restore_onto_mesh_single_device_is_bitwise(tiny_tree, tmp_path):
    """Reshard-on-load with a (1x1) target mesh: bitwise the meshless
    restore, and the snapshot manifest records the SAVED layout."""
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    cams = np.stack([_cam(np.random.default_rng(8)) for _ in range(2)])
    s = svc.LodService(tiny_tree, cfg, 2, focal=FOCAL, capacity=4)
    s.sync(cams)
    s.snapshot(str(tmp_path))
    assert ckpt.read_extras(str(tmp_path), 0)["mesh"] is None

    plain = svc.LodService.restore(tiny_tree, str(tmp_path))
    meshed = svc.LodService.restore(tiny_tree, str(tmp_path),
                                    mesh=make_fleet_mesh(1, 1))
    assert meshed.mesh is not None
    st_p, st_m = plain.sync(cams), meshed.sync(cams)
    for cid in (0, 1):
        _assert_records_equal(_record(meshed, st_m, cid, True),
                              _record(plain, st_p, cid, True),
                              f"restore-mesh/cid{cid}")


# ---------------------------------------------------------------------------
# (f) the 8-device resize-restore subprocess (the acceptance contract)
# ---------------------------------------------------------------------------


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, tempfile
sys.path.insert(0, "src")
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.gaussians import random_gaussians
from repro.core.lod_tree import build_lod_tree
from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_fleet_mesh
from repro.serve import lod_service as svc
from repro.serve import recovery as rec

assert len(jax.devices()) == 8
STATS = ("cut_size", "delta_size", "sync_bytes", "unique_delta",
         "nodes_touched", "resweeps", "client_resident", "delta_shipped",
         "delta_deferred", "pages")

rng = np.random.default_rng(11)
tree = build_lod_tree(random_gaussians(rng, 150, sh_degree=1, extent=30.0),
                      branching=(2, 4), target_subtrees=8, seed=1)
cfg = svc.SessionConfig(tau=32.0, cut_budget=2048)
mesh_save = make_fleet_mesh(clients=4, slabs=2)

# a churned meshed fleet: 4 seats, one admit, one evict, a few syncs
s = svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8, mode="pooled",
                   dedup=True, mesh=mesh_save)
pos = rng.uniform([2, 2, 1], [28, 28, 6], (4, 3)).astype(np.float32)
s.sync(pos)
cid = s.admit(np.asarray([14.0, 14.0, 3.0], np.float32))
s.evict(1)
cams = {c: (rng.uniform([2, 2, 1], [28, 28, 6]).astype(np.float32))
        for c in s.active_ids}
s.sync(dict(cams))
snap = tempfile.mkdtemp()
s.snapshot(snap)
sig = dict((a, int(n)) for a, n in ckpt.read_extras(snap, 0)["mesh"])
assert sig == {"clients": 4, "slabs": 2}, sig

# the expected trajectory: two more syncs of the UNINTERRUPTED service
def roll(service, steps=2):
    r2 = np.random.default_rng(77)
    out = []
    for _ in range(steps):
        c = {c: r2.uniform([2, 2, 1], [28, 28, 6]).astype(np.float32)
             for c in service.active_ids}
        st = service.sync(dict(c))
        out.append({f: np.asarray(getattr(st, f)).copy() for f in STATS})
    out.append({"cut_gids": np.asarray(service.state.cut_gids).copy(),
                "client_has": np.asarray(
                    service.state.mgr.client_has).copy()})
    return out

want = roll(s)

# restore the SAME snapshot onto: a rebalanced 8-device mesh, a BIGGER
# clients axis, a SMALLER 2-device mesh, and no mesh at all
targets = {
    "rebalanced_2x4": make_fleet_mesh(clients=2, slabs=4),
    "bigger_8x1": make_fleet_mesh(clients=8, slabs=1),
    "smaller_2x1": Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                        ("clients", "slabs")),
    "none": None,
}
results = {}
for name, mesh in targets.items():
    r = svc.LodService.restore(tree, snap, mesh=mesh)
    assert sorted(r.active_ids) == sorted(s.active_ids)
    got = roll(r)
    for k, (a, b) in enumerate(zip(got, want)):
        for f in a:
            np.testing.assert_array_equal(a[f], b[f],
                                          err_msg=f"{name}:{k}:{f}")
    if mesh is not None and "clients" in mesh.axis_names \
            and r.capacity % mesh.shape["clients"] == 0:
        # the declared client-axis layout holds on slot-axis state leaves
        for leaf in jax.tree_util.tree_leaves(r.state):
            if getattr(leaf, "ndim", 0) >= 1 \
                    and leaf.shape[0] == r.capacity:
                assert leaf.sharding.spec[0] == "clients", \
                    (name, leaf.shape, leaf.sharding.spec)
    results[name] = True

# crash recovery lands on a new mesh too: journaled run, kill, recover
# onto the rebalanced mesh, trajectory bitwise vs the meshless recover
work = tempfile.mkdtemp()
v = svc.LodService.restore(tree, snap, mesh=mesh_save)
mgr = rec.RecoveryManager(v, work, every=2, keep=2)
r3 = np.random.default_rng(5)
for _ in range(3):
    mgr.sync({c: r3.uniform([2, 2, 1], [28, 28, 6]).astype(np.float32)
              for c in v.active_ids})
del v, mgr
m_none, rep_a = rec.recover(tree, work, mesh=None)
w2 = roll(m_none.service)
m_mesh, rep_b = rec.recover(tree, work,
                            mesh=targets["rebalanced_2x4"])
assert rep_a == rep_b
g2 = roll(m_mesh.service)
for k, (a, b) in enumerate(zip(g2, w2)):
    for f in a:
        np.testing.assert_array_equal(a[f], b[f],
                                      err_msg=f"recover-mesh:{k}:{f}")
results["recover_onto_mesh"] = True
results["ok"] = True
print(json.dumps(results))
"""


@pytest.mark.slow
def test_mesh_resize_restore_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=900,
                         cwd=".")
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results["ok"] and results["rebalanced_2x4"] \
        and results["bigger_8x1"] and results["smaller_2x1"] \
        and results["none"] and results["recover_onto_mesh"]
