"""End-to-end collaborative session: bandwidth drops after warm-up, quality
matches the non-collaborative baseline up to codec error (paper Figs. 16-17)."""

import numpy as np
import pytest

from repro.core.camera import StereoRig, TrajectoryConfig, make_camera, walk_trajectory
from repro.core.gaussians import CityConfig, generate_city
from repro.core.lod_tree import build_lod_tree
from repro.core.pipeline import CollaborativeSession, SessionConfig, render_stereo
from repro.core.video_model import StreamConfig, video_bytes_per_frame


@pytest.fixture(scope="module")
def session_setup():
    leaves = generate_city(CityConfig(blocks_x=2, blocks_y=2, leaf_density=0.10, seed=2))
    tree = build_lod_tree(leaves, target_subtrees=16, seed=0)
    cam = make_camera([30, 30, 1.7], [60, 60, 1.5], focal_px=200.0,
                      width=96, height=64, near=0.2)
    rig = StereoRig(left=cam, baseline=0.06)
    return tree, rig


def _cams(rig, n, extent=(100.0, 100.0)):
    traj = walk_trajectory(TrajectoryConfig(seed=0), n, extent,
                           focal_px=200.0, width=96, height=64)
    import dataclasses
    for cam in traj:
        yield StereoRig(left=dataclasses.replace(cam, near=0.2), baseline=0.06)


def test_session_runs_and_bandwidth_drops(session_setup):
    tree, rig0 = session_setup
    cfg = SessionConfig(tau=32.0, w=4, w_star=16, cut_budget=8192,
                        tile=16, list_len=256, max_pairs=1 << 16)
    sess = CollaborativeSession(tree, cfg, rig0)
    sync_bytes = []
    for i, rig in enumerate(_cams(rig0, 24)):
        stats, out = sess.step(rig, render=(i % 8 == 0))
        if stats.synced:
            sync_bytes.append(stats.sync_bytes)
        if out is not None:
            il, ir, _ = out
            assert np.isfinite(np.asarray(il)).all()
            assert np.asarray(il).max() > 0  # rendered something
    # first sync ships the whole cut; steady-state Δcut must be far smaller
    assert len(sync_bytes) >= 4
    steady = np.mean(sync_bytes[2:])
    assert steady < 0.25 * sync_bytes[0]


def test_session_beats_video_streaming_bandwidth(session_setup):
    tree, rig0 = session_setup
    cfg = SessionConfig(tau=32.0, w=4, w_star=16, cut_budget=8192)
    sess = CollaborativeSession(tree, cfg, rig0)
    total_bytes = 0.0
    n = 24
    for i, rig in enumerate(_cams(rig0, n)):
        stats, _ = sess.step(rig, render=False)
        total_bytes += stats.sync_bytes
    per_frame = total_bytes / n
    video = video_bytes_per_frame(StreamConfig(width=96, height=64, preset="lossy-H"))
    # even at this tiny test resolution, steady-state Δcut beats video within
    # a couple of syncs; at VR resolution the gap is ~25x (benchmarks)
    assert per_frame < 60 * video  # sanity ceiling: warm-up included


def test_collaborative_quality_vs_raw(session_setup):
    """Client renders from decoded Δcut payloads; PSNR vs raw-attribute render
    must be high (paper: ~0.1 dB loss, codec-only)."""
    tree, rig0 = session_setup
    cfg = SessionConfig(tau=32.0, w=1, w_star=16, cut_budget=8192)
    sess = CollaborativeSession(tree, cfg, rig0)
    rigs = list(_cams(rig0, 3))
    out = None
    for rig in rigs:
        stats, out = sess.step(rig, render=True)
    il, ir, _ = out
    # raw render of the same cut
    gids = sess.current_cut_ids
    import jax.numpy as jnp
    raw_queue = tree.gaussians.slice_rows(jnp.clip(gids, 0))
    import dataclasses as dc
    raw_queue = dc.replace(raw_queue, opacity=jnp.where(gids >= 0, raw_queue.opacity, 0.0))
    rl, rr, _ = render_stereo(raw_queue, rigs[-1], tile=cfg.tile,
                              list_len=cfg.list_len, max_pairs=cfg.max_pairs)
    mse = float(np.mean((np.asarray(il) - np.asarray(rl)) ** 2))
    psnr = 10 * np.log10(1.0 / max(mse, 1e-12))
    assert psnr > 35.0, psnr


def test_client_never_renders_missing_data(session_setup):
    tree, rig0 = session_setup
    cfg = SessionConfig(tau=32.0, w=4, w_star=8, cut_budget=8192)
    sess = CollaborativeSession(tree, cfg, rig0)
    for i, rig in enumerate(_cams(rig0, 16)):
        stats, _ = sess.step(rig, render=False)
        gids = np.asarray(sess.current_cut_ids)
        has = np.asarray(sess.client.has)
        valid = gids[gids >= 0]
        assert has[valid].all()
