"""Ragged fleet lifecycle: churn conformance + the capacity-bucket
recompile contract.

The load-bearing claims pinned here:

  * CONFORMANCE — after ANY admit/evict/sync schedule, every surviving
    client's cuts, decoded Δ payloads, and per-client accounting are bitwise
    identical to a fresh fixed-size service that replayed only that client's
    camera history (and, with the unicast wire format, the byte accounting
    too — the shared-payload split legitimately depends on who else shares a
    row, so its bitwise replay check runs with dedup off);
  * the three sweep paths (vmapped reference, pooled XLA, pooled Pallas)
    agree bitwise on the whole churn trajectory;
  * INACTIVE SLOTS ARE FREE — zero stats rows (header included), no union
    rows, per-slot state bitwise frozen at the reset value; an
    evicted-then-recycled slot is indistinguishable from a fresh one;
  * RECOMPILE BOUND — a 30-step churn schedule inside one pow2 capacity
    bucket never retraces any jitted sync entry point, and a capacity-bucket
    growth retraces each exactly once.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import lod_search as ls
from repro.core import manager as mgr
from repro.core import pipeline as pl
from repro.serve import delta_path as dp
from repro.serve import fleet as flt
from repro.serve import lod_service as svc

FOCAL = 1400.0
TAU = 32.0

GAUSS_FIELDS = ("mu", "log_scale", "quat", "opacity", "sh")


def _rig_at(pos, width=64, height=48):
    from repro.core.camera import StereoRig, make_camera
    cam = make_camera(list(np.asarray(pos, np.float32)),
                      list(np.asarray(pos, np.float32) + [10, 10, -0.2]),
                      focal_px=200.0, width=width, height=height, near=0.25)
    return StereoRig(left=cam, baseline=0.06)


# ---------------------------------------------------------------------------
# schedule machinery
# ---------------------------------------------------------------------------


def _cam(rng):
    c = rng.uniform([5.0, 5.0, 1.5], [55.0, 55.0, 8.0]).astype(np.float32)
    return c


def _gen_schedule(rng, steps, start_clients, max_clients):
    """A randomized admit/evict/sync schedule. Client ids follow the
    service's monotone assignment, so events can name them directly.
    Returns a list of ("admit", cid, cam) | ("evict", cid) |
    ("sync", {cid: cam})."""
    alive = list(range(start_clients))
    next_id = start_clients
    pos = {cid: _cam(rng) for cid in alive}
    events = []
    for _ in range(steps):
        if len(alive) > 1 and rng.random() < 0.3:
            cid = alive[int(rng.integers(len(alive)))]
            alive.remove(cid)
            events.append(("evict", cid))
        if len(alive) < max_clients and rng.random() < 0.5:
            cam = _cam(rng)
            events.append(("admit", next_id, cam))
            pos[next_id] = cam
            alive.append(next_id)
            next_id += 1
        moves = {}
        for cid in alive:
            pos[cid] = (pos[cid] + rng.normal(0, 4.0, 3)).astype(np.float32)
            moves[cid] = pos[cid].copy()
        events.append(("sync", moves))
    return events


def _record(service, stats, cid, payload):
    """One client's view of one sync (everything host-side, copied)."""
    slot = service._slot_of(cid)
    rec = {
        "cut": np.asarray(service.state.cut_gids[slot]).copy(),
        "cut_size": int(stats.cut_size[slot]),
        "delta_size": int(stats.delta_size[slot]),
        "sync_bytes": float(stats.sync_bytes[slot]),
        "resident": int(stats.client_resident[slot]),
        "resweeps": int(stats.resweeps[slot]),
        "nodes": int(stats.nodes_touched[slot]),
    }
    if payload and service.dedup:
        ids, dec = service.client_delta(cid)
        ids = np.asarray(ids)
        sel = ids >= 0
        rec["delta_ids"] = ids[sel].copy()          # ascending by gid
        rec["delta_rows"] = {f: np.asarray(getattr(dec, f))[sel].copy()
                             for f in GAUSS_FIELDS}
    return rec


def _run_churn(mk_service, schedule, payload=True):
    """Drive one service through a schedule. Returns (service,
    {cid: [per-sync records]}, {cid: [per-sync cameras]})."""
    service = mk_service()
    log, hist = {}, {}
    for ev in schedule:
        if ev[0] == "admit":
            cid = service.admit(ev[2])
            assert cid == ev[1]  # ids are monotone and deterministic
            log.setdefault(cid, [])
            hist.setdefault(cid, [])
        elif ev[0] == "evict":
            service.evict(ev[1])
        else:
            stats = service.sync(dict(ev[1]))
            for cid in service.active_ids:
                log.setdefault(cid, []).append(
                    _record(service, stats, cid, payload))
                hist.setdefault(cid, []).append(ev[1][cid])
    return service, log, hist


def _assert_records_equal(a, b, ctx, skip=()):
    assert a.keys() == b.keys(), ctx
    for k in a:
        if k in skip:
            continue
        if k == "delta_rows":
            for f in GAUSS_FIELDS:
                np.testing.assert_array_equal(a[k][f], b[k][f],
                                              err_msg=f"{ctx}:{k}:{f}")
        elif isinstance(a[k], np.ndarray):
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"{ctx}:{k}")
        else:
            assert a[k] == b[k], (ctx, k, a[k], b[k])


def _replay_reference(tree, cfg, hist_cid, dedup, mode="pooled"):
    """A fresh single-client fixed-size service replaying one survivor's
    camera history; returns its per-sync records."""
    ref = svc.LodService(tree, cfg, 1, focal=FOCAL, mode=mode, dedup=dedup)
    out = []
    for cam in hist_cid:
        stats = ref.sync(np.asarray([cam], np.float32))
        out.append(_record(ref, stats, 0, payload=dedup))
    return out


# ---------------------------------------------------------------------------
# (a) churn conformance: survivors == fresh fixed-size replay, on all paths
# ---------------------------------------------------------------------------


def test_churn_conformance_across_paths(small_tree):
    """One randomized schedule (admits, evicts, growth past the capacity
    bucket) driven through all three sweep paths: the paths must agree
    bitwise sync-by-sync, and every surviving client must be bitwise
    indistinguishable from a fresh fixed-size service replaying only its own
    camera history (cuts, decoded Δ payload rows, per-client accounting —
    everything except the shared-payload byte split, which rightly depends
    on who else shares a union row; see the unicast test below)."""
    # seed chosen so the schedule reaches 5 concurrent clients (forcing one
    # capacity growth 4 -> 8), evicts three (recycling slots, including a
    # late admit), and leaves >= 2 survivors
    rng = np.random.default_rng(72)
    schedule = _gen_schedule(rng, steps=7, start_clients=2, max_clients=5)
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    mk = {
        "pooled": lambda: svc.LodService(small_tree, cfg, 2, focal=FOCAL,
                                         capacity=4, mode="pooled"),
        "vmapped": lambda: svc.LodService(small_tree, cfg, 2, focal=FOCAL,
                                          capacity=4, mode="vmapped"),
        "pallas": lambda: svc.LodService(small_tree, cfg, 2, focal=FOCAL,
                                         capacity=4, mode="pooled",
                                         sweep_impl="pallas"),
    }
    runs = {name: _run_churn(f, schedule) for name, f in mk.items()}

    s_pool, log_pool, hist = runs["pooled"]
    assert s_pool.capacity == 8  # the 5th client forced one bucket growth

    # cross-path bitwise agreement, sync by sync, client by client
    for other in ("vmapped", "pallas"):
        _s, log_o, _h = runs[other]
        assert log_o.keys() == log_pool.keys()
        for cid in log_pool:
            assert len(log_o[cid]) == len(log_pool[cid])
            for k, (a, b) in enumerate(zip(log_pool[cid], log_o[cid])):
                _assert_records_equal(a, b, f"{other}/cid{cid}/sync{k}")
    # the two pooled schedulers share every state leaf bitwise
    s_pal = runs["pallas"][0]
    for a, b in zip(jax.tree_util.tree_leaves(s_pool.state),
                    jax.tree_util.tree_leaves(s_pal.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # conformance vs fresh fixed-size replay, for every survivor
    assert len(s_pool.active_ids) >= 2
    for cid in s_pool.active_ids:
        ref_log = _replay_reference(small_tree, cfg, hist[cid], dedup=True)
        assert len(ref_log) == len(log_pool[cid])
        for k, (got, want) in enumerate(zip(log_pool[cid], ref_log)):
            _assert_records_equal(got, want, f"replay/cid{cid}/sync{k}",
                                  skip=("sync_bytes",))


def test_churn_unicast_byte_accounting_matches_fresh_replay(small_tree):
    """With the unicast wire format, per-client bytes are independent of the
    rest of the fleet — so a survivor's byte accounting must replay bitwise
    too, header and all."""
    rng = np.random.default_rng(7)
    schedule = _gen_schedule(rng, steps=5, start_clients=2, max_clients=4)
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    s, log, hist = _run_churn(
        lambda: svc.LodService(small_tree, cfg, 2, focal=FOCAL, capacity=4,
                               mode="pooled", dedup=False),
        schedule, payload=False)
    assert s.active_ids
    for cid in s.active_ids:
        ref_log = _replay_reference(small_tree, cfg, hist[cid], dedup=False)
        for k, (got, want) in enumerate(zip(log[cid], ref_log)):
            _assert_records_equal(got, want, f"unicast/cid{cid}/sync{k}")


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_property_churn_conformance(tiny_tree, seed):
    """Property form (hypothesis, or the seeded deterministic fallback):
    random schedules on the tiny tree, pooled path, unicast accounting —
    every survivor replays bitwise (cuts AND bytes)."""
    rng = np.random.default_rng(seed)
    schedule = _gen_schedule(rng, steps=4, start_clients=1, max_clients=4)
    cfg = svc.SessionConfig(tau=24.0, cut_budget=2048)
    s, log, hist = _run_churn(
        lambda: svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, capacity=4,
                               mode="pooled", dedup=False),
        schedule, payload=False)
    for cid in s.active_ids:
        ref = svc.LodService(tiny_tree, cfg, 1, focal=FOCAL, mode="pooled",
                             dedup=False)
        for k, cam in enumerate(hist[cid]):
            stats = ref.sync(np.asarray([cam], np.float32))
            want = _record(ref, stats, 0, payload=False)
            _assert_records_equal(log[cid][k], want,
                                  f"prop/cid{cid}/sync{k}")


# ---------------------------------------------------------------------------
# (b) inactive slots are provably free; recycled slots are fresh
# ---------------------------------------------------------------------------


def _fresh_slot_reference(tree, cfg, capacity):
    return svc.service_init(tree, cfg, 0, capacity=capacity)


def _assert_slot_fresh(state, fresh, slot, ctx=""):
    for got_leaf, want_leaf in zip(jax.tree_util.tree_leaves(
            (state.mgr, state.temporal, state.cut_gids, state.sync_index,
             state.pending)),
            jax.tree_util.tree_leaves(
            (fresh.mgr, fresh.temporal, fresh.cut_gids, fresh.sync_index,
             fresh.pending))):
        np.testing.assert_array_equal(np.asarray(got_leaf[slot]),
                                      np.asarray(want_leaf[slot]),
                                      err_msg=ctx)


def test_inactive_slots_are_provably_free(small_tree):
    """Slots without a client must contribute NOTHING: all-zero stats rows
    (header included), no staleness resweeps, no Δ-union rows, and their
    per-slot state stays bitwise frozen at the reset value while the live
    fleet churns around them."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    service = svc.LodService(small_tree, cfg, 3, focal=FOCAL, capacity=8,
                             mode="pooled", dedup=True)
    fresh = _fresh_slot_reference(small_tree, cfg, 8)
    rng = np.random.default_rng(3)
    cams = np.stack([_cam(rng) for _ in range(3)])
    for f in range(4):
        stats = service.sync(cams + rng.normal(0, 3.0, cams.shape
                                               ).astype(np.float32))
        inactive = ~service._active
        assert inactive.sum() == 5
        for name in ("cut_size", "delta_size", "unique_delta", "sync_bytes",
                     "dedup_bytes_saved", "nodes_touched", "resweeps",
                     "client_resident", "overflow", "delta_overflow",
                     "delta_shipped", "delta_deferred", "pages"):
            col = np.asarray(getattr(stats, name))
            assert not col[inactive].any(), (f, name)
        # no union rows on behalf of an inactive slot
        assert not np.asarray(service.last_delta.ref_mask)[inactive].any()
        # device fleet mask agrees with the host mirror
        np.testing.assert_array_equal(
            np.asarray(service.state.fleet.active), service._active)
        for slot in np.flatnonzero(inactive):
            _assert_slot_fresh(service.state, fresh, int(slot),
                               ctx=f"sync{f}/slot{slot}")
    # evict mid-run: the vacated slot is immediately frozen-fresh too
    victim = service.active_ids[1]
    v_slot = service._slot_of(victim)
    service.evict(victim)
    _assert_slot_fresh(service.state, fresh, v_slot, ctx="evicted")
    stats = service.sync()
    assert float(np.asarray(stats.sync_bytes)[v_slot]) == 0.0
    _assert_slot_fresh(service.state, fresh, v_slot, ctx="evicted+sync")


def test_recycled_slot_is_indistinguishable_from_fresh(small_tree):
    """Evict a heavily-used client and admit a new one into the recycled
    slot: the new tenant's first sync must equal a brand-new single-client
    service's first sync at the same camera, bit for bit."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, capacity=2,
                             mode="pooled", dedup=True)
    rng = np.random.default_rng(11)
    cams = np.stack([_cam(rng), _cam(rng)])
    for _ in range(3):
        service.sync(cams)
        cams = cams + rng.normal(0, 5.0, cams.shape).astype(np.float32)
    service.evict(0)
    cam_new = _cam(rng)
    cid = service.admit(cam_new)
    slot = service._slot_of(cid)
    assert slot == 0  # the recycled slot
    assert int(np.asarray(service.state.fleet.generation)[0]) == 2
    # the latest payload belongs to the PREVIOUS tenant of this slot —
    # reading it through the new client must fail, never silently alias
    with pytest.raises(ValueError, match="predates"):
        service.client_delta(cid)
    stats = service.sync({cid: cam_new})
    got = _record(service, stats, cid, payload=True)

    ref = svc.LodService(small_tree, cfg, 1, focal=FOCAL, mode="pooled",
                         dedup=True)
    want = _record(ref, ref.sync(np.asarray([cam_new])), 0, payload=True)
    _assert_records_equal(got, want, "recycled-first-sync",
                          skip=("sync_bytes",))
    assert got["sync_bytes"] > 0  # a cold cut is real traffic


def test_capacity_growth_follows_pow2_buckets(small_tree):
    """Admission beyond the slot array grows it on the shared pow2 policy;
    live clients' cuts survive the growth untouched."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=4096)
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, capacity=2,
                             mode="pooled")
    cams = {0: [30.0, 30.0, 2.0], 1: [40.0, 40.0, 2.0]}
    service.sync(cams)
    pre_cut = {cid: np.asarray(service.client_cut(cid)).copy()
               for cid in (0, 1)}
    assert service.capacity == 2
    service.admit([35.0, 35.0, 2.0])
    assert service.capacity == ls.pow2_bucket(3, flt.MAX_CAPACITY) == 4
    for _ in range(2):
        service.admit([20.0, 20.0, 2.0])
    assert service.capacity == 8 and service.n_clients == 5
    for cid in (0, 1):  # growth must not disturb live state
        np.testing.assert_array_equal(
            np.asarray(service.client_cut(cid)), pre_cut[cid])
    with pytest.raises(KeyError):
        service.evict(99)
    with pytest.raises(ValueError):
        svc.LodService(small_tree, cfg, 4, focal=FOCAL, capacity=2)


# ---------------------------------------------------------------------------
# (c) functional session-core admission/eviction primitives
# ---------------------------------------------------------------------------


def test_session_admit_evict_steps_reset_to_fresh(small_tree):
    """pipeline.admit_step / evict_step: after any amount of session
    history, both return exactly session_init's state (bitwise) — the
    single-client contract the fleet slot reset is built on."""
    cfg = pl.SessionConfig(tau=TAU, w=2, cut_budget=8192)
    codec, bpg = pl.session_wire_format(small_tree, cfg)
    state = pl.session_init(small_tree, cfg)
    pos = np.array([30.0, 30.0, 2.0], np.float32)
    for _ in range(5):
        state, _ = pl.session_step(small_tree, codec, cfg, state, pos,
                                   jnp.float32(FOCAL), bpg)
        pos = pos + 2.0
    assert int(state.sync_index) > 0
    fresh = pl.session_init(small_tree, cfg)
    for step in (pl.evict_step, pl.admit_step):
        got = step(state)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(fresh)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=step.__name__)
    # admit(evict(s)) == evict(s): a recycled slot is a fresh slot
    ev = pl.evict_step(state)
    re = pl.admit_step(ev)
    for a, b in zip(jax.tree_util.tree_leaves(ev),
                    jax.tree_util.tree_leaves(re)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (d) the recompile contract
# ---------------------------------------------------------------------------


def _trace_counts():
    """Compiled-signature counts of every jitted sync entry point on the
    churn path (jax's per-function pjit cache — one entry per static
    signature ever traced)."""
    entries = {
        "top_and_staleness": ls.batched_top_and_staleness,
        "compact_stale_pairs": svc._compact_stale_pairs,
        "pooled_pair_sweep": svc._pooled_pair_sweep,
        "apply_pooled_updates": svc._apply_pooled_updates,
        "batched_cut_gids": svc._batched_cut_gids,
        "batched_cloud_sync": mgr.batched_cloud_sync,
        "union_mask": dp._union_mask,
        "union_refs": dp._union_refs,
        "admit_slot": svc.service_admit_slot,
        "evict_slot": svc.service_evict_slot,
    }
    return {name: fn._cache_size() for name, fn in entries.items()}


def test_recompile_bound_churn_within_and_across_buckets(small_tree):
    """The capacity-bucket recompile contract: after a warmup cycle that
    visits each static signature once, a 30-step admit/evict/sync churn
    schedule INSIDE one pow2 capacity bucket triggers ZERO new traces of any
    jitted sync entry point; the admit that grows the bucket triggers
    exactly ONE new trace of each."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=8192)
    anchor = np.asarray([30.0, 30.0, 2.0], np.float32)
    service = svc.LodService(small_tree, cfg, 5, focal=FOCAL, capacity=8,
                             mode="pooled", dedup=True)
    # warmup: one cycle through every signature the churn loop can hit —
    # all-cold first sync, parked steady sync, cold-admit sync, evict sync
    # (clients park at one anchor so data-dependent pow2 buckets — stale
    # pool, Δ-union width — repeat exactly across the loop)
    service.sync(np.tile(anchor, (5, 1)))
    service.sync()
    warm_cid = service.admit(anchor)
    service.sync()
    service.evict(warm_cid)
    service.sync()
    base = _trace_counts()

    alive = []
    for t in range(30):
        if t % 3 == 0 and service.n_clients < 8:
            alive.append(service.admit(anchor))
        elif t % 3 == 2 and alive:
            service.evict(alive.pop(0))
        service.sync()
    assert service.capacity == 8
    assert _trace_counts() == base  # zero retraces inside the bucket

    # fill the bucket one admit+sync at a time (still warm signatures)...
    while service.n_clients < 8:
        service.admit(anchor)
        service.sync()
    assert _trace_counts() == base
    pre = _trace_counts()
    # ...then the admit that outgrows it: capacity 8 -> 16, and exactly one
    # new trace per entry point on the next churn cycle (one cold sync for
    # the sync-path entries, one evict for the evict step — a second sync
    # would legitimately add the steady-state Δ-width signature too, which
    # is the bounded data-dependent bucketing, not a capacity retrace)
    grow_cid = service.admit(anchor)
    assert service.capacity == 16
    service.sync()
    service.evict(grow_cid)
    post = _trace_counts()
    assert {k: post[k] - pre[k] for k in pre} == {k: 1 for k in pre}


def test_render_fallback_fleet_cache_key(small_tree):
    """The render caches key on the fleet signature: an evict can't serve a
    stale stacked-rig pytree (a wrong-length rig list is rejected, the
    evicted slot renders black, live clients are unchanged), and re-using
    the same rigs after re-admission realigns cleanly."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=4096)
    service = svc.LodService(small_tree, cfg, 3, focal=FOCAL, capacity=4,
                             mode="pooled")
    cams = np.asarray([[30, 30, 2], [40, 32, 3], [26, 44, 2]], np.float32)
    service.sync(cams)
    rigs = [_rig_at(c) for c in cams]
    il0, ir0, _ = service.render_fallback(rigs, list_len=128,
                                          max_pairs=1 << 15)
    assert il0.shape[0] == 4  # slot axis, not client count
    service.evict(1)
    with pytest.raises(ValueError):
        service.render_fallback(rigs, list_len=128, max_pairs=1 << 15)
    il1, ir1, _ = service.render_fallback([rigs[0], rigs[2]], list_len=128,
                                          max_pairs=1 << 15)
    # evicted slot 1 renders black; surviving slots are bitwise unchanged
    assert not np.asarray(il1[1]).any() and not np.asarray(ir1[1]).any()
    for slot in (0, 2):
        np.testing.assert_array_equal(np.asarray(il1[slot]),
                                      np.asarray(il0[slot]))
        np.testing.assert_array_equal(np.asarray(ir1[slot]),
                                      np.asarray(ir0[slot]))
    # distinct fleet signatures live side by side in the caches
    assert len(service._rcfg_cache) == 2
    cid = service.admit(cams[1])
    il2, _, _ = service.render_fallback([rigs[0], _rig_at(cams[1]), rigs[2]],
                                        list_len=128, max_pairs=1 << 15)
    # the re-admitted client hasn't synced yet: empty queue, black frame
    assert not np.asarray(il2[service._slot_of(cid)]).any()


def test_pooled_render_masks_inactive_tiles(small_tree):
    """On the pooled Pallas render path, an inactive slot's tiles never
    reach the kernel even if its (placeholder) rig overlaps the scene —
    fleet rasterization work tracks live clients."""
    cfg = svc.SessionConfig(tau=TAU, cut_budget=2048)
    service = svc.LodService(small_tree, cfg, 2, focal=FOCAL, capacity=4,
                             mode="pooled")
    cams = np.asarray([[30, 30, 2], [40, 32, 3]], np.float32)
    service.sync(cams)
    rigs = [_rig_at(c) for c in cams]
    il_v, ir_v, _ = service.render_fallback(rigs, list_len=128,
                                            max_pairs=1 << 15, path="vmap")
    il_p, ir_p, _ = service.render_fallback(rigs, list_len=128,
                                            max_pairs=1 << 15, path="pooled")
    assert not np.asarray(il_p[2:]).any() and not np.asarray(ir_p[2:]).any()
    for slot in (0, 1):  # live clients: pooled == vmapped (allclose — FMA)
        np.testing.assert_allclose(np.asarray(il_p[slot]),
                                   np.asarray(il_v[slot]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(ir_p[slot]),
                                   np.asarray(ir_v[slot]), atol=1e-5)
