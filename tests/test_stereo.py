"""Stereo rasterization: BIT-ACCURACY (the paper's headline claim, §4.4).

The full stereo pipeline (shared preprocessing → left raster → triangulation
shift-merge → right raster) must produce images bitwise equal to two fully
independent per-eye renders."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.binning import BinConfig, bin_left, bin_right
from repro.core.camera import StereoRig, make_camera
from repro.core.gaussians import random_gaussians
from repro.core.pipeline import render_stereo, render_stereo_reference
from repro.core.projection import depth_ranks, project
from repro.core.stereo import n_categories, stereo_lists


def _rig(width=128, height=96, focal=220.0, near=0.2, baseline=0.06,
         pos=(0, -18, 2)):
    cam = make_camera(list(pos), [0, 0, 0], focal_px=focal, width=width,
                      height=height, near=near)
    return StereoRig(left=cam, baseline=baseline)


@pytest.mark.parametrize("n,seed", [(200, 0), (600, 1), (1000, 2)])
def test_stereo_bit_accurate(n, seed):
    rng = np.random.default_rng(seed)
    g = random_gaussians(rng, n, sh_degree=1, extent=6.0)
    rig = _rig()
    il, ir, (_s, ll, rl, _st) = render_stereo(g, rig, tile=16, list_len=192,
                                              max_pairs=1 << 16)
    # the bit-accuracy claim is only valid with every budget honored — the
    # binning AND merge overflow flags must both be surfaced and clean
    assert not bool(ll.overflow) and not bool(rl.overflow)
    ref_l, ref_r = render_stereo_reference(g, rig)
    np.testing.assert_array_equal(np.asarray(il), np.asarray(ref_l))
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ref_r))


@pytest.mark.parametrize("baseline", [0.03, 0.06, 0.1])
@pytest.mark.parametrize("tile", [8, 16])
def test_stereo_bit_accurate_sweep(baseline, tile):
    """Tile-size / baseline sweep (paper Fig. 25 dimensions)."""
    rng = np.random.default_rng(7)
    g = random_gaussians(rng, 400, sh_degree=2, extent=6.0)
    rig = _rig(baseline=baseline)
    il, ir, (_s, ll, rl, _st) = render_stereo(g, rig, tile=tile, list_len=256,
                                              max_pairs=1 << 16)
    assert not bool(ll.overflow) and not bool(rl.overflow)
    ref_l, ref_r = render_stereo_reference(g, rig)
    np.testing.assert_array_equal(np.asarray(il), np.asarray(ref_l))
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ref_r))


def test_shift_merge_equals_direct_rebin():
    """The SRU/line-buffer construction must equal independent re-binning."""
    rng = np.random.default_rng(3)
    g = random_gaussians(rng, 500, sh_degree=1, extent=6.0)
    rig = _rig()
    cam = rig.left
    tile = 16
    n_cat = n_categories(rig.max_disparity_px(), tile)
    tiles_x_r = -(-cam.width // tile)
    wide = dataclasses.replace(cam, width=(tiles_x_r + n_cat - 1) * tile)
    splats = project(g, rig, wide)
    ranks = depth_ranks(splats)
    cfg = BinConfig(tile=tile, max_pairs=1 << 16, list_len=256)
    left = bin_left(splats, wide.width, cam.height, cfg, ranks)
    merged = stereo_lists(left, splats, ranks, tile=tile, width=cam.width,
                          n_cat=n_cat)
    direct = bin_right(splats, cam.width, cam.height, cfg, ranks)
    np.testing.assert_array_equal(np.asarray(merged.lists), np.asarray(direct.lists))
    np.testing.assert_array_equal(np.asarray(merged.counts), np.asarray(direct.counts))


def test_disparity_triangulation():
    """x_R = x_L − B·f/z must hold exactly for the projected centers."""
    rng = np.random.default_rng(4)
    g = random_gaussians(rng, 100, sh_degree=0, extent=4.0)
    rig = _rig()
    cam = rig.left
    wide = dataclasses.replace(cam, width=cam.width + 80)
    s = project(g, rig, wide)
    # project the right camera directly
    right = rig.right
    t = right.world_to_cam(g.mu)
    xr_direct = np.asarray(right.focal * t[:, 0] / t[:, 2] + right.cx)
    xr_shift = np.asarray(s.mean2d[:, 0] - s.disparity)
    vis = np.asarray(s.depth) > cam.near
    np.testing.assert_allclose(xr_shift[vis], xr_direct[vis], rtol=1e-4, atol=1e-3)


def test_depth_order_shared_between_eyes():
    """Rectified stereo: camera z identical for both eyes ⇒ one sort serves two."""
    rng = np.random.default_rng(5)
    g = random_gaussians(rng, 200, sh_degree=0, extent=5.0)
    rig = _rig()
    zl = np.asarray(rig.left.world_to_cam(g.mu))[:, 2]
    zr = np.asarray(rig.right.world_to_cam(g.mu))[:, 2]
    np.testing.assert_allclose(zl, zr, rtol=1e-6)


def test_max_disparity_bound():
    """Disparity of every visible splat is bounded by B·f/near (paper §4.4)."""
    rng = np.random.default_rng(6)
    g = random_gaussians(rng, 500, sh_degree=0, extent=8.0)
    rig = _rig()
    wide = dataclasses.replace(rig.left, width=rig.left.width + 80)
    s = project(g, rig, wide)
    vis = np.asarray(s.visible)
    assert (np.asarray(s.disparity)[vis] <= rig.max_disparity_px() + 1e-3).all()
