"""LoD tree construction invariants."""

import numpy as np
import pytest

from repro.core.gaussians import random_gaussians
from repro.core.lod_tree import build_lod_tree
from repro.core.lod_search import global_level_np, global_parent_np


def _check_invariants(tree):
    m = tree.meta
    parent = global_parent_np(tree)
    level = global_level_np(tree)
    valid = np.asarray(tree.valid_mask())
    size = np.asarray(tree.size)

    # exactly one root, at level 0 in the top-tree (or slab 0 if P==0)
    roots = np.where((parent == -1) & valid)[0]
    assert len(roots) == 1 and roots[0] == 0

    # parent levels are exactly one less
    ch = np.where(valid & (parent >= 0))[0]
    assert (level[ch] == level[parent[ch]] + 1).all()

    # bounding-sphere monotonicity: parent sphere contains child sphere
    mu = np.asarray(tree.gaussians.mu)
    d = np.linalg.norm(mu[ch] - mu[parent[ch]], axis=1)
    assert (d + size[ch] <= size[parent[ch]] + 1e-3).all()

    # every real node is counted once
    assert valid.sum() == m.n_real
    # slab roots have their parent in the top-tree
    rpt = np.asarray(tree.slab_root_parent_top)
    assert ((rpt >= 0) & (rpt < m.T)).all()
    # slab-local parents precede their children (BFS order)
    sp = np.asarray(tree.slab_parent)
    sv = np.asarray(tree.slab_valid)
    jj = np.broadcast_to(np.arange(m.S), (m.Ns, m.S))
    has_local = sv & (sp >= 0)
    assert (sp[has_local] < jj[has_local]).all()


@pytest.mark.parametrize("n,branching", [(50, (2, 4)), (400, (3, 7)), (1500, (2, 8))])
def test_tree_invariants(n, branching):
    rng = np.random.default_rng(n)
    leaves = random_gaussians(rng, n, sh_degree=1, extent=50.0)
    tree = build_lod_tree(leaves, branching=branching, target_subtrees=8, seed=2)
    _check_invariants(tree)


def test_city_tree_invariants(small_tree):
    _check_invariants(small_tree)


def test_leaf_count_preserved(small_city, small_tree):
    leafs = np.asarray(small_tree.top_is_leaf).sum() + (
        np.asarray(small_tree.slab_is_leaf) & np.asarray(small_tree.slab_valid)).sum()
    assert leafs == small_city.n == small_tree.meta.n_leaves


def test_padding_is_inert(small_tree):
    sv = np.asarray(small_tree.slab_valid)
    size = np.asarray(small_tree.slab_size())
    assert (size[~sv] == 0).all()
