"""Sharding rules + a scaled-down multi-device dry-run in a subprocess
(8 fake devices — the production path at toy scale; the conftest process must
keep seeing the single real device)."""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.models.model_zoo import get_model
from repro.sharding.partitioning import logical_to_pspec, make_shardings


def _fake_mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_logical_to_pspec_divisibility_fallback():
    mesh = _fake_mesh()
    # size-1 axes always divide
    assert logical_to_pspec(("embed", "heads"), mesh, (64, 64)) == P("data", "model")


def test_make_shardings_cover_all_archs():
    mesh = _fake_mesh()
    for name in sorted(ARCHS):
        model = get_model(ARCHS[name])
        shapes, axes = model.abstract_params()
        sh = make_shardings(mesh, shapes, axes)
        assert jax.tree.structure(sh) == jax.tree.structure(shapes)
        # caches too
        cache = model.abstract_cache(2, 64)
        csh = make_shardings(mesh, cache, model.cache_axes())
        assert jax.tree.structure(csh) == jax.tree.structure(cache)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "src")
import jax, numpy as np
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh
from repro.launch.hlo_analysis import analyze
import repro.launch.dryrun as dr
import repro.configs as C
from repro.models.config import reduced, ShapeConfig, SHAPES
import repro.models.config as mc

# shrink: tiny configs + tiny shapes, 2x4 and 2x2x2 meshes
mc.SHAPES = (ShapeConfig("train_4k", 64, 8, "train"),
             ShapeConfig("decode_32k", 128, 8, "decode"))
C.ARCHS = {k: reduced(v) for k, v in C.ARCHS.items()}

results = {}
for mesh in [make_mesh((2, 4), ("data", "model")),
             make_mesh((2, 2, 2), ("pod", "data", "model"))]:
    for arch in ["qwen2.5-3b", "granite-moe-1b-a400m", "zamba2-2.7b"]:
        for shape in ["train_4k", "decode_32k"]:
            lowered, meta = lower_cell(arch, shape, mesh)
            compiled = lowered.compile()
            ana = analyze(compiled.as_text())
            key = f"{arch}|{shape}|{len(mesh.devices.shape)}"
            results[key] = dict(flops=ana["flops"], ok=True)
print(json.dumps(results))
"""


@pytest.mark.slow
def test_multi_device_dryrun_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                         text=True, timeout=600, cwd=".")
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(results) == 12
    assert all(v["ok"] for v in results.values())
    assert all(v["flops"] > 0 for v in results.values())
