"""Deadline-driven MTP scheduler + the partial-fleet sync primitive.

The load-bearing claims pinned here:

  * PARTICIPATE-ALL PARITY — a sync whose participation mask selects every
    live slot replays BITWISE against the lockstep `participate=None` call
    (state AND stats), on all three sweep paths (vmapped XLA, pooled XLA,
    pooled Pallas) and, in the slow subprocess leg, on a forced 8-device
    clients×slabs mesh;
  * ISOLATION — a partial tick leaves every sat-out slot's state (temporal,
    manager, cut_gids, pending debt, sync counter) bitwise untouched and
    its stats rows zero, reusing the frozen-inactive-slot invariant; the
    controller freshness mask only re-commits measurements from slots that
    actually synced;
  * bad participation input (unknown client id, wrong mask shape) raises
    BEFORE any state is touched;
  * `sync(cam_positions=...)` array and dict forms agree bitwise on a
    churned fleet with non-contiguous live slots, and a dict naming an
    unknown client raises cleanly without corrupting `_slot_cams`;
  * the scheduler itself: EDF selection under deadlines + the greedy cost
    budget (the most urgent candidate is never starved), MTP/deadline-miss
    stamping on the served slots only, online cost-model refit,
    predicted-cost admission denial that leaves the service untouched,
    JSON-able state_dict round-trip, and snapshot/recovery carriage;
  * the workload generators are deterministic and shaped as documented.
"""

import json
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.serve import lod_service as svc
from repro.serve import recovery as rec
from repro.serve import scheduler as sch

FOCAL = 1400.0
TAU = 32.0


def _mk(tree, n, **kw):
    cfg = svc.SessionConfig(tau=TAU, cut_budget=2048)
    kw.setdefault("mode", "pooled")
    return svc.LodService(tree, cfg, n, focal=FOCAL, dedup=True, **kw)


def _cams(rng, n):
    return rng.uniform([2, 2, 1], [28, 28, 6], (n, 3)).astype(np.float32)


def _leaves_equal(a, b, tag=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=tag)


class _Clock:
    """Scripted monotonic clock: +1ms per read."""

    def __init__(self, t0: float = 100.0, step: float = 1e-3):
        self.t, self.step = float(t0), float(step)

    def __call__(self) -> float:
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# (a) participate-everyone == lockstep, bitwise, on all three sweep paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,impl", [("vmapped", "xla"), ("pooled", "xla"),
                                       ("pooled", "pallas")])
def test_participate_everyone_replays_lockstep_bitwise(tiny_tree, mode, impl):
    a = _mk(tiny_tree, 4, mode=mode, sweep_impl=impl)
    b = _mk(tiny_tree, 4, mode=mode, sweep_impl=impl)
    rng = np.random.default_rng(3)
    pos = _cams(rng, 4)
    for t in range(3):
        # alternate the two participation spellings (client ids, bool mask)
        part = (b.active_ids if t % 2 == 0
                else np.ones(b.capacity, bool))
        sa = a.sync(pos)
        sb = b.sync(pos, participate=part)
        _leaves_equal(sa, sb, f"{mode}/{impl}:stats:{t}")
        _leaves_equal(a.state, b.state, f"{mode}/{impl}:state:{t}")
        pos = (pos + rng.normal(0, 2.5, (4, 3))).astype(np.float32)


# ---------------------------------------------------------------------------
# (b) partial-tick isolation: sat-out slots are provably untouched
# ---------------------------------------------------------------------------


def _satout_rows_unchanged(new, old, touched, capacity):
    touched = set(touched)
    others = [s for s in range(capacity) if s not in touched]
    for x, y in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(old)):
        x, y = np.asarray(x), np.asarray(y)
        if x.ndim >= 1 and x.shape[0] == capacity:
            np.testing.assert_array_equal(x[others], y[others])
        else:
            np.testing.assert_array_equal(x, y)


def test_partial_tick_satout_slots_bitwise_untouched(tiny_tree):
    service = _mk(tiny_tree, 5, capacity=8)
    rng = np.random.default_rng(7)
    service.sync(_cams(rng, 5))
    service.evict(1)
    service.evict(3)                      # live slots 0, 2, 4 — ragged
    service.sync()                        # settle post-churn
    before = jax.device_get(service.state)
    idx0 = np.asarray(service.state.sync_index).copy()
    slot0 = service._slot_of(0)

    stats = service.sync({0: np.asarray([25.0, 25.0, 4.0], np.float32)},
                         participate=[0])
    _satout_rows_unchanged(service.state, before, {slot0}, service.capacity)
    # the tick only advanced the participant's sync counter
    idx1 = np.asarray(service.state.sync_index)
    assert idx1[slot0] == idx0[slot0] + 1
    # sat-out stats rows are zero (active AND inactive alike)
    others = [s for s in range(service.capacity) if s != slot0]
    for f in ("cut_size", "delta_size", "sync_bytes", "resweeps",
              "nodes_touched", "unique_delta"):
        assert not np.asarray(getattr(stats, f))[others].any(), f
    # the controller freshness mask marks exactly the participant
    fresh = np.zeros(service.capacity, bool)
    fresh[slot0] = True
    np.testing.assert_array_equal(service._stats_fresh, fresh)


def test_bad_participation_raises_before_state_is_touched(tiny_tree):
    service = _mk(tiny_tree, 3)
    service.sync(_cams(np.random.default_rng(0), 3))
    state = service.state
    with pytest.raises(KeyError):
        service.sync(participate=[99])
    with pytest.raises(ValueError):
        service.sync(participate=np.ones(service.capacity + 1, bool))
    assert service.state is state         # nothing ran


# ---------------------------------------------------------------------------
# (c) sync camera forms: array vs dict on a churned fleet, unknown ids
# ---------------------------------------------------------------------------


def test_sync_array_and_dict_forms_agree_on_churned_fleet(tiny_tree):
    a = _mk(tiny_tree, 6, capacity=8)
    b = _mk(tiny_tree, 6, capacity=8)
    rng = np.random.default_rng(5)
    pos = _cams(rng, 6)
    for s in (a, b):
        s.sync(pos)
        s.evict(0)
        s.evict(4)                        # live slots 1,2,3,5 — ragged
    ids = a.active_ids
    assert ids == b.active_ids
    for t in range(2):
        # array form addresses live clients in slot order == active_ids
        cams = _cams(rng, len(ids))
        sa = a.sync(cams)
        sb = b.sync({cid: cams[k] for k, cid in enumerate(ids)})
        _leaves_equal(sa, sb, f"stats:{t}")
        _leaves_equal(a.state, b.state, f"state:{t}")
        np.testing.assert_array_equal(a._slot_cams, b._slot_cams)


def test_sync_dict_unknown_client_raises_without_corruption(tiny_tree):
    service = _mk(tiny_tree, 3)
    rng = np.random.default_rng(1)
    service.sync(_cams(rng, 3))
    cams_before = service._slot_cams.copy()
    state_before = service.state
    with pytest.raises(KeyError):
        service.sync({0: [9.0, 9.0, 2.0], 99: [1.0, 1.0, 1.0]})
    # the bad id aborted BEFORE any position was stored or any sync ran
    np.testing.assert_array_equal(service._slot_cams, cams_before)
    assert service.state is state_before
    service.sync({0: [9.0, 9.0, 2.0]})    # the service is still healthy
    assert np.allclose(service._slot_cams[service._slot_of(0)],
                       [9.0, 9.0, 2.0])


# ---------------------------------------------------------------------------
# (d) the scheduler: selection, MTP stamping, cost model, admission
# ---------------------------------------------------------------------------


def test_tick_serves_only_unserved_motion_and_stamps_mtp(tiny_tree):
    service = _mk(tiny_tree, 4)
    rng = np.random.default_rng(2)
    service.sync(_cams(rng, 4))
    sched = sch.DeadlineScheduler(service, default_deadline_ms=1e6,
                                  clock=_Clock())
    sched.observe_motion(0, [20.0, 20.0, 3.0])
    sched.observe_motion(2, [4.0, 22.0, 2.0])
    assert set(sched.select()) == {0, 2}
    stats = sched.tick()
    mtp = np.asarray(stats.mtp_ms)
    served = [service._slot_of(0), service._slot_of(2)]
    others = [service._slot_of(1), service._slot_of(3)]
    assert (mtp[served] > 0.0).all() and not mtp[others].any()
    assert not np.asarray(stats.deadline_miss).any()
    assert sched.tick() is None           # motion served — an idle tick
    # a deadline the clock cannot hold stamps a miss for that client only
    sched.set_deadline(0, 1e-6)
    sched.observe_motion(0, [21.0, 21.0, 3.0])
    stats = sched.tick()
    miss = np.asarray(stats.deadline_miss)
    assert bool(miss[service._slot_of(0)]) and miss.sum() == 1
    s = sched.stats_summary()
    assert s["n"] == 3 and 0.0 < s["deadline_miss_rate"] < 1.0
    assert s["mtp_p99_ms"] >= s["mtp_p50_ms"] > 0.0


def test_select_edf_orders_by_slack_and_budget_never_starves_head(tiny_tree):
    service = _mk(tiny_tree, 3)
    service.sync(np.tile(np.asarray([10.0, 10.0, 2.0], np.float32), (3, 1)))
    sched = sch.DeadlineScheduler(service, default_deadline_ms=1000.0,
                                  clock=_Clock())
    sched.set_deadline(1, 10.0)           # the tightest deadline
    for cid in (0, 1, 2):
        # teleport: every candidate prices at a full resweep
        sched.observe_motion(cid, [25.0 - cid, 3.0 + cid, 5.0])
    sel = sched.select()
    assert sel[0] == 1 and set(sel) == {0, 1, 2}
    # a budget one candidate exhausts still selects the head of the queue
    sched.cost.alpha, sched.cost.beta = 0.0, 1.0
    sched.tick_budget_ms = 1.0
    assert sched.select() == [1]
    stats = sched.tick()
    assert int(np.asarray(stats.resweeps)[service._slot_of(1)]) > 0
    # the deferred candidates are still pending, served by later ticks
    sched.tick_budget_ms = None
    assert set(sched.select()) == {0, 2}


def test_cost_model_refits_from_measured_ticks():
    cm = sch.CostModel(alpha_ms=50.0, beta_ms=5.0, min_samples=6)
    for pairs in (0, 2, 4, 8, 16, 32, 64):
        cm.observe(pairs, 3.0 + 0.25 * pairs)
    assert cm.alpha == pytest.approx(3.0, abs=1e-6)
    assert cm.beta == pytest.approx(0.25, abs=1e-6)
    assert cm.predict(100) == pytest.approx(28.0, abs=1e-4)
    # a constant-pairs window re-estimates alpha only (no beta signal)
    cm2 = sch.CostModel(alpha_ms=1.0, beta_ms=0.5, min_samples=2)
    for _ in range(4):
        cm2.observe(4, 7.0)
    assert cm2.alpha == pytest.approx(7.0) and cm2.beta == 0.5
    # a degenerate fit never predicts negative (free) work
    cm3 = sch.CostModel(min_samples=2)
    for pairs, ms in ((0, 10.0), (10, 1.0), (20, 0.5)):
        cm3.observe(pairs, ms)
    assert cm3.beta == 0.0 and cm3.predict(1000) >= 0.0


def test_predicted_cost_admission_denial_leaves_service_untouched(tiny_tree):
    service = _mk(tiny_tree, 2, capacity=4)
    service.sync(_cams(np.random.default_rng(4), 2))
    sched = sch.DeadlineScheduler(service, default_deadline_ms=50.0,
                                  clock=_Clock())
    # the newcomer's cold full resweep is predicted over its deadline
    sched.cost.alpha, sched.cost.beta = 1000.0, 0.0
    state = service.state
    with pytest.raises(svc.AdmissionDenied, match="cold first sync"):
        sched.admit([5.0, 5.0, 2.0])
    assert sched.admit([5.0, 5.0, 2.0], required=False) is None
    assert service.n_clients == 2 and service.state is state
    # aggregate utilization gate: cheap ticks, but fleet demand > one lane
    # (deadline = 2·Ns·beta ms, so each client needs half the sync lane —
    # three of them cannot fit, while any one cold sync still could)
    sched.cost.alpha, sched.cost.beta = 0.0, 1.0
    d = 2.0 * sched._ns
    for cid in service.active_ids:
        sched.set_deadline(cid, d)
    with pytest.raises(svc.AdmissionDenied, match="utilization"):
        sched.admit([5.0, 5.0, 2.0], deadline_ms=d)
    with pytest.raises(svc.AdmissionDenied, match="not positive"):
        sched.admit([5.0, 5.0, 2.0], deadline_ms=0.0)
    # with sane costs the admit lands and its first pose is scheduled
    sched.cost.beta = 0.001
    cid = sched.admit([5.0, 5.0, 2.0], deadline_ms=40.0)
    assert service.n_clients == 3 and sched.deadline(cid) == 40.0
    assert cid in sched.select()
    sched.evict(cid)
    assert cid not in sched._clients and service.n_clients == 2


def test_scheduler_state_dict_json_roundtrip(tiny_tree):
    service = _mk(tiny_tree, 2)
    service.sync(_cams(np.random.default_rng(6), 2))
    clock = _Clock()
    sched = sch.DeadlineScheduler(service, default_deadline_ms=25.0,
                                  tick_budget_ms=12.0, clock=clock)
    sched.set_deadline(1, 75.0)
    sched.observe_motion(0, [20.0, 20.0, 3.0])
    sched.observe_motion(0, [21.0, 20.0, 3.0])   # → nonzero velocity EWMA
    sched.tick()
    blob = json.dumps(sched.state_dict())        # JSON-able by contract

    other = _mk(tiny_tree, 2)
    other.sync(_cams(np.random.default_rng(6), 2))
    sched2 = sch.DeadlineScheduler(other, clock=_Clock())
    sched2.load_state_dict(json.loads(blob))
    assert sched2.default_deadline_ms == 25.0
    assert sched2.tick_budget_ms == 12.0
    assert sched2.deadline(1) == 75.0
    assert sched2.cost.alpha == sched.cost.alpha
    assert sched2.cost.beta == sched.cost.beta
    for cid in (0, 1):
        a, b = sched._clients[cid], sched2._clients[cid]
        assert b.velocity == a.velocity and b.ewma_pairs == a.ewma_pairs
    assert sched._clients[0].velocity > 0.0


def test_recovery_journals_partial_ticks_and_carries_scheduler_state(
        tiny_tree, tmp_path):
    service = _mk(tiny_tree, 3)
    rng = np.random.default_rng(8)
    sched = sch.DeadlineScheduler(service, default_deadline_ms=42.0,
                                  clock=_Clock())
    man = rec.RecoveryManager(service, str(tmp_path), every=16,
                              scheduler=sched)
    pos = _cams(rng, 3)
    man.sync(pos)
    # partial ticks through the journal (stable ids, replayed on recover)
    man.sync({0: pos[0] + 2.0}, participate=[0])
    man.sync({1: pos[1] + 2.0, 2: pos[2] + 1.0}, participate=[1, 2])
    man.snapshot_now()                    # scheduler extras ride along
    man.sync({0: pos[0] + 4.0}, participate=[0])   # journal tail to replay

    man2, replayed = rec.recover(tiny_tree, str(tmp_path))
    assert replayed == 1
    _leaves_equal(man2.service.state, man.service.state, "recovered state")
    assert man2.scheduler_state is not None
    sched2 = sch.DeadlineScheduler(man2.service, clock=_Clock())
    sched2.load_state_dict(man2.scheduler_state)
    assert sched2.default_deadline_ms == 42.0
    assert sched2.cost.alpha == sched.cost.alpha


# ---------------------------------------------------------------------------
# (e) workload generators
# ---------------------------------------------------------------------------


def test_workload_generators_deterministic_and_shaped():
    a = sch.poisson_arrivals(np.random.default_rng(0), 2.0, 256)
    b = sch.poisson_arrivals(np.random.default_rng(0), 2.0, 256)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (256,) and a.dtype == np.int64
    assert 1.5 < a.mean() < 2.5

    calm = sch.bursty_motion_path(np.random.default_rng(1), 128,
                                  speed=0.5, burst_prob=0.0)
    again = sch.bursty_motion_path(np.random.default_rng(1), 128,
                                   speed=0.5, burst_prob=0.0)
    np.testing.assert_array_equal(calm, again)
    assert calm.shape == (128, 3) and calm.dtype == np.float32
    steps = np.linalg.norm(np.diff(calm, axis=0), axis=1)
    np.testing.assert_allclose(steps, 0.5, rtol=1e-5)   # no bursts: |step|==speed
    wild = sch.bursty_motion_path(np.random.default_rng(1), 128,
                                  speed=0.5, burst_prob=0.5, burst_scale=10.0)
    assert np.linalg.norm(np.diff(wild, axis=0), axis=1).max() > 2.0

    strag = sch.straggler_path(np.random.default_rng(2), 200,
                               teleport_every=5, extent=30.0)
    assert strag.shape == (200, 3)
    assert np.abs(strag).max() <= 30.0
    jumps = np.linalg.norm(np.diff(strag, axis=0), axis=1)
    assert (jumps == 0.0).mean() > 0.5    # mostly stationary...
    assert (jumps > 5.0).sum() >= 10      # ...punctuated by teleports


# ---------------------------------------------------------------------------
# (f) the 8-device mesh leg (the acceptance contract)
# ---------------------------------------------------------------------------


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
sys.path.insert(0, "src")
import numpy as np, jax
from repro.core.gaussians import random_gaussians
from repro.core.lod_tree import build_lod_tree
from repro.launch.mesh import make_fleet_mesh
from repro.serve import lod_service as svc

assert len(jax.devices()) == 8
rng = np.random.default_rng(11)
leaves = random_gaussians(rng, 150, sh_degree=1, extent=30.0)
tree = build_lod_tree(leaves, branching=(2, 4), target_subtrees=8, seed=1)
cfg = svc.SessionConfig(tau=32.0, cut_budget=2048)
mesh = make_fleet_mesh(clients=4, slabs=2)

def mk(m):
    return svc.LodService(tree, cfg, 4, focal=1400.0, capacity=8,
                          mode="pooled", dedup=True, mesh=m)

def eq(a, b, tag):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=tag)

lock, part, plain = mk(mesh), mk(mesh), mk(None)
pos = np.random.default_rng(5).uniform(
    [2, 2, 1], [28, 28, 6], (4, 3)).astype(np.float32)
for t in range(3):
    mask = part.active_ids if t % 2 == 0 else np.ones(8, bool)
    sl = lock.sync(pos)
    sp = part.sync(pos, participate=mask)
    s0 = plain.sync(pos, participate=np.ones(8, bool))
    eq(sl, sp, f"stats:{t}")
    eq(sl, s0, f"stats-vs-plain:{t}")
    eq(lock.state, part.state, f"state:{t}")
    eq(lock.state, plain.state, f"state-vs-plain:{t}")
    pos = (pos + np.random.default_rng(t).normal(0, 2.0, (4, 3))
           ).astype(np.float32)

# a PARTIAL tick under the mesh: sat-out slots bitwise untouched, and the
# mask rides the clients axis without disturbing the declared shardings
before = jax.device_get(part.state)
sp = part.sync({0: pos[0] + 5.0}, participate=[0])
for x, y in zip(jax.tree_util.tree_leaves(part.state),
                jax.tree_util.tree_leaves(before)):
    x, y = np.asarray(x), np.asarray(y)
    if x.ndim >= 1 and x.shape[0] == 8:
        np.testing.assert_array_equal(x[1:], y[1:])
assert not np.asarray(sp.resweeps)[1:].any()
assert not np.asarray(sp.sync_bytes)[1:].any()
for leaf in jax.tree_util.tree_leaves(part.state):
    spec = leaf.sharding.spec
    if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == 8:
        assert spec[0] == "clients", (leaf.shape, spec)
print(json.dumps({"ok": True}))
"""


@pytest.mark.slow
def test_partial_sync_mesh_parity_subprocess():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=900,
                         cwd=".")
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
