"""Trainer: loss goes down, fault injection → auto-restore, straggler flags,
grad compression converges, data determinism across restarts."""

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.tokens import DataConfig, PrefetchLoader, SyntheticTokens
from repro.models.config import reduced
from repro.models.model_zoo import get_model
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_model():
    cfg = reduced(ARCHS["qwen2.5-3b"], n_layers=2, d_model=64, d_ff=128,
                  vocab=256, n_heads=4, n_kv_heads=2, head_dim=16)
    return get_model(cfg)


def _data(cfg, batch=4, seq=32):
    return DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=0)


def test_loss_decreases(tmp_path):
    model = _tiny_model()
    tr = Trainer(model, opt.OptimizerConfig(lr=1e-3, warmup_steps=5,
                                            total_steps=60),
                 TrainerConfig(total_steps=60, checkpoint_every=1000,
                               checkpoint_dir=str(tmp_path)),
                 _data(model.cfg))
    out = tr.run(resume=False)
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_fault_injection_restores_and_finishes(tmp_path):
    model = _tiny_model()
    boom = {"armed": True}

    def hook(step):
        if step == 25 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    tr = Trainer(model, opt.OptimizerConfig(lr=1e-3, total_steps=40),
                 TrainerConfig(total_steps=40, checkpoint_every=10,
                               checkpoint_dir=str(tmp_path)),
                 _data(model.cfg), step_hook=hook)
    out = tr.run(resume=False)
    assert out["restarts"] == 1
    assert out["final_step"] == 40
    # failure hit before step 25 ran; restore was from the step-20 checkpoint,
    # so 20-24 ran twice, 19 once, 25 once (hook disarmed)
    steps = [h["step"] for h in out["history"]]
    assert steps.count(24) == 2 and steps.count(19) == 1 and steps.count(25) == 1


def test_straggler_watchdog(tmp_path):
    import time
    model = _tiny_model()

    def hook(step):
        if step == 15:
            time.sleep(1.0)  # injected slow step

    tr = Trainer(model, opt.OptimizerConfig(lr=1e-3, total_steps=20),
                 TrainerConfig(total_steps=20, checkpoint_every=1000,
                               checkpoint_dir=str(tmp_path),
                               straggler_factor=3.0),
                 _data(model.cfg, batch=2, seq=16), step_hook=hook)
    out = tr.run(resume=False)
    assert 15 in out["stragglers"]


def test_grad_compression_converges(tmp_path):
    model = _tiny_model()
    tr = Trainer(model, opt.OptimizerConfig(lr=1e-3, warmup_steps=5,
                                            total_steps=60),
                 TrainerConfig(total_steps=60, checkpoint_every=1000,
                               checkpoint_dir=str(tmp_path),
                               compress_grads=True),
                 _data(model.cfg))
    out = tr.run(resume=False)
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.15, (first, last)


def test_data_determinism():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=3)
    src = SyntheticTokens(cfg)
    b1 = src.batch(17)
    b2 = SyntheticTokens(cfg).batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_prefetch_matches_direct():
    cfg = DataConfig(vocab=500, seq_len=32, global_batch=2, seed=1)
    src = SyntheticTokens(cfg)
    loader = PrefetchLoader(src, start_step=5)
    try:
        for expect in range(5, 9):
            step, batch = next(loader)
            assert step == expect
            np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                          np.asarray(src.batch(step)["tokens"]))
    finally:
        loader.close()


def test_optimizer_matches_numpy_reference():
    import jax, jax.numpy as jnp
    ocfg = opt.OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                               weight_decay=0.0, clip_norm=1e9)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    st = opt.init(p)
    p2, st2, _ = opt.apply_updates(p, g, st, ocfg)
    # numpy adam, step 1
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.05 * gn * gn
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    lr1 = float(opt.schedule(ocfg, jnp.asarray(1)))
    expect = np.asarray(p["w"]) - lr1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
