"""Per-arch smoke tests: reduced config of the same family, one forward/train
step + one decode step on CPU; asserts output shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.config import reduced
from repro.models.model_zoo import get_model, input_specs
from repro.models.config import ShapeConfig

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=16, global_batch=2, mode="train")


def _batch_for(cfg, rng):
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, 1152)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, max(s // cfg.audio_downsample, 1), 1280)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step_smoke(arch):
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    rng = np.random.default_rng(1)
    params, axes = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda t: isinstance(t, tuple))
    batch = _batch_for(cfg, rng)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    # gradient sanity: finite and mostly nonzero
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), arch
    nz = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nz > len(flat) * 0.5, f"{arch}: too many zero grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_decode_smoke(arch):
    cfg = reduced(ARCHS[arch])
    model = get_model(cfg)
    rng = np.random.default_rng(2)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    cache = model.make_cache(b, s)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, {"token": tok})
    assert logits.shape == (b, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), arch
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_input_specs_cover_all_shapes(arch):
    from repro.models.config import SHAPES, long_context_capable
    cfg = ARCHS[arch]
    for shape in SHAPES:
        if shape.name == "long_500k" and not long_context_capable(cfg):
            continue  # documented skip (DESIGN.md §4)
        specs = input_specs(cfg, shape)
        assert "tokens" in specs or "token" in specs
        for v in jax.tree.leaves(specs):
            assert isinstance(v, jax.ShapeDtypeStruct)


def test_abstract_params_no_allocation():
    """abstract_params must not allocate device memory even for 123B."""
    cfg = ARCHS["mistral-large-123b"]
    model = get_model(cfg)
    shapes, axes = model.abstract_params()
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    assert total > 100e9  # the real thing
    assert jax.tree.structure(shapes) == jax.tree.structure(
        axes, is_leaf=lambda t: isinstance(t, tuple))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma3-4b", "xlstm-350m",
                                  "zamba2-2.7b", "granite-moe-1b-a400m"])
def test_arch_decode_matches_forward(arch):
    """Incremental decode must reproduce the full forward's last logits.

    MoE: capacity drops differ between a 2-token decode batch and a full
    forward, so the equivalence only holds drop-free — use a high capacity
    factor (drops themselves are covered by test_moe_capacity_drops_bounded)."""
    import dataclasses as _dc
    cfg = reduced(ARCHS[arch])
    if cfg.n_experts:
        cfg = _dc.replace(cfg, capacity_factor=8.0)
    model = get_model(cfg)
    rng = np.random.default_rng(3)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": toks[:, : s // 2]}
    if cfg.family == "vlm":
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, 1152)), jnp.float32)
    _, cache = model.prefill(params, batch, max_len=s + (cfg.n_img_tokens or 0))
    for t in range(s // 2, s):
        logits, cache = model.decode_step(params, cache, {"token": toks[:, t]})

    # full forward reference
    fam_mod = __import__(f"repro.models.{'dense' if cfg.family in ('dense', 'vlm') else {'moe': 'moe', 'xlstm': 'xlstm', 'hybrid': 'zamba'}[cfg.family]}",
                         fromlist=["x"])
    if cfg.family in ("dense", "vlm"):
        x = fam_mod.forward(params, toks, cfg,
                            img_embed=batch.get("img_embed"), kv_chunk=8)
    elif cfg.family == "moe":
        x, _ = fam_mod.forward(params, toks, cfg, kv_chunk=8)
    else:
        x = fam_mod.forward(params, toks, cfg)
    ref = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                     params["unembed"].astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
